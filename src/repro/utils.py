"""Shared utilities: logging, timing, pytree helpers, numeric helpers."""
from __future__ import annotations

import contextlib
import dataclasses
import logging
import math
import os
import time
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

_LOG_FORMAT = "%(asctime)s %(levelname).1s %(name)s: %(message)s"


def get_logger(name: str) -> logging.Logger:
    if not name.startswith("repro"):      # e.g. "__main__" under python -m
        name = f"repro.{name}"
    logger = logging.getLogger(name)
    if not logging.getLogger("repro").handlers:
        root = logging.getLogger("repro")
        handler = logging.StreamHandler()
        handler.setFormatter(logging.Formatter(_LOG_FORMAT))
        root.addHandler(handler)
        root.setLevel(os.environ.get("REPRO_LOG_LEVEL", "INFO"))
    return logger


@contextlib.contextmanager
def timed(label: str, sink: dict | None = None) -> Iterator[None]:
    """Context manager measuring wall time; optionally records into ``sink``."""
    t0 = time.perf_counter()
    yield
    dt = time.perf_counter() - t0
    if sink is not None:
        sink[label] = dt


def block_tree(tree: Any) -> Any:
    """Block until all arrays in a pytree are ready (for honest timing)."""
    for leaf in jax.tree_util.tree_leaves(tree):
        if isinstance(leaf, jax.Array):
            leaf.block_until_ready()
    return tree


def tree_bytes(tree: Any) -> int:
    """Total byte size of all array leaves in a pytree."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        if hasattr(leaf, "dtype") and hasattr(leaf, "shape"):
            total += int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
    return total


def tree_params(tree: Any) -> int:
    """Total element count of all array leaves in a pytree."""
    return sum(
        int(np.prod(leaf.shape))
        for leaf in jax.tree_util.tree_leaves(tree)
        if hasattr(leaf, "shape")
    )


def tree_any_nan(tree: Any) -> bool:
    for leaf in jax.tree_util.tree_leaves(tree):
        if hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype, jnp.floating):
            if bool(jnp.any(jnp.isnan(leaf))):
                return True
    return False


def human_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f} {unit}"
        n /= 1024.0
    return f"{n:.2f} PiB"


def human_count(n: float) -> str:
    for unit in ("", "K", "M", "B", "T"):
        if abs(n) < 1000.0:
            return f"{n:.2f}{unit}"
        n /= 1000.0
    return f"{n:.2f}Q"


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return ceil_div(a, b) * b


def asdict_shallow(obj: Any) -> dict:
    """dataclasses.asdict without deep-copying array fields."""
    return {f.name: getattr(obj, f.name) for f in dataclasses.fields(obj)}
