"""Shared utilities: logging, timing, pytree helpers, numeric helpers."""
from __future__ import annotations

import contextlib
import dataclasses
import logging
import math
import os
import time
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

_LOG_FORMAT = "%(asctime)s %(levelname).1s %(name)s: %(message)s"

# -- jax version compat ------------------------------------------------------
# shard_map graduated from jax.experimental (with kwargs renamed), and
# make_mesh grew axis_types, in newer jax; these shims keep one call site per
# API working on both.

def shard_map_compat(fn, mesh, in_specs, out_specs, **kwargs):
    """jax.shard_map on new jax; jax.experimental.shard_map on old, with
    ``check_vma``->``check_rep`` and ``axis_names``->``auto`` translated."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kwargs)
    from jax.experimental.shard_map import shard_map
    if "check_vma" in kwargs:                    # renamed (same meaning)
        kwargs["check_rep"] = kwargs.pop("check_vma")
    if "axis_names" in kwargs:                   # old API names the complement
        manual = set(kwargs.pop("axis_names"))
        kwargs["auto"] = frozenset(set(mesh.axis_names) - manual)
    return shard_map(fn, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, **kwargs)


def peak_memory_bytes(memory_analysis) -> int:
    """CompiledMemoryStats.peak_memory_in_bytes where available; otherwise
    the argument+output+temp estimate older jaxlib exposes."""
    peak = getattr(memory_analysis, "peak_memory_in_bytes", None)
    if peak is not None:
        return int(peak)
    ma = memory_analysis
    return int(ma.argument_size_in_bytes + ma.output_size_in_bytes
               + ma.temp_size_in_bytes - ma.alias_size_in_bytes)


def make_mesh_compat(shape, axes, **kwargs):
    """jax.make_mesh with axis_types=Auto where supported (Auto is the
    default behavior on versions without the parameter)."""
    if hasattr(jax.sharding, "AxisType"):
        kwargs.setdefault("axis_types",
                          (jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes, **kwargs)


def get_logger(name: str) -> logging.Logger:
    if not name.startswith("repro"):      # e.g. "__main__" under python -m
        name = f"repro.{name}"
    logger = logging.getLogger(name)
    if not logging.getLogger("repro").handlers:
        root = logging.getLogger("repro")
        handler = logging.StreamHandler()
        handler.setFormatter(logging.Formatter(_LOG_FORMAT))
        root.addHandler(handler)
        root.setLevel(os.environ.get("REPRO_LOG_LEVEL", "INFO"))
    return logger


@contextlib.contextmanager
def timed(label: str, sink: dict | None = None) -> Iterator[None]:
    """Context manager measuring wall time; optionally records into ``sink``."""
    t0 = time.perf_counter()
    yield
    dt = time.perf_counter() - t0
    if sink is not None:
        sink[label] = dt


def block_tree(tree: Any) -> Any:
    """Block until all arrays in a pytree are ready (for honest timing)."""
    for leaf in jax.tree_util.tree_leaves(tree):
        if isinstance(leaf, jax.Array):
            leaf.block_until_ready()
    return tree


def tree_bytes(tree: Any) -> int:
    """Total byte size of all array leaves in a pytree."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        if hasattr(leaf, "dtype") and hasattr(leaf, "shape"):
            total += int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
    return total


def tree_params(tree: Any) -> int:
    """Total element count of all array leaves in a pytree."""
    return sum(
        int(np.prod(leaf.shape))
        for leaf in jax.tree_util.tree_leaves(tree)
        if hasattr(leaf, "shape")
    )


def tree_any_nan(tree: Any) -> bool:
    for leaf in jax.tree_util.tree_leaves(tree):
        if hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype, jnp.floating):
            if bool(jnp.any(jnp.isnan(leaf))):
                return True
    return False


def human_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f} {unit}"
        n /= 1024.0
    return f"{n:.2f} PiB"


def human_count(n: float) -> str:
    for unit in ("", "K", "M", "B", "T"):
        if abs(n) < 1000.0:
            return f"{n:.2f}{unit}"
        n /= 1000.0
    return f"{n:.2f}Q"


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return ceil_div(a, b) * b


def asdict_shallow(obj: Any) -> dict:
    """dataclasses.asdict without deep-copying array fields."""
    return {f.name: getattr(obj, f.name) for f in dataclasses.fields(obj)}
