"""Train/serve step builders: model API × optimizer × GSPMD sharding.

``build_train_step``/``build_serve_fns`` produce the pure step functions;
``shardings_for``/``lower_*`` attach PartitionSpecs for a concrete mesh —
used identically by the real trainer (``launch/train.py``), the streaming
pipeline (train-on-stream), and the multi-pod dry-run
(``launch/dryrun.py`` lowers the same functions at full scale).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import batch_specs_logical, input_specs
from repro.configs.base import ModelConfig, OptimizerConfig, ShapeConfig
from repro.models.registry import get_model
from repro.optim import adamw_update, init_opt_state, zero1_state_specs
from repro.parallel.sharding import (ShardingRules, tree_specs,
                                     tree_specs_shaped, use_mesh)
from repro.utils import get_logger

log = get_logger(__name__)


def rules_for(config: ModelConfig) -> ShardingRules:
    return ShardingRules(overrides=dict(config.sharding_overrides))


# -- step functions ------------------------------------------------------------
def build_train_step(config: ModelConfig, opt: OptimizerConfig
                     ) -> Callable[[dict, dict], tuple[dict, dict]]:
    model = get_model(config)

    def train_step(state: dict, batch: dict) -> tuple[dict, dict]:
        def loss_fn(params):
            return model.loss_and_metrics(params, batch, config)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["params"])
        new_params, new_opt, opt_metrics = adamw_update(
            state["params"], grads, state["opt"], opt)
        return ({"params": new_params, "opt": new_opt},
                {**metrics, **opt_metrics, "total_loss": loss})

    return train_step


def build_serve_fns(config: ModelConfig):
    model = get_model(config)

    def prefill(params: dict, batch: dict) -> tuple[jax.Array, dict]:
        return model.prefill(params, batch, config)

    def decode_step(params: dict, tokens: jax.Array, cache: dict
                    ) -> tuple[jax.Array, dict]:
        return model.decode_step(params, tokens, cache, config)

    return prefill, decode_step


def init_state(key: jax.Array, config: ModelConfig,
               opt: OptimizerConfig) -> dict:
    model = get_model(config)
    params = model.init(key, config)
    state = {"params": params, "opt": init_opt_state(params, opt)}
    # Identical constant leaves (zeros/ones) can alias the same device
    # buffer, which breaks donation ("donate the same buffer twice") —
    # force-unique every leaf once at init.
    return jax.tree_util.tree_map(jnp.copy, state)


# -- sharding assembly -----------------------------------------------------------
@dataclass
class CellShardings:
    """All PartitionSpecs for one (arch × shape × mesh) cell."""
    mesh: Mesh
    rules: ShardingRules
    param_specs: Any
    state_specs: Any | None = None          # train
    batch_specs: Any | None = None
    cache_specs: Any | None = None          # decode

    def sharding(self, spec_tree: Any) -> Any:
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(self.mesh, s), spec_tree,
            is_leaf=lambda x: isinstance(x, P))


def shardings_for(config: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                  opt: OptimizerConfig | None = None) -> CellShardings:
    model = get_model(config)
    rules = rules_for(config)
    param_shapes = jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0), config))
    pspecs = tree_specs_shaped(model.param_specs(config), param_shapes,
                               mesh, rules)
    cell = CellShardings(mesh=mesh, rules=rules, param_specs=pspecs)
    bspec_logical = batch_specs_logical(config, shape)
    cell_inputs = input_specs(config, shape)
    if shape.kind == "train":
        if opt is None:
            opt = OptimizerConfig()
        cell.state_specs = {
            "params": pspecs,
            "opt": zero1_state_specs(pspecs, param_shapes, mesh, opt)}
        cell.batch_specs = tree_specs_shaped(
            bspec_logical["batch"], cell_inputs["batch"], mesh, rules)
    elif shape.kind == "prefill":
        cache_shapes = jax.eval_shape(
            lambda: model.init_cache(config, shape.global_batch,
                                     shape.seq_len))
        cell.batch_specs = tree_specs_shaped(
            bspec_logical["batch"], cell_inputs["batch"], mesh, rules)
        cell.cache_specs = tree_specs_shaped(
            model.cache_specs(config), cache_shapes, mesh, rules)
    else:  # decode
        cell.batch_specs = tree_specs_shaped(
            bspec_logical["tokens"], cell_inputs["tokens"], mesh, rules)
        cell.cache_specs = tree_specs_shaped(
            model.cache_specs(config), cell_inputs["cache"], mesh, rules)
    return cell


# -- lowering (dry-run entry points) ---------------------------------------------
def lower_cell(config: ModelConfig, shape: ShapeConfig, mesh: Mesh,
               opt: OptimizerConfig | None = None):
    """Lower the cell's step function at full scale (no allocation).

    Returns (lowered, kind). train -> train_step(state, batch);
    prefill -> prefill(params, batch); decode -> decode_step(params, tokens,
    cache)."""
    opt = opt or OptimizerConfig()
    model = get_model(config)
    rules = rules_for(config)
    cell = shardings_for(config, shape, mesh, opt)
    specs = input_specs(config, shape)

    with use_mesh(mesh, rules):
        if shape.kind == "train":
            state_shapes = jax.eval_shape(
                lambda: init_state(jax.random.PRNGKey(0), config, opt))
            fn = build_train_step(config, opt)
            jitted = jax.jit(
                fn,
                in_shardings=(cell.sharding(cell.state_specs),
                              cell.sharding(cell.batch_specs)),
                out_shardings=(cell.sharding(cell.state_specs), None),
                donate_argnums=(0,))
            lowered = jitted.lower(state_shapes, specs["batch"])
        elif shape.kind == "prefill":
            param_shapes = jax.eval_shape(
                lambda: model.init(jax.random.PRNGKey(0), config))
            prefill, _ = build_serve_fns(config)
            jitted = jax.jit(
                prefill,
                in_shardings=(cell.sharding(cell.param_specs),
                              cell.sharding(cell.batch_specs)),
                out_shardings=(None, cell.sharding(cell.cache_specs)))
            lowered = jitted.lower(param_shapes, specs["batch"])
        else:  # decode
            param_shapes = jax.eval_shape(
                lambda: model.init(jax.random.PRNGKey(0), config))
            _, decode = build_serve_fns(config)
            jitted = jax.jit(
                decode,
                in_shardings=(cell.sharding(cell.param_specs),
                              cell.sharding(cell.batch_specs),
                              cell.sharding(cell.cache_specs)),
                out_shardings=(None, cell.sharding(cell.cache_specs)),
                donate_argnums=(2,))
            lowered = jitted.lower(param_shapes, specs["tokens"],
                                   specs["cache"])
    return lowered, shape.kind
