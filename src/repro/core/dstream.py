"""Discretized streams: micro-batch scheduling over RDDs (paper §II, Fig. 7).

A DStream is a time-indexed sequence of RDDs. Every ``batch_interval`` the
streaming context drains each registered source into a batch RDD (per-topic
RDDs unioned, exactly the paper's ``run_batch``), applies the pipeline
function, and hands the result to sinks. Processing-time accounting exposes
the paper's near-real-time criterion: *processing time per micro-batch must
stay below the batch interval*, otherwise batches queue without bound.

The scheduler runs inline (``run_batches``) for deterministic tests and
benchmarks, or on a background thread (``start``/``stop``) for the streaming
examples. Checkpointing of stream progress makes a restarted pipeline resume
where it left off — offsets + replayable broker give at-least-once
processing, upgraded to exactly-once when the sink is idempotent (both
demonstrated in tests). The checkpoint is epoch-stamped and commits consumed
offsets *atomically with attached window state* (one ``os.replace``; see
``repro/data/state.py``), so an open window's accumulated records survive a
crash together with the offsets that consumed them. Serial sinks are
delivered before the commit — a failing sink replays the batch rather than
losing it; delivery *lanes* (``add_sink(policy=...)``) are asynchronous and
keep their documented <= queue-depth post-commit crash window.

The ``broker`` handed to :class:`StreamingContext` may equally be a
:class:`~repro.data.transport.RemoteBroker` — same duck type, served from
another process by :class:`~repro.data.transport.BrokerServer` — which puts
the consumer on the opposite side of a socket from the detector, the paper's
Fig. 7 beamline/cluster split (see ``docs/transport.md``). After each
committed batch the context pushes its progress to the broker
(``broker.commit``) so *remote* producers' backpressure can measure lag
against what was actually processed, not just appended.
"""
from __future__ import annotations

import json
import os
import threading
import time
from contextlib import nullcontext
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

from repro.core.broker import Broker, OffsetRange, create_rdd
from repro.core.rdd import RDD, Context
from repro.utils import get_logger

log = get_logger(__name__)


def _stage(rec: Any, name: str):
    """A span-stage timer when a recorder is present, else a no-op — so
    ``_commit`` reads the same with and without tracing."""
    return rec.stage(name) if rec is not None else nullcontext()


@dataclass
class BatchInfo:
    index: int
    ranges: list[OffsetRange]
    num_records: int
    scheduled_at: float
    processing_time: float = 0.0
    result: Any = None


@dataclass
class StreamProgress:
    """The restart checkpoint, epoch-stamped: consumed offsets per (topic,
    partition) plus, per attached windower, the ref its state store returned
    for this epoch. One ``save`` is one ``os.replace`` — offsets and window
    state advance *together or not at all* (the atomicity the window state
    layer builds on; see ``repro/data/state.py``)."""
    offsets: dict[str, list[int]] = field(default_factory=dict)
    epoch: int = 0
    window_refs: dict[str, int] = field(default_factory=dict)

    def save(self, path: str) -> None:
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"epoch": self.epoch, "offsets": self.offsets,
                       "window_refs": self.window_refs}, f)
            # fsync before the rename: os.replace is atomic against a crash,
            # but without it the new checkpoint's *contents* may not be on
            # disk when the rename is — a power loss could surface a torn
            # checkpoint exactly when recovery matters.
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)

    @classmethod
    def load(cls, path: str) -> "StreamProgress":
        """Load a checkpoint; a torn/corrupt/old-format file degrades to an
        empty progress (with a warning) instead of making the restart
        unrecoverable — the stream replays from offset 0 and idempotent
        sinks absorb the duplicates (at-least-once, never stuck)."""
        if not os.path.exists(path):
            return cls()
        try:
            with open(path) as f:
                blob = json.load(f)
            offsets = {str(t): [int(o) for o in parts]
                       for t, parts in blob["offsets"].items()}
            return cls(offsets=offsets, epoch=int(blob.get("epoch", 0)),
                       window_refs={str(k): int(v) for k, v in
                                    blob.get("window_refs", {}).items()})
        except (OSError, ValueError, KeyError, TypeError,
                AttributeError) as exc:
            log.warning("checkpoint %s is unreadable (%s: %s); starting "
                        "from empty progress", path, type(exc).__name__, exc)
            return cls()


class StreamingContext:
    """Drives micro-batches: broker topics -> union RDD -> pipeline fn -> sinks."""

    def __init__(self, context: Context, broker: Broker,
                 batch_interval: float = 0.1,
                 max_records_per_partition: int | None = None,
                 checkpoint_path: str | None = None,
                 clock: Callable[[], float] | None = None) -> None:
        self.context = context
        self.broker = broker
        self.batch_interval = batch_interval
        self.max_records_per_partition = max_records_per_partition
        self.checkpoint_path = checkpoint_path
        # stream clock: stamps BatchInfo.scheduled_at and pumped-record
        # timestamps. Injectable so time-based windows are deterministic in
        # tests; scheduling waits always use real time.
        self._default_clock = clock is None
        self._clock = clock or time.monotonic
        self._delivery = None          # lazy DeliveryRuntime (parallel sinks)
        self._topics: list[str] = []
        self._decoder: Callable[[Any], Any] | None = None
        self._batch_fn: Callable[[RDD, BatchInfo], Any] | None = None
        self._sinks: list[Callable[[BatchInfo], None]] = []
        # pull-model sources pumped inline before each micro-batch:
        # (source, topic, poll_batch)
        self._sources: list[tuple[Any, str, int]] = []
        # per-topic produce round-robin cursor — persists across batches, so
        # short polls don't restart at partition 0 every batch
        self._rr: dict[str, int] = {}
        # HA: a FailoverBroker bumps .failovers when it promotes a new
        # primary; the new primary's log may be shorter than our cursor
        # (async replication lost the tail), so the cursor must be rebased
        self._last_failovers = getattr(broker, "failovers", 0)
        # windowers whose state rides this context's commit protocol
        self._window_states: list[tuple[str, Any]] = []
        # consumer-group mode (join_group): when set, only assigned
        # partitions are consumed and broker commits carry (group, consumer,
        # generation) so the coordinator can fence stale owners
        self.group_member: Any = None
        self._group_owned: dict[str, set[int]] = {}
        self._group_start_offset: Callable[[str, int], int | None] | None = \
            None
        self._group_on_rebalance: Callable[[dict, dict], None] | None = None
        self._progress = (StreamProgress.load(checkpoint_path)
                          if checkpoint_path else StreamProgress())
        self._history: list[BatchInfo] = []
        self._batch_index = 0
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        # constructor-time import: repro.data.metrics triggers the
        # repro.data package __init__, whose window module imports *this*
        # module — a top-level import here would see it half-initialized
        from repro.data.metrics import TraceLog, get_registry
        self.traces = TraceLog()
        self._obs_server: Any = None
        reg = self._registry = get_registry()
        self._m_batches = reg.counter(
            "stream_batches_total", help="micro-batches committed")
        self._m_records = reg.counter(
            "stream_records_total",
            help="records processed by committed batches")
        self._m_batch_s = reg.histogram(
            "stream_batch_seconds", help="end-to-end micro-batch duration")
        reg.gauge("stream_epoch",
                  help="checkpoint epoch of the last committed batch",
                  callback=lambda: self._progress.epoch)

    # -- wiring -------------------------------------------------------------
    def subscribe(self, topics: Sequence[str],
                  value_decoder: Callable[[Any], Any] | None = None) -> None:
        new = [t for t in topics if t not in self._topics]
        self._topics.extend(new)
        if value_decoder is not None:
            self._decoder = value_decoder
        for t in self._topics:
            self._padded_offsets(t)
        if new and self.group_member is not None:
            # subscription changed while in a group: re-join so the
            # coordinator assigns the new topics' partitions too
            self.group_member.topics = list(self._topics)
            self.group_member.join()
        for t in new:
            # evaluated per scrape, not per batch (a round trip on a remote
            # broker — priced where it is read, never on the hot path)
            self._registry.gauge(
                "stream_lag", help="produced-but-unprocessed records",
                labels={"topic": t}, callback=lambda t=t: self.lag(t))

    def _padded_offsets(self, topic: str,
                        parts: int | None = None) -> list[int]:
        """The checkpointed start offsets, padded with zeros to the broker's
        *current* partition count. A checkpoint written before a topic was
        repartitioned knows fewer partitions than the broker has — zipping
        its starts against the broker's ends would silently never consume
        the new partitions. Pass ``parts`` when the caller already knows the
        count (saves a round trip on a remote broker)."""
        starts = self._progress.offsets.setdefault(topic, [])
        if parts is None:
            parts = self.broker.num_partitions(topic)
        if len(starts) < parts:
            starts.extend([0] * (parts - len(starts)))
        return starts

    def subscribe_source(self, source: Any, topic: str | None = None,
                         partitions: int = 1,
                         poll_batch: int | None = None) -> str:
        """Subscribe a :class:`repro.data.sources.Source` directly.

        Creates ``topic`` if missing (default: ``source-<i>``), subscribes to
        it, and pumps the source inline before each micro-batch — the
        pull-model twin of :class:`repro.data.ingest.IngestRunner`, fully
        deterministic for tests and single-process pipelines. If the source
        is replayable, it is ``seek``-ed to the topic's current end offset so
        a restart (offsets reloaded from checkpoint) does not re-produce
        records the broker already has.
        """
        topic = topic or f"source-{len(self._sources)}"
        if topic not in self.broker.topics():
            self.broker.create_topic(topic, partitions)
        if hasattr(source, "seek"):
            source.seek(sum(self.broker.end_offsets(topic)))
        self.subscribe([topic])
        if poll_batch is not None:
            n = poll_batch
        elif self.max_records_per_partition is not None:
            # the consumer cap is per partition; pump enough to fill them all
            n = self.max_records_per_partition * partitions
        else:
            n = 64
        self._sources.append((source, topic, n))
        return topic

    # -- consumer-group mode ------------------------------------------------
    def join_group(self, group: str, consumer_id: str | None = None, *,
                   heartbeat_interval: float = 1.0,
                   session_timeout: float = 5.0,
                   start_offset: Callable[[str, int], int | None] | None = None,
                   on_rebalance: Callable[[dict, dict], None] | None = None,
                   clock: Callable[[], float] | None = None) -> Any:
        """Enter consumer-group mode: this context consumes only the
        partitions the group coordinator assigns it, heartbeats at the top
        of every micro-batch, and commits offsets under ``(group, consumer,
        generation)`` so a stale owner is fenced instead of corrupting the
        group's progress.

        ``start_offset(topic, partition)`` resolves where a newly *gained*
        partition starts (e.g. from a handoff checkpoint — see
        :class:`~repro.data.groups.GroupConsumer`); returning ``None`` falls
        back to the group's committed offset on the broker. ``on_rebalance
        (old_assignment, new_assignment)`` fires after the context applied
        an ownership change. Returns the :class:`~repro.data.groups
        .GroupMember` (whose ``leave()`` runs automatically in
        :meth:`close`)."""
        from repro.data.groups import GroupMember
        if self.group_member is not None:
            raise ValueError("context already joined group "
                             f"{self.group_member.group!r}")
        self._group_start_offset = start_offset
        self._group_on_rebalance = on_rebalance
        self.group_member = GroupMember(
            self.broker, group, consumer_id, topics=list(self._topics),
            heartbeat_interval=heartbeat_interval,
            session_timeout=session_timeout, clock=clock,
            on_rebalance=self._apply_group_assignment)
        self._registry.gauge(
            "stream_group_partitions",
            help="partitions this consumer currently owns",
            labels={"group": group},
            callback=lambda: sum(len(p) for p in self._group_owned.values()))
        self.group_member.join()
        return self.group_member

    def _apply_group_assignment(self, old: dict, new: dict) -> None:
        """Adopt a new partition assignment: newly gained partitions get
        their start offset resolved (handoff checkpoint, else the group's
        broker-committed offset); lost partitions simply stop appearing in
        :meth:`_pending_ranges`. Fires the user ``on_rebalance`` last."""
        member = self.group_member
        for topic in self._topics:
            owned = set(new.get(topic, []))
            prev = self._group_owned.get(topic, set())
            starts = self._padded_offsets(topic)
            for p in sorted(owned - prev):
                start = None
                if self._group_start_offset is not None:
                    start = self._group_start_offset(topic, p)
                if start is None:
                    done = self.broker.committed(topic, group=member.group)
                    start = done[p] if p < len(done) else 0
                if p >= len(starts):
                    starts.extend([0] * (p + 1 - len(starts)))
                starts[p] = int(start)
            self._group_owned[topic] = owned
        if self._group_on_rebalance is not None:
            self._group_on_rebalance(old, new)

    def foreach_batch(self, fn: Callable[[RDD, BatchInfo], Any]) -> None:
        self._batch_fn = fn
        # windowed(...) tags its wrapper with the Windower it drives: attach
        # it so window state joins this context's commit protocol
        windower = getattr(fn, "windower", None)
        if windower is not None:
            self.attach_window_state(windower)

    def attach_window_state(self, windower: Any,
                            name: str | None = None) -> None:
        """Tie a :class:`~repro.data.window.Windower` into the commit
        protocol. Attached windowers are rolled back to their last committed
        state when a batch fails (the replay must not find records already
        half-pushed), and — when the windower carries a
        :class:`~repro.data.state.WindowStateStore` and this context has a
        ``checkpoint_path`` — their state is persisted each batch and
        published atomically with the consumed offsets, then restored here
        from the checkpoint's ref on a restart."""
        if any(w is windower for _, w in self._window_states):
            return                         # re-registered fn: already wired
        name = name or f"window-{len(self._window_states)}"
        if any(n == name for n, _ in self._window_states):
            raise ValueError(f"window state {name!r} already attached")
        self._window_states.append((name, windower))
        store = getattr(windower, "store", None)
        if store is None:
            return
        if not self.checkpoint_path:
            log.warning("window state store attached but the context has no "
                        "checkpoint_path: nothing to commit it against; the "
                        "store will not be written")
            return
        state = store.restore(self._progress.window_refs.get(name))
        if state is not None:
            windower.restore_state(state)
            if (state.t0 is not None and self._default_clock
                    and getattr(getattr(windower, "spec", None), "kind",
                                None) == "time"):
                log.warning(
                    "restored time-kind window state under the default "
                    "time.monotonic clock: its stream epoch (t0=%r) came "
                    "from the previous process and monotonic readings are "
                    "not comparable across restarts — window arithmetic "
                    "will be wrong. Inject a restart-comparable clock "
                    "(e.g. time.time) or use count windows.", state.t0)

    def add_sink(self, fn: Callable[[BatchInfo], None],
                 policy: Any = None, name: str | None = None) -> None:
        """Register a batch sink. Without a ``policy`` the sink runs serially
        in the batch thread (the degenerate single-thread path). With a
        :class:`~repro.data.delivery.SinkPolicy`, the sink gets its own
        delivery lane — worker thread + bounded queue + failure isolation —
        on this context's :class:`~repro.data.delivery.DeliveryRuntime`."""
        if policy is None:
            self._sinks.append(fn)
        else:
            self.delivery.add_batch_sink(fn, policy, name=name)

    @property
    def delivery(self):
        """The context's sink-delivery runtime (created on first use); its
        dead-letter topics live on this context's broker."""
        if self._delivery is None:
            from repro.data.delivery import DeliveryRuntime
            self._delivery = DeliveryRuntime(broker=self.broker)
        return self._delivery

    # -- consumer-side accounting ------------------------------------------
    def committed(self, topic: str) -> int:
        """Total records committed (processed) for a topic."""
        return sum(self._progress.offsets.get(topic, []))

    def lag(self, topic: str) -> int:
        """Produced-but-unprocessed records — the backpressure signal
        :class:`repro.data.ingest.IngestRunner` bounds."""
        return sum(self.broker.end_offsets(topic)) - self.committed(topic)

    @property
    def sources_exhausted(self) -> bool:
        return all(s.exhausted for s, _, _ in self._sources)

    @property
    def history(self) -> list[BatchInfo]:
        return self._history

    # -- one micro-batch ------------------------------------------------------
    def _pending_ranges(self) -> list[OffsetRange]:
        in_group = self.group_member is not None
        ranges: list[OffsetRange] = []
        for topic in self._topics:
            ends = self.broker.end_offsets(topic)
            # re-pad every batch: the topic may have grown partitions since
            # subscribe (or since the checkpoint was written)
            starts = self._padded_offsets(topic, parts=len(ends))
            owned = self._group_owned.get(topic, set()) if in_group else None
            for p, (start, end) in enumerate(zip(starts, ends)):
                if owned is not None and p not in owned:
                    continue           # another group member owns it
                if self.max_records_per_partition is not None:
                    end = min(end, start + self.max_records_per_partition)
                if end > start:
                    ranges.append(OffsetRange(topic, p, start, end))
        return ranges

    def _pump_sources(self) -> None:
        # the round-robin cursor persists across batches (self._rr): resetting
        # it every pump would land *every* record on partition 0 whenever a
        # poll returns fewer records than the topic has partitions
        for source, topic, n in self._sources:
            if source.exhausted:
                continue
            parts = self.broker.num_partitions(topic)
            rr = self._rr.get(topic, 0)
            for key, value in source.poll(n):
                self.broker.produce(topic, value, key=key,
                                    partition=rr % parts,
                                    timestamp=self._clock())
                rr += 1
            self._rr[topic] = rr

    def _rebase_after_failover(self) -> None:
        """Clamp start offsets to the new primary's log ends after a broker
        failover. Replication is asynchronous: the promoted follower may be
        missing a tail this consumer already read, and a start offset past
        the log end would silently skip every record the new primary appends
        below it. Clamping replays the gap instead — duplicates the
        idempotent-by-key sinks absorb (``docs/replication.md``)."""
        for topic in self._topics:
            ends = self.broker.end_offsets(topic)
            starts = self._padded_offsets(topic, parts=len(ends))
            for p, end in enumerate(ends):
                if starts[p] > end:
                    log.warning(
                        "failover rebase: %s[%d] cursor %d is past the new "
                        "primary's end %d; rewinding (replayed records are "
                        "absorbed by idempotent sinks)",
                        topic, p, starts[p], end)
                    starts[p] = end

    def run_one_batch(self) -> BatchInfo | None:
        """Paper Fig. 8 ``run_batch``: per-topic RDDs, union, process."""
        failovers = getattr(self.broker, "failovers", 0)
        if failovers != self._last_failovers:
            self._last_failovers = failovers
            self._rebase_after_failover()
        if self.group_member is not None:
            # heartbeat / rejoin as due; an ownership change lands through
            # _apply_group_assignment before ranges are computed
            self.group_member.maintain()
        t_pump = time.perf_counter()
        if self._sources:
            self._pump_sources()
        ranges = self._pending_ranges()
        pump_s = time.perf_counter() - t_pump
        if not ranges:
            # no span for idle probes: the trace log holds batches, and an
            # idle poll loop would otherwise drown them
            return None
        info = BatchInfo(index=self._batch_index, ranges=ranges,
                         num_records=sum(r.count() for r in ranges),
                         scheduled_at=self._clock())
        rec = self.traces.begin(self._batch_index, info.num_records)
        rec.add("pump", pump_s)
        per_topic: dict[str, list[OffsetRange]] = {}
        for r in ranges:
            per_topic.setdefault(r.topic, []).append(r)
        # codec decode first (per-topic payload codecs are self-describing,
        # see repro.data.codec), then the subscriber's own value_decoder
        from repro.data.codec import compose_decoder
        decoder = compose_decoder(self._decoder)
        topic_rdds = [create_rdd(self.context, self.broker, rs, decoder)
                      for rs in per_topic.values()]
        union = (topic_rdds[0].union(*topic_rdds[1:])
                 if len(topic_rdds) > 1 else topic_rdds[0])
        # snapshot attached window state so a failed batch fn / serial sink
        # rolls back cleanly: the replay must not find records half-pushed
        rollback = [(w, w.state()) for _, w in self._window_states]
        t0 = time.perf_counter()
        try:
            with rec.stage("batch_fn"):
                if self._batch_fn is not None:
                    info.result = self._batch_fn(union, info)
            info.processing_time = time.perf_counter() - t0
            # Serial sinks run BEFORE the commit: a raising sink aborts the
            # commit, so the batch (windower pushes included, via the
            # rollback above) replays — the at-least-once contract the module
            # docstring promises. Delivery lanes below keep their documented
            # <= queue-depth post-commit crash window.
            with rec.stage("sinks"):
                for sink in self._sinks:
                    sink(info)
        except BaseException:
            for w, st in rollback:
                w.restore_state(st)
            raise                      # failed batches never enter the trace
        self._commit(ranges, rec=rec)
        self._batch_index += 1
        self._history.append(info)
        if self._delivery is not None:
            # parallel lanes: enqueue only; check() surfaces a fail_pipeline
            # lane's verdict (possibly from an earlier batch) and aborts here
            with rec.stage("delivery_submit"):
                self._delivery.submit(info)
            self._delivery.check()
        span = rec.finish(self._progress.epoch)
        self._m_batches.inc()
        self._m_records.inc(info.num_records)
        self._m_batch_s.observe(span.total_s)
        return info

    def _commit(self, ranges: Sequence[OffsetRange],
                rec: Any = None) -> None:
        """Advance consumed offsets + attached window state as one epoch.

        Window stores persist first (each returns the ref for this epoch);
        the checkpoint's single ``os.replace`` then publishes ``(offsets,
        epoch, refs)`` together. A crash between the two leaves the previous
        checkpoint pointing at the previous refs — the store's ``restore``
        truncates the unpublished tail, and the interrupted batch replays
        with its window pushes: offsets and window state move
        both-or-neither, by construction.
        """
        epoch = self._progress.epoch + 1
        if self.checkpoint_path:
            with _stage(rec, "state_commit"):
                for name, windower in self._window_states:
                    store = getattr(windower, "store", None)
                    if store is not None:
                        self._progress.window_refs[name] = \
                            store.commit(epoch, windower.state())
        for r in ranges:
            self._progress.offsets[r.topic][r.partition] = r.until
        self._progress.epoch = epoch
        if self.checkpoint_path:
            with _stage(rec, "checkpoint"):
                self._progress.save(self.checkpoint_path)
        # Progress is also pushed broker-side so producers in other processes
        # (RemoteBroker -> BrokerServer) can bound their lag against it. In
        # group mode the commit carries (group, consumer, generation): a
        # fenced commit means the group rebalanced away from us mid-batch —
        # local progress stands (the new owner replays from its own start
        # offset; idempotent sinks absorb the overlap) and the member
        # resyncs at the top of the next batch.
        broker_commit = getattr(self.broker, "commit", None)
        if broker_commit is not None:
            with _stage(rec, "broker_commit"):
                member = self.group_member
                if member is None:
                    for r in ranges:
                        broker_commit(r.topic, r.partition, r.until)
                else:
                    from repro.data.groups import GroupError
                    try:
                        for r in ranges:
                            broker_commit(r.topic, r.partition, r.until,
                                          group=member.group,
                                          consumer=member.consumer_id,
                                          generation=member.generation)
                    except GroupError as e:
                        log.warning("group commit fenced (%s); resyncing", e)
                        member.request_resync()

    def checkpoint_now(self) -> None:
        """Checkpoint current progress + window state outside the batch loop
        — e.g. right after a terminal :meth:`Windower.flush`, so a restart
        does not re-fire the final partial window."""
        self._commit([])

    def run_batches(self, max_batches: int, wait_for_data: float = 0.0) -> list[BatchInfo]:
        """Inline scheduler: deterministic micro-batch loop for tests/benches."""
        out = []
        deadline = time.monotonic() + wait_for_data
        while len(out) < max_batches:
            info = self.run_one_batch()
            if info is None:
                if time.monotonic() > deadline:
                    break
                time.sleep(max(self.batch_interval / 10, 0.001))
                continue
            out.append(info)
        return out

    # -- background scheduler ---------------------------------------------
    def start(self) -> None:
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def _loop(self) -> None:
        while not self._stop.is_set():
            t0 = time.monotonic()
            self.run_one_batch()
            sleep = self.batch_interval - (time.monotonic() - t0)
            if sleep > 0:
                self._stop.wait(sleep)

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10)
            self._thread = None

    def serve_observability(self, address: tuple[str, int] = ("127.0.0.1", 0),
                            lag_policy: Any = None):
        """Start (or return) this context's HTTP observability endpoint:
        ``/metrics`` + ``/metrics.json`` over the registry the context's
        layers registered into, ``/traces`` over :attr:`traces`, and
        ``/health`` judging per-topic lag against ``lag_policy``'s
        ``scale_up_lag`` watermark (see ``repro/data/obs_server.py``).
        Stopped by :meth:`close`; port 0 binds an ephemeral port — read the
        bound address from the returned server's ``.url``."""
        if self._obs_server is not None:
            return self._obs_server
        from repro.data.obs_server import ObservabilityServer, lag_health
        health = lag_health(
            lambda: {t: self.lag(t) for t in self._topics}, lag_policy)
        self._obs_server = ObservabilityServer(
            registry=self._registry, traces=self.traces,
            health_fn=health, address=address).start()
        return self._obs_server

    def close(self, drain: bool = True) -> None:
        """Stop the scheduler and shut down the delivery lanes. With
        ``drain=True`` (default) every queued batch is written before the
        lanes exit — the no-lost-batches contract; ``drain=False`` discards
        queued work (fast teardown). Raises a pending
        :class:`~repro.data.delivery.DeliveryFailed`. Attached window state
        stores are closed (their last committed state stays on disk), and
        the observability endpoint (if served) is stopped."""
        self.stop()
        try:
            if self._delivery is not None:
                self._delivery.close(drain=drain)
        finally:
            if self.group_member is not None:
                self.group_member.leave()
                self.group_member = None
            for _, windower in self._window_states:
                store = getattr(windower, "store", None)
                if store is not None:
                    store.close()
            if self._obs_server is not None:
                self._obs_server.stop()
                self._obs_server = None

    # -- near-real-time accounting ------------------------------------------
    def realtime_report(self) -> dict[str, float]:
        """Is processing keeping up with the batch interval? (paper §III)."""
        if not self._history:
            return {"batches": 0}
        times = [b.processing_time for b in self._history]
        recs = sum(b.num_records for b in self._history)
        return {
            "batches": len(self._history),
            "records": recs,
            "mean_processing_s": sum(times) / len(times),
            "max_processing_s": max(times),
            "throughput_rec_per_s": recs / max(sum(times), 1e-9),
            "keeps_up": max(times) <= self.batch_interval,
        }
