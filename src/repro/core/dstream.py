"""Discretized streams: micro-batch scheduling over RDDs (paper §II, Fig. 7).

A DStream is a time-indexed sequence of RDDs. Every ``batch_interval`` the
streaming context drains each registered source into a batch RDD (per-topic
RDDs unioned, exactly the paper's ``run_batch``), applies the pipeline
function, and hands the result to sinks. Processing-time accounting exposes
the paper's near-real-time criterion: *processing time per micro-batch must
stay below the batch interval*, otherwise batches queue without bound.

The scheduler runs inline (``run_batches``) for deterministic tests and
benchmarks, or on a background thread (``start``/``stop``) for the streaming
examples. Checkpointing of stream progress (consumed offsets) makes a
restarted pipeline resume where it left off — offsets + replayable broker
give at-least-once processing, upgraded to exactly-once when the sink is
idempotent (both demonstrated in tests).

The ``broker`` handed to :class:`StreamingContext` may equally be a
:class:`~repro.data.transport.RemoteBroker` — same duck type, served from
another process by :class:`~repro.data.transport.BrokerServer` — which puts
the consumer on the opposite side of a socket from the detector, the paper's
Fig. 7 beamline/cluster split (see ``docs/transport.md``). After each
committed batch the context pushes its progress to the broker
(``broker.commit``) so *remote* producers' backpressure can measure lag
against what was actually processed, not just appended.
"""
from __future__ import annotations

import json
import os
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

from repro.core.broker import Broker, OffsetRange, create_rdd
from repro.core.rdd import RDD, Context
from repro.utils import get_logger

log = get_logger(__name__)


@dataclass
class BatchInfo:
    index: int
    ranges: list[OffsetRange]
    num_records: int
    scheduled_at: float
    processing_time: float = 0.0
    result: Any = None


@dataclass
class StreamProgress:
    """Consumed offsets per (topic, partition) — the restart checkpoint."""
    offsets: dict[str, list[int]] = field(default_factory=dict)

    def save(self, path: str) -> None:
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"offsets": self.offsets}, f)
        os.replace(tmp, path)

    @classmethod
    def load(cls, path: str) -> "StreamProgress":
        if not os.path.exists(path):
            return cls()
        with open(path) as f:
            return cls(offsets=json.load(f)["offsets"])


class StreamingContext:
    """Drives micro-batches: broker topics -> union RDD -> pipeline fn -> sinks."""

    def __init__(self, context: Context, broker: Broker,
                 batch_interval: float = 0.1,
                 max_records_per_partition: int | None = None,
                 checkpoint_path: str | None = None,
                 clock: Callable[[], float] | None = None) -> None:
        self.context = context
        self.broker = broker
        self.batch_interval = batch_interval
        self.max_records_per_partition = max_records_per_partition
        self.checkpoint_path = checkpoint_path
        # stream clock: stamps BatchInfo.scheduled_at and pumped-record
        # timestamps. Injectable so time-based windows are deterministic in
        # tests; scheduling waits always use real time.
        self._clock = clock or time.monotonic
        self._delivery = None          # lazy DeliveryRuntime (parallel sinks)
        self._topics: list[str] = []
        self._decoder: Callable[[Any], Any] | None = None
        self._batch_fn: Callable[[RDD, BatchInfo], Any] | None = None
        self._sinks: list[Callable[[BatchInfo], None]] = []
        # pull-model sources pumped inline before each micro-batch:
        # (source, topic, poll_batch)
        self._sources: list[tuple[Any, str, int]] = []
        self._progress = (StreamProgress.load(checkpoint_path)
                          if checkpoint_path else StreamProgress())
        self._history: list[BatchInfo] = []
        self._batch_index = 0
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    # -- wiring -------------------------------------------------------------
    def subscribe(self, topics: Sequence[str],
                  value_decoder: Callable[[Any], Any] | None = None) -> None:
        self._topics.extend(t for t in topics if t not in self._topics)
        if value_decoder is not None:
            self._decoder = value_decoder
        for t in self._topics:
            self._progress.offsets.setdefault(
                t, [0] * self.broker.num_partitions(t))

    def subscribe_source(self, source: Any, topic: str | None = None,
                         partitions: int = 1,
                         poll_batch: int | None = None) -> str:
        """Subscribe a :class:`repro.data.sources.Source` directly.

        Creates ``topic`` if missing (default: ``source-<i>``), subscribes to
        it, and pumps the source inline before each micro-batch — the
        pull-model twin of :class:`repro.data.ingest.IngestRunner`, fully
        deterministic for tests and single-process pipelines. If the source
        is replayable, it is ``seek``-ed to the topic's current end offset so
        a restart (offsets reloaded from checkpoint) does not re-produce
        records the broker already has.
        """
        topic = topic or f"source-{len(self._sources)}"
        if topic not in self.broker.topics():
            self.broker.create_topic(topic, partitions)
        if hasattr(source, "seek"):
            source.seek(sum(self.broker.end_offsets(topic)))
        self.subscribe([topic])
        if poll_batch is not None:
            n = poll_batch
        elif self.max_records_per_partition is not None:
            # the consumer cap is per partition; pump enough to fill them all
            n = self.max_records_per_partition * partitions
        else:
            n = 64
        self._sources.append((source, topic, n))
        return topic

    def foreach_batch(self, fn: Callable[[RDD, BatchInfo], Any]) -> None:
        self._batch_fn = fn

    def add_sink(self, fn: Callable[[BatchInfo], None],
                 policy: Any = None, name: str | None = None) -> None:
        """Register a batch sink. Without a ``policy`` the sink runs serially
        in the batch thread (the degenerate single-thread path). With a
        :class:`~repro.data.delivery.SinkPolicy`, the sink gets its own
        delivery lane — worker thread + bounded queue + failure isolation —
        on this context's :class:`~repro.data.delivery.DeliveryRuntime`."""
        if policy is None:
            self._sinks.append(fn)
        else:
            self.delivery.add_batch_sink(fn, policy, name=name)

    @property
    def delivery(self):
        """The context's sink-delivery runtime (created on first use); its
        dead-letter topics live on this context's broker."""
        if self._delivery is None:
            from repro.data.delivery import DeliveryRuntime
            self._delivery = DeliveryRuntime(broker=self.broker)
        return self._delivery

    # -- consumer-side accounting ------------------------------------------
    def committed(self, topic: str) -> int:
        """Total records committed (processed) for a topic."""
        return sum(self._progress.offsets.get(topic, []))

    def lag(self, topic: str) -> int:
        """Produced-but-unprocessed records — the backpressure signal
        :class:`repro.data.ingest.IngestRunner` bounds."""
        return sum(self.broker.end_offsets(topic)) - self.committed(topic)

    @property
    def sources_exhausted(self) -> bool:
        return all(s.exhausted for s, _, _ in self._sources)

    @property
    def history(self) -> list[BatchInfo]:
        return self._history

    # -- one micro-batch ------------------------------------------------------
    def _pending_ranges(self) -> list[OffsetRange]:
        ranges: list[OffsetRange] = []
        for topic in self._topics:
            ends = self.broker.end_offsets(topic)
            starts = self._progress.offsets[topic]
            for p, (start, end) in enumerate(zip(starts, ends)):
                if self.max_records_per_partition is not None:
                    end = min(end, start + self.max_records_per_partition)
                if end > start:
                    ranges.append(OffsetRange(topic, p, start, end))
        return ranges

    def _pump_sources(self) -> None:
        rr = {t: 0 for _, t, _ in self._sources}
        for source, topic, n in self._sources:
            if source.exhausted:
                continue
            parts = self.broker.num_partitions(topic)
            for key, value in source.poll(n):
                self.broker.produce(topic, value, key=key,
                                    partition=rr[topic] % parts,
                                    timestamp=self._clock())
                rr[topic] += 1

    def run_one_batch(self) -> BatchInfo | None:
        """Paper Fig. 8 ``run_batch``: per-topic RDDs, union, process."""
        if self._sources:
            self._pump_sources()
        ranges = self._pending_ranges()
        if not ranges:
            return None
        info = BatchInfo(index=self._batch_index, ranges=ranges,
                         num_records=sum(r.count() for r in ranges),
                         scheduled_at=self._clock())
        per_topic: dict[str, list[OffsetRange]] = {}
        for r in ranges:
            per_topic.setdefault(r.topic, []).append(r)
        topic_rdds = [create_rdd(self.context, self.broker, rs, self._decoder)
                      for rs in per_topic.values()]
        union = (topic_rdds[0].union(*topic_rdds[1:])
                 if len(topic_rdds) > 1 else topic_rdds[0])
        t0 = time.perf_counter()
        if self._batch_fn is not None:
            info.result = self._batch_fn(union, info)
        info.processing_time = time.perf_counter() - t0
        # Commit offsets only after the batch succeeded (at-least-once).
        # Progress is also pushed broker-side so producers in other processes
        # (RemoteBroker -> BrokerServer) can bound their lag against it.
        broker_commit = getattr(self.broker, "commit", None)
        for r in ranges:
            self._progress.offsets[r.topic][r.partition] = r.until
            if broker_commit is not None:
                broker_commit(r.topic, r.partition, r.until)
        if self.checkpoint_path:
            self._progress.save(self.checkpoint_path)
        self._batch_index += 1
        self._history.append(info)
        for sink in self._sinks:
            sink(info)
        if self._delivery is not None:
            # parallel lanes: enqueue only; check() surfaces a fail_pipeline
            # lane's verdict (possibly from an earlier batch) and aborts here
            self._delivery.submit(info)
            self._delivery.check()
        return info

    def run_batches(self, max_batches: int, wait_for_data: float = 0.0) -> list[BatchInfo]:
        """Inline scheduler: deterministic micro-batch loop for tests/benches."""
        out = []
        deadline = time.monotonic() + wait_for_data
        while len(out) < max_batches:
            info = self.run_one_batch()
            if info is None:
                if time.monotonic() > deadline:
                    break
                time.sleep(self.batch_interval / 10 or 0.001)
                continue
            out.append(info)
        return out

    # -- background scheduler ---------------------------------------------
    def start(self) -> None:
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def _loop(self) -> None:
        while not self._stop.is_set():
            t0 = time.monotonic()
            self.run_one_batch()
            sleep = self.batch_interval - (time.monotonic() - t0)
            if sleep > 0:
                self._stop.wait(sleep)

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10)
            self._thread = None

    def close(self, drain: bool = True) -> None:
        """Stop the scheduler and shut down the delivery lanes. With
        ``drain=True`` (default) every queued batch is written before the
        lanes exit — the no-lost-batches contract; ``drain=False`` discards
        queued work (fast teardown). Raises a pending
        :class:`~repro.data.delivery.DeliveryFailed`."""
        self.stop()
        if self._delivery is not None:
            self._delivery.close(drain=drain)

    # -- near-real-time accounting ------------------------------------------
    def realtime_report(self) -> dict[str, float]:
        """Is processing keeping up with the batch interval? (paper §III)."""
        if not self._history:
            return {"batches": 0}
        times = [b.processing_time for b in self._history]
        recs = sum(b.num_records for b in self._history)
        return {
            "batches": len(self._history),
            "records": recs,
            "mean_processing_s": sum(times) / len(times),
            "max_processing_s": max(times),
            "throughput_rec_per_s": recs / max(sum(times), 1e-9),
            "keeps_up": max(times) <= self.batch_interval,
        }
