"""Spark-MPI platform core: RDD middleware, broker, discretized streams,
PMI wire-up, the Spark-MPI collective bridge, fault tolerance, pipelines."""
from repro.core.bridge import MPIBridge, make_worker_mesh, rank_of
from repro.core.broker import (Broker, InMemoryPartitionLog, OffsetRange,
                               PartitionLog, Record, create_rdd)
from repro.core.dstream import BatchInfo, StreamingContext, StreamProgress
from repro.core.fault import (ElasticController, LagPolicy, Watchdog,
                              run_with_recovery)
from repro.core.pipeline import (NearRealTimePipeline, PipelineConfig,
                                 PipelineReport)
from repro.core.pmi import KeyValueSpace, PMIClient, PMIServer
from repro.core.rdd import (RDD, Context, FailureInjector, PartitionLostError,
                            TaskScheduler)

__all__ = [
    "MPIBridge", "make_worker_mesh", "rank_of",
    "Broker", "PartitionLog", "InMemoryPartitionLog", "OffsetRange",
    "Record", "create_rdd",
    "BatchInfo", "StreamingContext", "StreamProgress",
    "ElasticController", "LagPolicy", "Watchdog", "run_with_recovery",
    "NearRealTimePipeline", "PipelineConfig", "PipelineReport",
    "KeyValueSpace", "PMIClient", "PMIServer",
    "RDD", "Context", "FailureInjector", "PartitionLostError", "TaskScheduler",
]
