"""Fault tolerance + elasticity for the compute plane.

The data plane already self-heals (RDD lineage recompute + replayable broker
offsets). This module covers the *collective* side, where a single dead rank
stalls everyone — the classic MPI weakness the Spark-MPI paper inherits and
that a 1000-node deployment must solve:

* :class:`Watchdog` — heartbeat monitor over the PMI server; missed
  heartbeats bump the PMI generation.
* :class:`ElasticController` — owns the worker set; on a generation bump it
  re-forms the mesh over the survivors (or grown worker set), triggers a
  checkpoint restore resharded to the new topology, and resumes. This is
  checkpoint/restart elasticity: the only strategy that works for collective
  programs at scale (you cannot lineage-recompute half an allreduce).
* :func:`run_with_recovery` — drives a step function, catching injected
  worker failures between steps, re-meshing and restoring.
* :class:`LagPolicy` — closes the elasticity loop with the *data* plane:
  sustained ingest lag (or shed load under the drop/sample backpressure
  policies) scales the worker set up instead of shedding data, and a drained
  pipeline scales it back down — Kafka consumer-group rebalancing driven by
  consumer lag, with hysteresis so the controller never flaps.

In-process, "workers" are virtual devices; on a real pod the same control
flow fronts ``jax.distributed`` re-initialization. The contract tested in
``tests/test_fault.py``: training state after crash+elastic-restart equals a
run that never crashed (modulo the re-executed steps), for both shrink and
grow.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Any, Callable

import jax
import numpy as np

from repro.core.bridge import MPIBridge, make_worker_mesh
from repro.core.pmi import PMIServer
from repro.utils import get_logger

log = get_logger(__name__)


class WorkerFailure(RuntimeError):
    def __init__(self, worker_id: str) -> None:
        super().__init__(f"worker {worker_id} failed")
        self.worker_id = worker_id


class Watchdog:
    """Background heartbeat checker over the PMI server."""

    def __init__(self, pmi: PMIServer, interval: float = 0.5,
                 on_failure: Callable[[list[str]], None] | None = None) -> None:
        self.pmi = pmi
        self.interval = interval
        self.on_failure = on_failure
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    def start(self) -> None:
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def _loop(self) -> None:
        while not self._stop.wait(self.interval):
            failed = self.pmi.check_heartbeats()
            if failed and self.on_failure:
                self.on_failure(failed)

    def stop(self) -> None:
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=5)


@dataclass
class ElasticEvent:
    generation: int
    world: int
    reason: str
    step: int


class ElasticController:
    """Re-forms the device mesh across PMI generations.

    The controller slices the *physical* device list by the alive-worker
    count: generation g with W alive workers runs on devices[:W]. A real
    deployment maps worker→host; the resharding logic (checkpoint restored
    with a new mesh/sharding) is identical.
    """

    def __init__(self, num_workers: int | None = None,
                 initial_workers: int | None = None) -> None:
        devices = jax.devices()
        self.max_workers = num_workers or len(devices)
        if self.max_workers > len(devices):
            raise ValueError(
                f"{self.max_workers} workers requested, {len(devices)} devices")
        if initial_workers is not None and not (
                1 <= initial_workers <= self.max_workers):
            raise ValueError(
                f"initial_workers {initial_workers} outside "
                f"[1, {self.max_workers}]")
        self.pmi = PMIServer(world_size=self.max_workers)
        # start shrunk when asked: the elastic-scale-out demo begins on a
        # minimal worker set and lets LagPolicy grow it under load
        self.alive = list(range(initial_workers or self.max_workers))
        self.events: list[ElasticEvent] = []
        self._bridge: MPIBridge | None = None

    @property
    def world(self) -> int:
        return len(self.alive)

    def bridge(self) -> MPIBridge:
        if self._bridge is None:
            devs = [jax.devices()[i] for i in range(self.world)]
            mesh = make_worker_mesh(devs)
            self._bridge = MPIBridge(mesh=mesh)
        return self._bridge

    def fail_workers(self, n: int, step: int = -1) -> None:
        """Simulate n worker deaths (drops from the tail)."""
        if n >= self.world:
            raise ValueError("cannot fail every worker")
        self.alive = self.alive[: self.world - n]
        self._bridge = None
        self.events.append(ElasticEvent(len(self.events) + 1, self.world,
                                        f"failed {n} workers", step))
        log.info("elastic: shrank to %d workers", self.world)

    def add_workers(self, n: int, step: int = -1) -> None:
        """Scale out (workers re-join or capacity added)."""
        new = min(self.max_workers, self.world + n)
        self.alive = list(range(new))
        self._bridge = None
        self.events.append(ElasticEvent(len(self.events) + 1, self.world,
                                        f"grew to {new} workers", step))
        log.info("elastic: grew to %d workers", self.world)


@dataclass
class LagObservation:
    """One policy tick: what was seen and what was decided."""
    now: float
    lag: int
    shed: int          # records dropped/sampled-out since the previous tick
    delta: int         # worker delta: requested by observe(), applied by drive()


class LagPolicy:
    """Hysteresis controller from ingest lag to worker-set size.

    Consumes the backpressure signals :class:`~repro.data.ingest
    .IngestRunner` exposes (current per-topic lag via ``lag_snapshot()``,
    cumulative drop/sample counts via ``metrics``) and drives
    :meth:`ElasticController.add_workers` / :meth:`ElasticController
    .fail_workers`:

    * scale **up** by ``step`` after ``sustain`` consecutive observations
      with ``lag >= scale_up_lag`` *or* shed records (under the drop/sample
      policies overload shows up as shedding, not lag — both mean the
      consumer is too small);
    * scale **down** by ``step`` after ``sustain`` consecutive observations
      with ``lag <= scale_down_lag`` and nothing shed (the pipeline
      drained);
    * inside the band ``(scale_down_lag, scale_up_lag)`` the streak counters
      reset — a noisy signal bouncing around a watermark never flaps;
    * after any scale event, observations inside ``cooldown`` seconds are
      ignored entirely, so the re-formed mesh gets to prove itself before
      the next decision.

    The clock is injectable (``clock=``) and every ``observe``/``drive``
    accepts an explicit ``now=`` — decisions are a pure function of the fed
    signal, which is what makes the scripted tests deterministic.
    """

    def __init__(self, scale_up_lag: int, scale_down_lag: int, *,
                 sustain: int = 3, cooldown: float = 10.0, step: int = 1,
                 clock: Callable[[], float] = time.monotonic) -> None:
        if scale_down_lag >= scale_up_lag:
            raise ValueError(
                f"scale_down_lag {scale_down_lag} must be < scale_up_lag "
                f"{scale_up_lag} (the hysteresis band)")
        if sustain < 1 or step < 1:
            raise ValueError("sustain and step must be >= 1")
        self.scale_up_lag = scale_up_lag
        self.scale_down_lag = scale_down_lag
        self.sustain = sustain
        self.cooldown = cooldown
        self.step = step
        self._clock = clock
        self._above = 0                  # consecutive overloaded ticks
        self._below = 0                  # consecutive drained ticks
        self._last_event_at: float | None = None
        self._shed_seen = 0              # cumulative shed already accounted
        self.history: list[LagObservation] = []

    # -- pure decision ------------------------------------------------------
    def _decide(self, lag: int, shed: int, now: float) -> int:
        """Update streaks and return the delta the signal calls for — the
        event (streak reset + cooldown start) is committed separately, so a
        decision the controller cannot apply (already at max/min) does not
        burn a cooldown it never earned."""
        in_cooldown = (self._last_event_at is not None
                       and now - self._last_event_at < self.cooldown)
        if in_cooldown:
            return 0
        if lag >= self.scale_up_lag or shed > 0:
            self._above += 1
            self._below = 0
            if self._above >= self.sustain:
                return self.step
        elif lag <= self.scale_down_lag:
            self._below += 1
            self._above = 0
            if self._below >= self.sustain:
                return -self.step
        else:                            # inside the band: streaks reset
            self._above = self._below = 0
        return 0

    def _commit(self, now: float) -> None:
        self._above = self._below = 0
        self._last_event_at = now

    def observe(self, lag: int, shed: int = 0, now: float | None = None) -> int:
        """Feed one observation; returns the requested worker delta
        (``+step``, ``-step`` or ``0``)."""
        now = self._clock() if now is None else now
        delta = self._decide(lag, shed, now)
        if delta:
            self._commit(now)
        self.history.append(LagObservation(now, lag, shed, delta))
        return delta

    # -- wired decision -----------------------------------------------------
    def drive(self, controller: "ElasticController", runner: Any = None,
              lag: int | None = None, now: float | None = None) -> int:
        """One tick against live signals: read ``runner``'s lag + shed
        deltas (or take ``lag`` directly), decide, and apply the decision to
        ``controller``. Returns the worker delta actually applied."""
        shed = 0
        if runner is not None:
            if lag is None:
                lag = max(runner.lag_snapshot().values(), default=0)
            total_shed = sum(m.dropped + m.sampled_out
                             for m in runner.metrics)
            shed = max(0, total_shed - self._shed_seen)
            self._shed_seen = total_shed
        if lag is None:
            raise ValueError("drive() needs a runner or an explicit lag")
        now = self._clock() if now is None else now
        delta = self._decide(lag, shed, now)
        applied = 0
        if delta > 0:
            applied = min(delta, controller.max_workers - controller.world)
            if applied > 0:
                controller.add_workers(applied)
        elif delta < 0:
            # never fail the last worker
            applied = -min(-delta, controller.world - 1)
            if applied < 0:
                controller.fail_workers(-applied)
        # only an APPLIED change starts the cooldown: a decision clamped to
        # nothing (controller already at its bound) keeps the streak alive,
        # so the policy reacts immediately once headroom appears
        if applied:
            self._commit(now)
        self.history.append(LagObservation(now, lag, shed, applied))
        return applied


def run_with_recovery(
    controller: ElasticController,
    init_state: Callable[[MPIBridge], Any],
    step_fn: Callable[[MPIBridge, Any, int], Any],
    num_steps: int,
    save_fn: Callable[[Any, int], None],
    restore_fn: Callable[[MPIBridge], tuple[Any, int]],
    checkpoint_every: int = 5,
    failure_plan: dict[int, int] | None = None,
) -> tuple[Any, list[ElasticEvent]]:
    """Drive ``step_fn`` to ``num_steps`` with elastic checkpoint/restart.

    ``failure_plan[step] = n`` injects n worker failures *before* that step.
    On failure the state is restored from the last checkpoint on the new
    (smaller) mesh and the lost steps are re-executed — exactly the recovery
    a SLURM-level requeue would perform, compressed into one process.
    """
    failure_plan = dict(failure_plan or {})
    bridge = controller.bridge()
    state = init_state(bridge)
    step = 0
    save_fn(state, step)
    while step < num_steps:
        if step in failure_plan and failure_plan[step] > 0:
            n = failure_plan.pop(step)
            controller.fail_workers(n, step=step)
            bridge = controller.bridge()
            state, step = restore_fn(bridge)
            log.info("elastic: restored at step %d on world %d", step,
                     controller.world)
            continue
        state = step_fn(bridge, state, step)
        step += 1
        if step % checkpoint_every == 0 or step == num_steps:
            save_fn(state, step)
    return state, controller.events
