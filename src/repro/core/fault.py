"""Fault tolerance + elasticity for the compute plane.

The data plane already self-heals (RDD lineage recompute + replayable broker
offsets). This module covers the *collective* side, where a single dead rank
stalls everyone — the classic MPI weakness the Spark-MPI paper inherits and
that a 1000-node deployment must solve:

* :class:`Watchdog` — heartbeat monitor over the PMI server; missed
  heartbeats bump the PMI generation.
* :class:`ElasticController` — owns the worker set; on a generation bump it
  re-forms the mesh over the survivors (or grown worker set), triggers a
  checkpoint restore resharded to the new topology, and resumes. This is
  checkpoint/restart elasticity: the only strategy that works for collective
  programs at scale (you cannot lineage-recompute half an allreduce).
* :func:`run_with_recovery` — drives a step function, catching injected
  worker failures between steps, re-meshing and restoring.

In-process, "workers" are virtual devices; on a real pod the same control
flow fronts ``jax.distributed`` re-initialization. The contract tested in
``tests/test_fault.py``: training state after crash+elastic-restart equals a
run that never crashed (modulo the re-executed steps), for both shrink and
grow.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Any, Callable

import jax
import numpy as np

from repro.core.bridge import MPIBridge, make_worker_mesh
from repro.core.pmi import PMIServer
from repro.utils import get_logger

log = get_logger(__name__)


class WorkerFailure(RuntimeError):
    def __init__(self, worker_id: str) -> None:
        super().__init__(f"worker {worker_id} failed")
        self.worker_id = worker_id


class Watchdog:
    """Background heartbeat checker over the PMI server."""

    def __init__(self, pmi: PMIServer, interval: float = 0.5,
                 on_failure: Callable[[list[str]], None] | None = None) -> None:
        self.pmi = pmi
        self.interval = interval
        self.on_failure = on_failure
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    def start(self) -> None:
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def _loop(self) -> None:
        while not self._stop.wait(self.interval):
            failed = self.pmi.check_heartbeats()
            if failed and self.on_failure:
                self.on_failure(failed)

    def stop(self) -> None:
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=5)


@dataclass
class ElasticEvent:
    generation: int
    world: int
    reason: str
    step: int


class ElasticController:
    """Re-forms the device mesh across PMI generations.

    The controller slices the *physical* device list by the alive-worker
    count: generation g with W alive workers runs on devices[:W]. A real
    deployment maps worker→host; the resharding logic (checkpoint restored
    with a new mesh/sharding) is identical.
    """

    def __init__(self, num_workers: int | None = None) -> None:
        devices = jax.devices()
        self.max_workers = num_workers or len(devices)
        if self.max_workers > len(devices):
            raise ValueError(
                f"{self.max_workers} workers requested, {len(devices)} devices")
        self.pmi = PMIServer(world_size=self.max_workers)
        self.alive = list(range(self.max_workers))
        self.events: list[ElasticEvent] = []
        self._bridge: MPIBridge | None = None

    @property
    def world(self) -> int:
        return len(self.alive)

    def bridge(self) -> MPIBridge:
        if self._bridge is None:
            devs = [jax.devices()[i] for i in range(self.world)]
            mesh = make_worker_mesh(devs)
            self._bridge = MPIBridge(mesh=mesh)
        return self._bridge

    def fail_workers(self, n: int, step: int = -1) -> None:
        """Simulate n worker deaths (drops from the tail)."""
        if n >= self.world:
            raise ValueError("cannot fail every worker")
        self.alive = self.alive[: self.world - n]
        self._bridge = None
        self.events.append(ElasticEvent(len(self.events) + 1, self.world,
                                        f"failed {n} workers", step))
        log.info("elastic: shrank to %d workers", self.world)

    def add_workers(self, n: int, step: int = -1) -> None:
        """Scale out (workers re-join or capacity added)."""
        new = min(self.max_workers, self.world + n)
        self.alive = list(range(new))
        self._bridge = None
        self.events.append(ElasticEvent(len(self.events) + 1, self.world,
                                        f"grew to {new} workers", step))
        log.info("elastic: grew to %d workers", self.world)


def run_with_recovery(
    controller: ElasticController,
    init_state: Callable[[MPIBridge], Any],
    step_fn: Callable[[MPIBridge, Any, int], Any],
    num_steps: int,
    save_fn: Callable[[Any, int], None],
    restore_fn: Callable[[MPIBridge], tuple[Any, int]],
    checkpoint_every: int = 5,
    failure_plan: dict[int, int] | None = None,
) -> tuple[Any, list[ElasticEvent]]:
    """Drive ``step_fn`` to ``num_steps`` with elastic checkpoint/restart.

    ``failure_plan[step] = n`` injects n worker failures *before* that step.
    On failure the state is restored from the last checkpoint on the new
    (smaller) mesh and the lost steps are re-executed — exactly the recovery
    a SLURM-level requeue would perform, compressed into one process.
    """
    failure_plan = dict(failure_plan or {})
    bridge = controller.bridge()
    state = init_state(bridge)
    step = 0
    save_fn(state, step)
    while step < num_steps:
        if step in failure_plan and failure_plan[step] > 0:
            n = failure_plan.pop(step)
            controller.fail_workers(n, step=step)
            bridge = controller.bridge()
            state, step = restore_fn(bridge)
            log.info("elastic: restored at step %d on world %d", step,
                     controller.world)
            continue
        state = step_fn(bridge, state, step)
        step += 1
        if step % checkpoint_every == 0 or step == num_steps:
            save_fn(state, step)
    return state, controller.events
