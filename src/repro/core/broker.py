"""An in-process message broker with Kafka semantics (paper §II, Fig. 7-8).

The paper ingests detector streams through Kafka: topics split into
partitions, each partition an append-only totally-ordered log addressed by
offsets; no ordering across partitions; messages are (key, value) byte pairs.
``KafkaUtils.createRDD(offsets)`` — the paper's chosen "more flexible option"
— becomes :func:`create_rdd` here: an RDD whose partitions are explicit
``OffsetRange`` reads.

Storage is factored behind the :class:`PartitionLog` protocol
(``append``/``read``/``end_offset``, plus optional ``append_many`` for the
batched :meth:`Broker.produce_many` path): :class:`Broker` composes one log
per (topic, partition) and never looks inside. :class:`InMemoryPartitionLog`
is the single-host default; :class:`~repro.data.durable_log
.DurablePartitionLog` keeps the log on disk across broker restarts (Kafka's
segment files); the multi-host path serves the *whole broker* over a
socket instead (``repro.data.transport``: :class:`~repro.data.transport
.BrokerServer` in the consumer process, :class:`~repro.data.transport
.RemoteBroker` — same duck type as :class:`Broker` — in each producer), so
ingest and reconstruction can live on different hosts, the beamline-vs-
cluster split of the paper's Fig. 7 and its ZeroMQ future-work item
(see ``docs/transport.md``).

The broker also tracks *committed* (consumer-processed) offsets per topic —
:meth:`Broker.commit` / :meth:`Broker.committed` / :meth:`Broker.lag` — which
:class:`~repro.core.dstream.StreamingContext` pushes after every successful
micro-batch. In-process this is redundant with the context's own progress;
over the transport it is what lets a *remote* producer's backpressure see how
far the consumer actually got.

Producers append, consumers poll by (topic, partition, offset), and nothing
downstream (DStream scheduler, bridge, solvers) can tell in-process from
remote. The paper's own future-work item — "augment the Kafka Receiver with
interfaces to other data sources, such as ZeroMQ" — is the
:class:`repro.data.sources.Source` protocol: concrete sources (detector,
tilt-series, file replay, synthetic rate, topic re-ingest) are pumped into
broker topics by :class:`repro.data.ingest.IngestRunner` (threaded, with
backpressure) or inline via ``StreamingContext.subscribe_source``.
"""
from __future__ import annotations

import inspect
import threading
import zlib
from dataclasses import dataclass
from typing import Any, Callable, Protocol, Sequence, runtime_checkable

from repro.core.rdd import RDD, Context

# Committed offsets are namespaced per consumer group; pre-group callers
# (and the broker's own lag gauge) land on this default group.
DEFAULT_GROUP = ""

# Broker-side record of group/committed-offset advances, appended to this
# topic when Broker(commit_topic=...) is set. With a durable log factory the
# topic replicates to followers like any other, which is how a promoted
# follower rebuilds per-group committed offsets and the coordinator's
# generation floor (see repro.data.replication and Broker.restore_commits).
COMMIT_TOPIC = "__commits"


class BrokerFencedError(RuntimeError):
    """This broker was fenced by a higher-epoch promotion: a follower took
    over while it was away, and accepting writes now would fork the log. A
    zombie primary raises this on every produce/commit after a returning
    client fences it (``Broker.fence``)."""


class NotPrimaryError(RuntimeError):
    """This broker is a replica (read-only follower): writes must go to the
    primary until ``Broker.promote`` makes this one the primary."""


@dataclass(frozen=True)
class Record:
    key: bytes | None
    value: Any
    offset: int
    timestamp: float = 0.0


@dataclass(frozen=True)
class OffsetRange:
    """Paper Fig. 8: ``OffsetRange(topic, partition, fromOffset, untilOffset)``."""
    topic: str
    partition: int
    start: int
    until: int

    def count(self) -> int:
        return max(0, self.until - self.start)


@runtime_checkable
class PartitionLog(Protocol):
    """Append-only offset-addressed log: the storage unit behind one
    (topic, partition). ``append`` returns the record's offset; ``read``
    returns records in ``[start, min(until, end))``; offsets are dense from 0.
    Implementations must be thread-safe (one broker serves many producer and
    consumer threads)."""

    def append(self, key: bytes | None, value: Any, timestamp: float) -> int: ...

    def read(self, start: int, until: int) -> list[Record]: ...

    def end_offset(self) -> int: ...


class InMemoryPartitionLog:
    """Default :class:`PartitionLog`: a locked Python list (single host)."""

    def __init__(self) -> None:
        from repro.data.locktrace import new_lock
        self._records: list[Record] = []
        self._lock = new_lock("InMemoryPartitionLog._lock")

    def append(self, key: bytes | None, value: Any, timestamp: float) -> int:
        with self._lock:
            offset = len(self._records)
            self._records.append(Record(key, value, offset, timestamp))
            return offset

    def read(self, start: int, until: int) -> list[Record]:
        with self._lock:
            end = min(until, len(self._records))
            return self._records[start:end]

    def end_offset(self) -> int:
        with self._lock:
            return len(self._records)


# Pre-protocol name, kept for anything that reached into the underscore API.
_PartitionLog = InMemoryPartitionLog


def _route_partition(key: Any, partitions: int) -> int:
    """Key -> partition. Bytes keys route by CRC-32, which is *stable across
    processes and restarts* — Python's hash() is salted per process, and with
    a durable log a salted route would strand a key's replayed history on a
    different partition than its new records."""
    if key is None:
        return 0
    if isinstance(key, (bytes, bytearray, memoryview)):
        return zlib.crc32(bytes(key)) % partitions
    return hash(key) % partitions


def _factory_wants_location(factory: Callable) -> bool:
    """Does ``factory`` accept ``(topic=, partition=)``? Durable logs need to
    know *which* partition they store (their on-disk directory is derived
    from it); zero-arg factories like :class:`InMemoryPartitionLog` don't."""
    try:
        inspect.signature(factory).bind(topic="", partition=0)
        return True
    except (TypeError, ValueError):
        return False


class Broker:
    """Topics → partitions → append-only :class:`PartitionLog` s. Thread-safe.

    ``log_factory`` picks the storage implementation per partition
    (:class:`InMemoryPartitionLog` unless told otherwise). A factory may be
    zero-argument, or accept ``(topic, partition)`` keywords — the broker
    passes the location to factories that want it, which is how
    :class:`~repro.data.durable_log.DurableLogFactory` maps partitions onto
    stable on-disk directories that survive a broker restart.
    """

    def __init__(self, log_factory: Callable[..., PartitionLog] | None = None,
                 commit_topic: str | None = None, writable: bool = True,
                 epoch: int = 0) -> None:
        self._log_factory: Callable[..., PartitionLog] = (
            log_factory or InMemoryPartitionLog)
        self._locate_logs = _factory_wants_location(self._log_factory)
        self._topics: dict[str, list[PartitionLog]] = {}
        # topic -> payload codec name (repro.data.codec); absent = raw
        self._topic_codecs: dict[str, str] = {}
        # topic -> group -> per-partition committed offsets
        self._committed: dict[str, dict[str, list[int]]] = {}
        # lock seam (repro.data.locktrace): plain threading.Lock unless a
        # tracing registry is enabled — the chaos suites run with traced
        # locks and assert the acquisition graph stays acyclic
        from repro.data.locktrace import new_lock
        self._lock = new_lock("Broker._lock")
        self._coordinator: Any = None
        self._coord_lock = new_lock("Broker._coord_lock")
        # -- HA role state (repro.data.replication) ------------------------
        # epoch is the fencing token: each failover promotes at a strictly
        # higher epoch, and a broker fenced by a higher epoch refuses writes.
        self.epoch = epoch
        self.writable = writable           # False = replica until promoted
        self._fenced_by: int | None = None
        self.commit_topic = commit_topic
        self._commit_replay = False        # True while restore_commits runs
        # replica_id -> {topic: [per-partition replicated high-watermarks]}
        self._replica_hwms: dict[str, dict[str, list[int]]] = {}
        # runs after a successful promote (e.g. ReplicaFollower persisting
        # the new epoch) — called outside the lock, with the broker
        self.on_promote: Callable[["Broker"], None] | None = None
        # constructor-time import: repro.data.metrics pulls in the data
        # package, which imports this module — at construction the cycle is
        # long resolved. Instruments are cached per topic (one dict lookup
        # per produce/read, no registry lookup on the hot path).
        from repro.data.metrics import get_registry
        self._registry = get_registry()
        self._m_produce: dict[str, Any] = {}
        self._m_read: dict[str, Any] = {}

    def _register_topic_metrics(self, topic: str,
                                logs: list[PartitionLog]) -> None:
        self._m_produce[topic] = self._registry.counter(
            "broker_produce_records_total",
            "records appended to broker topics", labels={"topic": topic})
        self._m_read[topic] = self._registry.counter(
            "broker_read_records_total",
            "records read out of broker topics", labels={"topic": topic})
        self._registry.gauge(
            "broker_log_records", "per-topic log size (sum of end offsets)",
            labels={"topic": topic},
            callback=lambda: sum(log.end_offset() for log in logs))
        self._registry.gauge(
            "broker_lag", "produced-but-uncommitted records per topic",
            labels={"topic": topic}, callback=lambda: self.lag(topic))

    def _new_log(self, topic: str, partition: int) -> PartitionLog:
        if self._locate_logs:
            return self._log_factory(topic=topic, partition=partition)
        return self._log_factory()

    def create_topic(self, topic: str, partitions: int = 1,
                     codec: str | None = None) -> None:
        if codec is not None:
            # validate the name now (constructor-time import, see __init__):
            # a typo'd codec must fail topic creation, not the first decode
            from repro.data.codec import get_codec
            codec = get_codec(codec).name
        with self._lock:
            if topic in self._topics:
                raise ValueError(f"topic {topic!r} exists")
            logs = [self._new_log(topic, p) for p in range(partitions)]
            self._topics[topic] = logs
            self._committed[topic] = {DEFAULT_GROUP: [0] * partitions}
            if codec is not None:
                self._topic_codecs[topic] = codec
        self._register_topic_metrics(topic, logs)

    def topic_codec(self, topic: str) -> str | None:
        """The payload codec this topic was created with (``None`` = raw).
        Advisory: producers (``IngestRunner``) encode values at the
        source→broker boundary, consumers decode at subscribe — the broker
        itself never looks inside a value, so the durable log and the
        replication path carry codec'd payloads verbatim."""
        self._topic(topic)             # raise KeyError for unknown topics
        with self._lock:
            return self._topic_codecs.get(topic)

    def topics(self) -> list[str]:
        with self._lock:
            return sorted(self._topics)

    def num_partitions(self, topic: str) -> int:
        return len(self._topic(topic))

    def _topic(self, topic: str) -> list[PartitionLog]:
        with self._lock:
            if topic not in self._topics:
                raise KeyError(f"unknown topic {topic!r}")
            return self._topics[topic]

    # -- HA role ----------------------------------------------------------
    def _require_writable(self) -> None:
        if self._fenced_by is not None:
            raise BrokerFencedError(
                f"broker fenced by epoch {self._fenced_by} (own epoch "
                f"{self.epoch}): a promoted follower owns the log now")
        if not self.writable:
            raise NotPrimaryError(
                f"broker is a replica at epoch {self.epoch}; "
                "produce/commit must go to the primary")

    def broker_epoch(self) -> dict:
        """The fencing state clients probe before trusting a broker."""
        return {"epoch": self.epoch,
                "writable": self.writable and self._fenced_by is None}

    def fence(self, epoch: int) -> dict:
        """Fence this broker out of the write path: a failover promoted a
        follower at ``epoch``, so any write accepted here would fork the
        log. Requires a *strictly higher* epoch — a stale fencing attempt
        (epoch <= ours) is itself rejected."""
        if epoch <= self.epoch:
            raise ValueError(
                f"fence epoch {epoch} is not newer than broker epoch "
                f"{self.epoch}")
        with self._lock:
            if self._fenced_by is None or epoch > self._fenced_by:
                self._fenced_by = epoch
        return self.broker_epoch()

    def promote(self, epoch: int) -> dict:
        """Promote this (replica) broker to primary at ``epoch``.

        Idempotent across racing clients: the first caller at a new epoch
        performs the promotion (un-fence + rebuild group/committed offsets
        from the replicated commit topic); later callers at the same or an
        older epoch get the current state back with ``promoted=False``. A
        promotion epoch must be strictly higher than the epoch this broker
        last *followed or served* at, so a zombie primary can never promote
        itself back over the new one."""
        with self._lock:
            if self.writable and self._fenced_by is None \
                    and self.epoch >= epoch:
                return {"epoch": self.epoch, "promoted": False,
                        "writable": True}
            # the fence epoch is a floor too: a broker fenced at N knows a
            # promotion at N happened elsewhere, so re-entering at <= N
            # would put two primaries at the same epoch
            floor = max(self.epoch, self._fenced_by or 0)
            if epoch <= floor:
                raise ValueError(
                    f"promote epoch {epoch} is not newer than broker epoch "
                    f"{floor}")
            self.epoch = epoch
            self.writable = True
            self._fenced_by = None
        self.restore_commits()
        if self.on_promote is not None:
            self.on_promote(self)
        return {"epoch": epoch, "promoted": True, "writable": True}

    def fetch_frames(self, topic: str, partition: int, start: int,
                     max_bytes: int = 4 * 1024 * 1024
                     ) -> tuple[bytes, list[int], int, int]:
        """Replication pull: raw CRC frames for ``[start, end)`` of one
        partition as one contiguous blob plus per-frame sizes, capped at
        ``max_bytes`` per call. Returns ``(blob, lengths, next_offset,
        end_offset)``. Durable logs serve their segment bytes verbatim
        (:meth:`~repro.data.durable_log.DurablePartitionLog.read_frames`);
        in-memory logs frame records on the fly, so every backend is
        replicable. The follower CRC-verifies every frame before it appends
        — the primary ships bytes, it does not re-check them."""
        plog = self._topic(topic)[partition]
        end = plog.end_offset()
        reader = getattr(plog, "read_frames", None)
        if reader is not None:
            blob, lengths, nxt = reader(start, end, max_bytes=max_bytes)
            return blob, lengths, nxt, end
        from repro.data.durable_log import frame_bytes
        from repro.data.transport import encode_message
        frames, total, nxt = [], 0, max(start, 0)
        for rec in plog.read(start, end):
            frame = frame_bytes(b"".join(
                encode_message((rec.key, rec.value, rec.timestamp))))
            if frames and total + len(frame) > max_bytes:
                break
            frames.append(frame)
            total += len(frame)
            nxt += 1
        return b"".join(frames), [len(f) for f in frames], nxt, end

    def replica_sync(self, replica_id: str, cursors: dict,
                     max_bytes: int = 4 * 1024 * 1024) -> dict:
        """One whole replication round in one round trip — a chatty
        follower polling ``topics`` + per-partition :meth:`fetch_frames` +
        :meth:`replica_hwm` every few milliseconds measurably taxes the
        produce hot path it shares the broker with (see
        ``bench_ingest:replication_overhead``); this op folds the round
        into a single request. ``cursors`` is the follower's ``{topic:
        [next_offset per partition]}`` — it doubles as the high-watermark
        report (what the follower has IS what is safely replicated).
        Returns ``{"topics": {topic: n_partitions}, "parts": {topic:
        [(blob, lengths, next_offset, end_offset), ...]}}``; topics the
        follower has no cursor for yet are served from offset 0 so it can
        mirror and append in the same round. ``max_bytes`` caps the total
        payload across all partitions — the remainder comes next round."""
        self.replica_hwm(replica_id, cursors)
        topics: dict[str, int] = {}
        parts: dict[str, list] = {}
        remaining = int(max_bytes)
        for topic in self.topics():
            plogs = self._topic(topic)
            topics[topic] = len(plogs)
            starts = cursors.get(topic) or []
            entries = []
            for p, plog in enumerate(plogs):
                start = int(starts[p]) if p < len(starts) else 0
                end = plog.end_offset()
                if remaining > 0 and start < end:
                    blob, lengths, nxt, end = self.fetch_frames(
                        topic, p, start, max_bytes=remaining)
                    remaining -= len(blob)
                else:
                    blob, lengths, nxt = b"", [], start
                entries.append((blob, lengths, nxt, end))
            parts[topic] = entries
        return {"topics": topics, "parts": parts}

    def replica_hwm(self, replica_id: str | None = None,
                    hwms: dict | None = None) -> dict:
        """Follower-reported replicated high-watermarks.

        A follower calls this with its ``replica_id`` and a ``{topic:
        [per-partition next offsets]}`` map after each pull round; anyone
        (monitoring, a :class:`~repro.data.replication.FailoverBroker`
        confirming its resend window) calls it bare to read the full
        ``{replica_id: {topic: [hwm]}}`` map back."""
        with self._lock:
            if replica_id is not None and hwms is not None:
                self._replica_hwms[str(replica_id)] = {
                    str(t): [int(o) for o in offs]
                    for t, offs in hwms.items()}
            return {r: {t: list(offs) for t, offs in m.items()}
                    for r, m in self._replica_hwms.items()}

    def _record_group_event(self, event: tuple) -> None:
        """Append one commit/generation event to the durable commit topic
        (when configured) so group progress survives a failover. Never on
        the replay path, and never for the commit topic itself."""
        if self.commit_topic is None or self._commit_replay:
            return
        with self._lock:
            missing = self.commit_topic not in self._topics
        if missing:
            self.create_topic(self.commit_topic, 1)
        logs = self._topic(self.commit_topic)
        logs[0].append(None, event, 0.0)
        self._m_produce[self.commit_topic].inc()

    def restore_commits(self) -> int:
        """Replay the commit topic into per-group committed offsets and the
        coordinator's generation floor — the restart/promotion path (data
        topics themselves are restored by ``DurableLogFactory.restore``).
        Offsets are clamped to the local log end: replication of the data
        may trail replication of the commit record, and a committed offset
        pointing past the log would wedge every reader. Returns the number
        of events applied."""
        if self.commit_topic is None:
            return 0
        with self._lock:
            if self.commit_topic not in self._topics:
                return 0
        plog = self._topic(self.commit_topic)[0]
        applied = 0
        self._commit_replay = True
        try:
            for rec in plog.read(0, plog.end_offset()):
                event = tuple(rec.value)
                if event[0] == "commit":
                    _, group, topic, partition, offset = event
                    try:
                        logs = self._topic(topic)
                    except KeyError:
                        continue           # data topic not replicated (yet)
                    if not 0 <= int(partition) < len(logs):
                        continue
                    offset = min(int(offset),
                                 logs[int(partition)].end_offset())
                    with self._lock:
                        done = self._committed[topic].setdefault(
                            str(group), [0] * len(logs))
                        if len(done) < len(logs):
                            done.extend([0] * (len(logs) - len(done)))
                        done[int(partition)] = max(done[int(partition)],
                                                   offset)
                elif event[0] == "gen":
                    _, group, generation = event
                    self.coordinator.seed_generation(str(group),
                                                     int(generation))
                applied += 1
        finally:
            self._commit_replay = False
        return applied

    # -- producer ---------------------------------------------------------
    def produce(self, topic: str, value: Any, key: bytes | None = None,
                partition: int | None = None, timestamp: float = 0.0) -> int:
        self._require_writable()
        logs = self._topic(topic)
        if partition is None:
            partition = _route_partition(key, len(logs))
        offset = logs[partition].append(key, value, timestamp)
        self._m_produce[topic].inc()
        return offset

    def produce_many(self, topic: str, pairs: Sequence[tuple],
                     partition: int | None = None, timestamp: float = 0.0
                     ) -> list[int]:
        """Append a batch of ``(key, value)`` pairs; returns their offsets in
        input order.

        Argument validation is all-or-nothing: an unknown topic, an
        out-of-range ``partition`` or a malformed pair raises *before any
        record is appended*. Once appends start, a storage-layer failure can
        leave a committed prefix — retrying the whole batch (what
        ``RemoteBroker`` does on a lost ack) duplicates records, which the
        idempotent-by-key sinks absorb: delivery is at-least-once per batch.
        With an explicit ``partition``, storage backends exposing
        ``append_many`` (the durable log) get the whole batch in one call —
        one disk write + fsync instead of one per record.
        """
        self._require_writable()
        logs = self._topic(topic)
        if partition is not None and not 0 <= partition < len(logs):
            raise ValueError(
                f"partition {partition} out of range for topic {topic!r} "
                f"({len(logs)} partitions)")
        batch = []
        for pair in pairs:
            try:
                key, value = pair
            except (TypeError, ValueError):
                raise ValueError(
                    f"produce_many pair must be (key, value), got {pair!r}")
            if partition is None:
                try:
                    p = _route_partition(key, len(logs))
                except TypeError:          # unhashable non-bytes key: fail
                    raise ValueError(      # the batch BEFORE any append
                        f"produce_many key {key!r} is not routable "
                        "(unhashable); pass an explicit partition")
            else:
                p = partition
            batch.append((key, value, p))
        if partition is not None:
            plog = logs[partition]
            append_many = getattr(plog, "append_many", None)
            if append_many is not None:
                offsets = list(append_many([(k, v) for k, v, _ in batch],
                                           timestamp))
                self._m_produce[topic].inc(len(offsets))
                return offsets
        offsets = [logs[p].append(k, v, timestamp) for k, v, p in batch]
        self._m_produce[topic].inc(len(offsets))
        return offsets

    # -- consumer ---------------------------------------------------------
    def read(self, rng: OffsetRange) -> list[Record]:
        records = self._topic(rng.topic)[rng.partition].read(rng.start,
                                                             rng.until)
        if records:
            self._m_read[rng.topic].inc(len(records))
        return records

    def end_offset(self, topic: str, partition: int = 0) -> int:
        return self._topic(topic)[partition].end_offset()

    def end_offsets(self, topic: str) -> list[int]:
        return [log.end_offset() for log in self._topic(topic)]

    # -- consumer progress -------------------------------------------------
    # Committed offsets live broker-side so producers on *other* hosts can
    # bound their lag against what the consumer has actually processed
    # (IngestRunner backpressure over repro.data.transport). Commits are
    # monotonic: replays never move progress backwards. Each consumer group
    # tracks its own offsets; groupless callers share ``DEFAULT_GROUP``.
    def commit(self, topic: str, partition: int, offset: int,
               group: str = DEFAULT_GROUP, consumer: str | None = None,
               generation: int | None = None) -> None:
        # Network-facing via the transport: a bad partition (negative Python
        # indexing!) or an offset past the log end must not poison the lag
        # signal backpressure runs on.
        self._require_writable()
        logs = self._topic(topic)               # raise on unknown topic
        if not 0 <= partition < len(logs):
            raise ValueError(
                f"partition {partition} out of range for topic {topic!r} "
                f"({len(logs)} partitions)")
        if not 0 <= offset <= logs[partition].end_offset():
            raise ValueError(
                f"commit offset {offset} outside "
                f"[0, {logs[partition].end_offset()}] for "
                f"{topic!r}[{partition}]")
        if generation is not None:
            # generation fencing: only a live member of `group` at the
            # current generation that owns the partition may advance it —
            # a zombie consumer's commit raises StaleGenerationError instead
            # of silently corrupting the group's lag signal. Checked before
            # taking self._lock (coordinator -> broker lock order).
            self.coordinator.check_commit(group, consumer, generation,
                                          topic=topic, partition=partition)
        with self._lock:
            done = self._committed[topic].setdefault(group, [0] * len(logs))
            if len(done) < len(logs):
                done.extend([0] * (len(logs) - len(done)))
            advanced = offset > done[partition]
            done[partition] = max(done[partition], offset)
        if advanced and topic != self.commit_topic:
            # durable (and hence replicated) record of the advance: one
            # append per committing micro-batch, the price of group progress
            # surviving a broker failover (see restore_commits)
            self._record_group_event(("commit", group, topic, partition,
                                      offset))

    def committed(self, topic: str, group: str = DEFAULT_GROUP) -> list[int]:
        logs = self._topic(topic)
        with self._lock:
            done = self._committed[topic].get(group)
            if done is None:
                return [0] * len(logs)
            return done + [0] * (len(logs) - len(done))

    def commit_groups(self, topic: str) -> list[str]:
        """Groups with committed offsets on ``topic`` (default group first)."""
        self._topic(topic)
        with self._lock:
            return sorted(self._committed[topic])

    def lag(self, topic: str, group: str = DEFAULT_GROUP) -> int:
        """Produced-but-uncommitted records — the backpressure signal,
        measured against ``group``'s committed offsets."""
        return sum(self.end_offsets(topic)) - sum(self.committed(topic,
                                                                 group))

    # -- consumer groups ---------------------------------------------------
    @property
    def coordinator(self):
        """The broker-hosted :class:`~repro.data.groups.GroupCoordinator`
        (created on first use — lazy import, the data package imports this
        module). Tests inject a fake-clock coordinator by assigning
        ``broker._coordinator`` before the first group op."""
        with self._coord_lock:
            if self._coordinator is None:
                from repro.data.groups import GroupCoordinator
                self._coordinator = GroupCoordinator(self)
            return self._coordinator

    def join_group(self, group: str, consumer: str, topics: Sequence[str],
                   session_timeout: float = 5.0) -> dict:
        # group membership is primary-side state: joining a fenced zombie or
        # an unpromoted replica would split the group across brokers
        self._require_writable()
        return self.coordinator.join_group(group, consumer, topics,
                                           session_timeout=session_timeout)

    def heartbeat(self, group: str, consumer: str, generation: int) -> dict:
        return self.coordinator.heartbeat(group, consumer, generation)

    def sync_group(self, group: str, consumer: str,
                   generation: int) -> dict:
        return self.coordinator.sync_group(group, consumer, generation)

    def leave_group(self, group: str, consumer: str) -> None:
        return self.coordinator.leave_group(group, consumer)

    def describe_group(self, group: str) -> dict:
        return self.coordinator.describe(group)


def create_rdd(context: Context, broker: Broker,
               offset_ranges: Sequence[OffsetRange],
               value_decoder: Callable[[Any], Any] | None = None) -> RDD:
    """``KafkaUtils.createRDD`` — one RDD partition per OffsetRange.

    The read happens lazily inside the partition task, so a lost partition is
    recomputed by re-reading the broker at the same offsets (exactly Kafka's
    replayability property that makes the lineage story work end-to-end).
    """
    ranges = list(offset_ranges)

    def compute(idx: int) -> list[Any]:
        records = broker.read(ranges[idx])
        values = [r.value for r in records]
        if value_decoder is not None:
            values = [value_decoder(v) for v in values]
        return values

    rdd = RDD(context, len(ranges), [], compute, name="kafkaRDD")
    return rdd
