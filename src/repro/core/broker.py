"""An in-process message broker with Kafka semantics (paper §II, Fig. 7-8).

The paper ingests detector streams through Kafka: topics split into
partitions, each partition an append-only totally-ordered log addressed by
offsets; no ordering across partitions; messages are (key, value) byte pairs.
``KafkaUtils.createRDD(offsets)`` — the paper's chosen "more flexible option"
— becomes :func:`create_rdd` here: an RDD whose partitions are explicit
``OffsetRange`` reads.

The broker is in-process because this container is one host, but the API is
transport-shaped: producers append, consumers poll by (topic, partition,
offset), and nothing downstream (DStream scheduler, bridge, solvers) can tell
the difference. The paper's own future-work item — "augment the Kafka
Receiver with interfaces to other data sources, such as ZeroMQ" — is the
:class:`repro.data.sources.Source` protocol: concrete sources (detector,
tilt-series, file replay, synthetic rate, topic re-ingest) are pumped into
broker topics by :class:`repro.data.ingest.IngestRunner` (threaded, with
backpressure) or inline via ``StreamingContext.subscribe_source``.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Any, Callable, Sequence

from repro.core.rdd import RDD, Context


@dataclass(frozen=True)
class Record:
    key: bytes | None
    value: Any
    offset: int
    timestamp: float = 0.0


@dataclass(frozen=True)
class OffsetRange:
    """Paper Fig. 8: ``OffsetRange(topic, partition, fromOffset, untilOffset)``."""
    topic: str
    partition: int
    start: int
    until: int

    def count(self) -> int:
        return max(0, self.until - self.start)


class _PartitionLog:
    def __init__(self) -> None:
        self._records: list[Record] = []
        self._lock = threading.Lock()

    def append(self, key: bytes | None, value: Any, timestamp: float) -> int:
        with self._lock:
            offset = len(self._records)
            self._records.append(Record(key, value, offset, timestamp))
            return offset

    def read(self, start: int, until: int) -> list[Record]:
        with self._lock:
            end = min(until, len(self._records))
            return self._records[start:end]

    def end_offset(self) -> int:
        with self._lock:
            return len(self._records)


class Broker:
    """Topics → partitions → append-only logs. Thread-safe."""

    def __init__(self) -> None:
        self._topics: dict[str, list[_PartitionLog]] = {}
        self._lock = threading.Lock()

    def create_topic(self, topic: str, partitions: int = 1) -> None:
        with self._lock:
            if topic in self._topics:
                raise ValueError(f"topic {topic!r} exists")
            self._topics[topic] = [_PartitionLog() for _ in range(partitions)]

    def topics(self) -> list[str]:
        with self._lock:
            return sorted(self._topics)

    def num_partitions(self, topic: str) -> int:
        return len(self._topic(topic))

    def _topic(self, topic: str) -> list[_PartitionLog]:
        with self._lock:
            if topic not in self._topics:
                raise KeyError(f"unknown topic {topic!r}")
            return self._topics[topic]

    # -- producer ---------------------------------------------------------
    def produce(self, topic: str, value: Any, key: bytes | None = None,
                partition: int | None = None, timestamp: float = 0.0) -> int:
        logs = self._topic(topic)
        if partition is None:
            partition = (hash(key) if key is not None else 0) % len(logs)
        return logs[partition].append(key, value, timestamp)

    # -- consumer ---------------------------------------------------------
    def read(self, rng: OffsetRange) -> list[Record]:
        return self._topic(rng.topic)[rng.partition].read(rng.start, rng.until)

    def end_offset(self, topic: str, partition: int = 0) -> int:
        return self._topic(topic)[partition].end_offset()

    def end_offsets(self, topic: str) -> list[int]:
        return [log.end_offset() for log in self._topic(topic)]


def create_rdd(context: Context, broker: Broker,
               offset_ranges: Sequence[OffsetRange],
               value_decoder: Callable[[Any], Any] | None = None) -> RDD:
    """``KafkaUtils.createRDD`` — one RDD partition per OffsetRange.

    The read happens lazily inside the partition task, so a lost partition is
    recomputed by re-reading the broker at the same offsets (exactly Kafka's
    replayability property that makes the lineage story work end-to-end).
    """
    ranges = list(offset_ranges)

    def compute(idx: int) -> list[Any]:
        records = broker.read(ranges[idx])
        values = [r.value for r in records]
        if value_decoder is not None:
            values = [value_decoder(v) for v in values]
        return values

    rdd = RDD(context, len(ranges), [], compute, name="kafkaRDD")
    return rdd
