"""An in-process message broker with Kafka semantics (paper §II, Fig. 7-8).

The paper ingests detector streams through Kafka: topics split into
partitions, each partition an append-only totally-ordered log addressed by
offsets; no ordering across partitions; messages are (key, value) byte pairs.
``KafkaUtils.createRDD(offsets)`` — the paper's chosen "more flexible option"
— becomes :func:`create_rdd` here: an RDD whose partitions are explicit
``OffsetRange`` reads.

Storage is factored behind the :class:`PartitionLog` protocol
(``append``/``read``/``end_offset``, plus optional ``append_many`` for the
batched :meth:`Broker.produce_many` path): :class:`Broker` composes one log
per (topic, partition) and never looks inside. :class:`InMemoryPartitionLog`
is the single-host default; :class:`~repro.data.durable_log
.DurablePartitionLog` keeps the log on disk across broker restarts (Kafka's
segment files); the multi-host path serves the *whole broker* over a
socket instead (``repro.data.transport``: :class:`~repro.data.transport
.BrokerServer` in the consumer process, :class:`~repro.data.transport
.RemoteBroker` — same duck type as :class:`Broker` — in each producer), so
ingest and reconstruction can live on different hosts, the beamline-vs-
cluster split of the paper's Fig. 7 and its ZeroMQ future-work item
(see ``docs/transport.md``).

The broker also tracks *committed* (consumer-processed) offsets per topic —
:meth:`Broker.commit` / :meth:`Broker.committed` / :meth:`Broker.lag` — which
:class:`~repro.core.dstream.StreamingContext` pushes after every successful
micro-batch. In-process this is redundant with the context's own progress;
over the transport it is what lets a *remote* producer's backpressure see how
far the consumer actually got.

Producers append, consumers poll by (topic, partition, offset), and nothing
downstream (DStream scheduler, bridge, solvers) can tell in-process from
remote. The paper's own future-work item — "augment the Kafka Receiver with
interfaces to other data sources, such as ZeroMQ" — is the
:class:`repro.data.sources.Source` protocol: concrete sources (detector,
tilt-series, file replay, synthetic rate, topic re-ingest) are pumped into
broker topics by :class:`repro.data.ingest.IngestRunner` (threaded, with
backpressure) or inline via ``StreamingContext.subscribe_source``.
"""
from __future__ import annotations

import inspect
import threading
import zlib
from dataclasses import dataclass
from typing import Any, Callable, Protocol, Sequence, runtime_checkable

from repro.core.rdd import RDD, Context

# Committed offsets are namespaced per consumer group; pre-group callers
# (and the broker's own lag gauge) land on this default group.
DEFAULT_GROUP = ""


@dataclass(frozen=True)
class Record:
    key: bytes | None
    value: Any
    offset: int
    timestamp: float = 0.0


@dataclass(frozen=True)
class OffsetRange:
    """Paper Fig. 8: ``OffsetRange(topic, partition, fromOffset, untilOffset)``."""
    topic: str
    partition: int
    start: int
    until: int

    def count(self) -> int:
        return max(0, self.until - self.start)


@runtime_checkable
class PartitionLog(Protocol):
    """Append-only offset-addressed log: the storage unit behind one
    (topic, partition). ``append`` returns the record's offset; ``read``
    returns records in ``[start, min(until, end))``; offsets are dense from 0.
    Implementations must be thread-safe (one broker serves many producer and
    consumer threads)."""

    def append(self, key: bytes | None, value: Any, timestamp: float) -> int: ...

    def read(self, start: int, until: int) -> list[Record]: ...

    def end_offset(self) -> int: ...


class InMemoryPartitionLog:
    """Default :class:`PartitionLog`: a locked Python list (single host)."""

    def __init__(self) -> None:
        self._records: list[Record] = []
        self._lock = threading.Lock()

    def append(self, key: bytes | None, value: Any, timestamp: float) -> int:
        with self._lock:
            offset = len(self._records)
            self._records.append(Record(key, value, offset, timestamp))
            return offset

    def read(self, start: int, until: int) -> list[Record]:
        with self._lock:
            end = min(until, len(self._records))
            return self._records[start:end]

    def end_offset(self) -> int:
        with self._lock:
            return len(self._records)


# Pre-protocol name, kept for anything that reached into the underscore API.
_PartitionLog = InMemoryPartitionLog


def _route_partition(key: Any, partitions: int) -> int:
    """Key -> partition. Bytes keys route by CRC-32, which is *stable across
    processes and restarts* — Python's hash() is salted per process, and with
    a durable log a salted route would strand a key's replayed history on a
    different partition than its new records."""
    if key is None:
        return 0
    if isinstance(key, (bytes, bytearray, memoryview)):
        return zlib.crc32(bytes(key)) % partitions
    return hash(key) % partitions


def _factory_wants_location(factory: Callable) -> bool:
    """Does ``factory`` accept ``(topic=, partition=)``? Durable logs need to
    know *which* partition they store (their on-disk directory is derived
    from it); zero-arg factories like :class:`InMemoryPartitionLog` don't."""
    try:
        inspect.signature(factory).bind(topic="", partition=0)
        return True
    except (TypeError, ValueError):
        return False


class Broker:
    """Topics → partitions → append-only :class:`PartitionLog` s. Thread-safe.

    ``log_factory`` picks the storage implementation per partition
    (:class:`InMemoryPartitionLog` unless told otherwise). A factory may be
    zero-argument, or accept ``(topic, partition)`` keywords — the broker
    passes the location to factories that want it, which is how
    :class:`~repro.data.durable_log.DurableLogFactory` maps partitions onto
    stable on-disk directories that survive a broker restart.
    """

    def __init__(self, log_factory: Callable[..., PartitionLog] | None = None
                 ) -> None:
        self._log_factory: Callable[..., PartitionLog] = (
            log_factory or InMemoryPartitionLog)
        self._locate_logs = _factory_wants_location(self._log_factory)
        self._topics: dict[str, list[PartitionLog]] = {}
        # topic -> group -> per-partition committed offsets
        self._committed: dict[str, dict[str, list[int]]] = {}
        self._lock = threading.Lock()
        self._coordinator: Any = None
        self._coord_lock = threading.Lock()
        # constructor-time import: repro.data.metrics pulls in the data
        # package, which imports this module — at construction the cycle is
        # long resolved. Instruments are cached per topic (one dict lookup
        # per produce/read, no registry lookup on the hot path).
        from repro.data.metrics import get_registry
        self._registry = get_registry()
        self._m_produce: dict[str, Any] = {}
        self._m_read: dict[str, Any] = {}

    def _register_topic_metrics(self, topic: str,
                                logs: list[PartitionLog]) -> None:
        self._m_produce[topic] = self._registry.counter(
            "broker_produce_records_total",
            "records appended to broker topics", labels={"topic": topic})
        self._m_read[topic] = self._registry.counter(
            "broker_read_records_total",
            "records read out of broker topics", labels={"topic": topic})
        self._registry.gauge(
            "broker_log_records", "per-topic log size (sum of end offsets)",
            labels={"topic": topic},
            callback=lambda: sum(log.end_offset() for log in logs))
        self._registry.gauge(
            "broker_lag", "produced-but-uncommitted records per topic",
            labels={"topic": topic}, callback=lambda: self.lag(topic))

    def _new_log(self, topic: str, partition: int) -> PartitionLog:
        if self._locate_logs:
            return self._log_factory(topic=topic, partition=partition)
        return self._log_factory()

    def create_topic(self, topic: str, partitions: int = 1) -> None:
        with self._lock:
            if topic in self._topics:
                raise ValueError(f"topic {topic!r} exists")
            logs = [self._new_log(topic, p) for p in range(partitions)]
            self._topics[topic] = logs
            self._committed[topic] = {DEFAULT_GROUP: [0] * partitions}
        self._register_topic_metrics(topic, logs)

    def topics(self) -> list[str]:
        with self._lock:
            return sorted(self._topics)

    def num_partitions(self, topic: str) -> int:
        return len(self._topic(topic))

    def _topic(self, topic: str) -> list[PartitionLog]:
        with self._lock:
            if topic not in self._topics:
                raise KeyError(f"unknown topic {topic!r}")
            return self._topics[topic]

    # -- producer ---------------------------------------------------------
    def produce(self, topic: str, value: Any, key: bytes | None = None,
                partition: int | None = None, timestamp: float = 0.0) -> int:
        logs = self._topic(topic)
        if partition is None:
            partition = _route_partition(key, len(logs))
        offset = logs[partition].append(key, value, timestamp)
        self._m_produce[topic].inc()
        return offset

    def produce_many(self, topic: str, pairs: Sequence[tuple],
                     partition: int | None = None, timestamp: float = 0.0
                     ) -> list[int]:
        """Append a batch of ``(key, value)`` pairs; returns their offsets in
        input order.

        Argument validation is all-or-nothing: an unknown topic, an
        out-of-range ``partition`` or a malformed pair raises *before any
        record is appended*. Once appends start, a storage-layer failure can
        leave a committed prefix — retrying the whole batch (what
        ``RemoteBroker`` does on a lost ack) duplicates records, which the
        idempotent-by-key sinks absorb: delivery is at-least-once per batch.
        With an explicit ``partition``, storage backends exposing
        ``append_many`` (the durable log) get the whole batch in one call —
        one disk write + fsync instead of one per record.
        """
        logs = self._topic(topic)
        if partition is not None and not 0 <= partition < len(logs):
            raise ValueError(
                f"partition {partition} out of range for topic {topic!r} "
                f"({len(logs)} partitions)")
        batch = []
        for pair in pairs:
            try:
                key, value = pair
            except (TypeError, ValueError):
                raise ValueError(
                    f"produce_many pair must be (key, value), got {pair!r}")
            if partition is None:
                try:
                    p = _route_partition(key, len(logs))
                except TypeError:          # unhashable non-bytes key: fail
                    raise ValueError(      # the batch BEFORE any append
                        f"produce_many key {key!r} is not routable "
                        "(unhashable); pass an explicit partition")
            else:
                p = partition
            batch.append((key, value, p))
        if partition is not None:
            plog = logs[partition]
            append_many = getattr(plog, "append_many", None)
            if append_many is not None:
                offsets = list(append_many([(k, v) for k, v, _ in batch],
                                           timestamp))
                self._m_produce[topic].inc(len(offsets))
                return offsets
        offsets = [logs[p].append(k, v, timestamp) for k, v, p in batch]
        self._m_produce[topic].inc(len(offsets))
        return offsets

    # -- consumer ---------------------------------------------------------
    def read(self, rng: OffsetRange) -> list[Record]:
        records = self._topic(rng.topic)[rng.partition].read(rng.start,
                                                             rng.until)
        if records:
            self._m_read[rng.topic].inc(len(records))
        return records

    def end_offset(self, topic: str, partition: int = 0) -> int:
        return self._topic(topic)[partition].end_offset()

    def end_offsets(self, topic: str) -> list[int]:
        return [log.end_offset() for log in self._topic(topic)]

    # -- consumer progress -------------------------------------------------
    # Committed offsets live broker-side so producers on *other* hosts can
    # bound their lag against what the consumer has actually processed
    # (IngestRunner backpressure over repro.data.transport). Commits are
    # monotonic: replays never move progress backwards. Each consumer group
    # tracks its own offsets; groupless callers share ``DEFAULT_GROUP``.
    def commit(self, topic: str, partition: int, offset: int,
               group: str = DEFAULT_GROUP, consumer: str | None = None,
               generation: int | None = None) -> None:
        # Network-facing via the transport: a bad partition (negative Python
        # indexing!) or an offset past the log end must not poison the lag
        # signal backpressure runs on.
        logs = self._topic(topic)               # raise on unknown topic
        if not 0 <= partition < len(logs):
            raise ValueError(
                f"partition {partition} out of range for topic {topic!r} "
                f"({len(logs)} partitions)")
        if not 0 <= offset <= logs[partition].end_offset():
            raise ValueError(
                f"commit offset {offset} outside "
                f"[0, {logs[partition].end_offset()}] for "
                f"{topic!r}[{partition}]")
        if generation is not None:
            # generation fencing: only a live member of `group` at the
            # current generation that owns the partition may advance it —
            # a zombie consumer's commit raises StaleGenerationError instead
            # of silently corrupting the group's lag signal. Checked before
            # taking self._lock (coordinator -> broker lock order).
            self.coordinator.check_commit(group, consumer, generation,
                                          topic=topic, partition=partition)
        with self._lock:
            done = self._committed[topic].setdefault(group, [0] * len(logs))
            if len(done) < len(logs):
                done.extend([0] * (len(logs) - len(done)))
            done[partition] = max(done[partition], offset)

    def committed(self, topic: str, group: str = DEFAULT_GROUP) -> list[int]:
        logs = self._topic(topic)
        with self._lock:
            done = self._committed[topic].get(group)
            if done is None:
                return [0] * len(logs)
            return done + [0] * (len(logs) - len(done))

    def commit_groups(self, topic: str) -> list[str]:
        """Groups with committed offsets on ``topic`` (default group first)."""
        self._topic(topic)
        with self._lock:
            return sorted(self._committed[topic])

    def lag(self, topic: str, group: str = DEFAULT_GROUP) -> int:
        """Produced-but-uncommitted records — the backpressure signal,
        measured against ``group``'s committed offsets."""
        return sum(self.end_offsets(topic)) - sum(self.committed(topic,
                                                                 group))

    # -- consumer groups ---------------------------------------------------
    @property
    def coordinator(self):
        """The broker-hosted :class:`~repro.data.groups.GroupCoordinator`
        (created on first use — lazy import, the data package imports this
        module). Tests inject a fake-clock coordinator by assigning
        ``broker._coordinator`` before the first group op."""
        with self._coord_lock:
            if self._coordinator is None:
                from repro.data.groups import GroupCoordinator
                self._coordinator = GroupCoordinator(self)
            return self._coordinator

    def join_group(self, group: str, consumer: str, topics: Sequence[str],
                   session_timeout: float = 5.0) -> dict:
        return self.coordinator.join_group(group, consumer, topics,
                                           session_timeout=session_timeout)

    def heartbeat(self, group: str, consumer: str, generation: int) -> dict:
        return self.coordinator.heartbeat(group, consumer, generation)

    def sync_group(self, group: str, consumer: str,
                   generation: int) -> dict:
        return self.coordinator.sync_group(group, consumer, generation)

    def leave_group(self, group: str, consumer: str) -> None:
        return self.coordinator.leave_group(group, consumer)

    def describe_group(self, group: str) -> dict:
        return self.coordinator.describe(group)


def create_rdd(context: Context, broker: Broker,
               offset_ranges: Sequence[OffsetRange],
               value_decoder: Callable[[Any], Any] | None = None) -> RDD:
    """``KafkaUtils.createRDD`` — one RDD partition per OffsetRange.

    The read happens lazily inside the partition task, so a lost partition is
    recomputed by re-reading the broker at the same offsets (exactly Kafka's
    replayability property that makes the lineage story work end-to-end).
    """
    ranges = list(offset_ranges)

    def compute(idx: int) -> list[Any]:
        records = broker.read(ranges[idx])
        values = [r.value for r in records]
        if value_decoder is not None:
            values = [value_decoder(v) for v in values]
        return values

    rdd = RDD(context, len(ranges), [], compute, name="kafkaRDD")
    return rdd
