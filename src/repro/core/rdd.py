"""Resilient Distributed Datasets — the Spark middleware layer, in-process.

The paper leans on three RDD properties and we reproduce all of them:

1. **Partitioned, lazily-evaluated datasets** with narrow (map, filter, zip,
   union) and wide (repartition) dependencies — `RDD` below.
2. **Lineage-based fault tolerance**: a lost partition is *recomputed* from
   its parents instead of being replicated. Our scheduler retries failed
   tasks by replaying lineage (see `TaskScheduler`), and `test_fault.py`
   kills partitions mid-job to prove it.
3. **The driver–worker execution model**: a driver builds the DAG, a
   scheduler runs partition tasks on an executor pool. This is the *slow
   path* the paper benchmarks against (Table I): `collect()` funnels every
   partition back through the driver.

The fast path — running a tightly-coupled collective program *in place* over
the partitions — is `core/bridge.py`, the paper's actual contribution.

Executors are threads (this container is one host); the scheduler implements
the two production behaviours that matter at 1000-node scale regardless of
transport: bounded retries driven by lineage, and speculative re-execution of
stragglers.
"""
from __future__ import annotations

import itertools
import threading
import time
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from dataclasses import dataclass
from typing import Any, Callable, Iterable, Sequence

import numpy as np

from repro.utils import get_logger

log = get_logger(__name__)

_rdd_ids = itertools.count()


class PartitionLostError(RuntimeError):
    """Raised by failure injection / executors when a partition's cached or
    computed data is lost; the scheduler recomputes from lineage."""


@dataclass(frozen=True)
class TaskAttempt:
    rdd_id: int
    partition: int
    attempt: int
    speculative: bool = False


class FailureInjector:
    """Deterministic fault injection for tests/benchmarks.

    ``fail_map[(rdd_id_offset_or_None, partition)] = n`` makes the first ``n``
    attempts of that partition raise ``PartitionLostError``. ``slow_map``
    makes attempts sleep (straggler simulation).
    """

    def __init__(self,
                 fail: dict[int, int] | None = None,
                 slow: dict[int, float] | None = None) -> None:
        self.fail = dict(fail or {})
        self.slow = dict(slow or {})
        self._lock = threading.Lock()
        self._attempts: dict[int, int] = {}

    def on_task(self, attempt: TaskAttempt) -> None:
        with self._lock:
            n = self._attempts.get(attempt.partition, 0)
            self._attempts[attempt.partition] = n + 1
        delay = self.slow.get(attempt.partition)
        if delay and not attempt.speculative:
            time.sleep(delay)
        if self.fail.get(attempt.partition, 0) > n:
            raise PartitionLostError(
                f"injected loss of partition {attempt.partition} "
                f"(attempt {attempt.attempt})")


class RDD:
    """An immutable, partitioned, lazily-evaluated dataset with lineage."""

    def __init__(self, context: "Context", num_partitions: int,
                 parents: Sequence["RDD"],
                 compute: Callable[[int], Any],
                 name: str = "rdd") -> None:
        self.context = context
        self.id = next(_rdd_ids)
        self.num_partitions = num_partitions
        self.parents = tuple(parents)
        self._compute = compute  # partition index -> partition data
        self.name = name
        self._cache: dict[int, Any] = {}
        self._cached = False

    # -- lineage ----------------------------------------------------------
    def compute_partition(self, idx: int) -> Any:
        """Compute partition ``idx`` from lineage (uses cache when present)."""
        if idx in self._cache:
            return self._cache[idx]
        data = self._compute(idx)
        if self._cached:
            self._cache[idx] = data
        return data

    def cache(self) -> "RDD":
        self._cached = True
        return self

    def unpersist_partition(self, idx: int) -> None:
        """Simulate loss of a cached partition (node crash)."""
        self._cache.pop(idx, None)

    def lineage(self) -> list["RDD"]:
        """Topologically-ordered ancestry (self last)."""
        seen: dict[int, RDD] = {}

        def visit(r: RDD) -> None:
            if r.id in seen:
                return
            for p in r.parents:
                visit(p)
            seen[r.id] = r

        visit(self)
        return list(seen.values())

    # -- narrow transformations ---------------------------------------------
    def map(self, fn: Callable[[Any], Any]) -> "RDD":
        def compute(idx: int, parent: "RDD" = self) -> Any:
            part = parent.compute_partition(idx)
            if isinstance(part, list):
                return [fn(x) for x in part]
            return fn(part)

        return RDD(self.context, self.num_partitions, [self], compute,
                   name=f"{self.name}.map")

    def map_partitions(self, fn: Callable[[Any], Any]) -> "RDD":
        def compute(idx: int, parent: "RDD" = self) -> Any:
            return fn(parent.compute_partition(idx))

        return RDD(self.context, self.num_partitions, [self], compute,
                   name=f"{self.name}.mapPartitions")

    def map_partitions_with_index(self, fn: Callable[[int, Any], Any]) -> "RDD":
        def compute(idx: int, parent: "RDD" = self) -> Any:
            return fn(idx, parent.compute_partition(idx))

        return RDD(self.context, self.num_partitions, [self], compute,
                   name=f"{self.name}.mapPartitionsWithIndex")

    def filter(self, pred: Callable[[Any], bool]) -> "RDD":
        def compute(idx: int, parent: "RDD" = self) -> Any:
            part = parent.compute_partition(idx)
            items = part if isinstance(part, list) else [part]
            return [x for x in items if pred(x)]

        return RDD(self.context, self.num_partitions, [self], compute,
                   name=f"{self.name}.filter")

    def zip_partitions(self, other: "RDD",
                       fn: Callable[[Any, Any], Any]) -> "RDD":
        if other.num_partitions != self.num_partitions:
            raise ValueError("zip requires equal partition counts")

        def compute(idx: int, a: "RDD" = self, b: "RDD" = other) -> Any:
            return fn(a.compute_partition(idx), b.compute_partition(idx))

        return RDD(self.context, self.num_partitions, [self, other], compute,
                   name=f"{self.name}.zip")

    def union(self, *others: "RDD") -> "RDD":
        """Paper Fig. 8: per-topic RDDs combined with a union before the MPI
        job — partitions are concatenated, lineage fans in."""
        rdds = (self,) + others
        offsets = np.cumsum([0] + [r.num_partitions for r in rdds])

        def compute(idx: int, rdds: tuple = rdds, offsets=offsets) -> Any:
            src = int(np.searchsorted(offsets, idx, side="right") - 1)
            return rdds[src].compute_partition(idx - int(offsets[src]))

        return RDD(self.context, int(offsets[-1]), list(rdds), compute,
                   name=f"{self.name}.union")

    # -- wide transformation ------------------------------------------------
    def repartition(self, num_partitions: int) -> "RDD":
        """Wide dependency: every output partition reads all input partitions
        (the tomography pipeline repartitions so neighbouring slices land in
        the same partition)."""
        def compute(idx: int, parent: "RDD" = self, n: int = num_partitions) -> Any:
            items: list[Any] = []
            for p in range(parent.num_partitions):
                part = parent.compute_partition(p)
                items.extend(part if isinstance(part, list) else [part])
            return items[idx::n] if n > 0 else items

        return RDD(self.context, num_partitions, [self], compute,
                   name=f"{self.name}.repartition")

    # -- actions ------------------------------------------------------------
    def collect(self) -> list[Any]:
        """Driver-side gather of every partition (the Table-I slow path)."""
        parts = self.context.scheduler.run(self)
        out: list[Any] = []
        for part in parts:
            out.extend(part if isinstance(part, list) else [part])
        return out

    def collect_partitions(self) -> list[Any]:
        return self.context.scheduler.run(self)

    def count(self) -> int:
        return len(self.collect())

    def reduce(self, fn: Callable[[Any, Any], Any]) -> Any:
        items = self.collect()
        if not items:
            raise ValueError("reduce of empty RDD")
        acc = items[0]
        for x in items[1:]:
            acc = fn(acc, x)
        return acc

    def take(self, n: int) -> list[Any]:
        return self.collect()[:n]


class TaskScheduler:
    """Runs partition tasks with lineage-driven retries + speculation.

    * Retry: a task failing with any exception is re-run up to
      ``max_failures`` times; because RDDs are lazy + deterministic, the
      re-run *is* the lineage recompute.
    * Straggler mitigation: when a task runs longer than
      ``speculation_multiplier`` × median of completed tasks (and at least
      ``speculation_quantile`` of tasks finished), a speculative copy is
      launched; first result wins — Spark's speculative execution.
    """

    def __init__(self, num_executors: int = 4, max_failures: int = 4,
                 speculation: bool = True, speculation_multiplier: float = 4.0,
                 speculation_quantile: float = 0.5,
                 failure_injector: FailureInjector | None = None) -> None:
        self.num_executors = num_executors
        self.max_failures = max_failures
        self.speculation = speculation
        self.speculation_multiplier = speculation_multiplier
        self.speculation_quantile = speculation_quantile
        self.failure_injector = failure_injector
        self.metrics = {"tasks": 0, "retries": 0, "speculative": 0,
                        "speculative_wins": 0}

    def _run_task(self, rdd: RDD, attempt: TaskAttempt) -> Any:
        self.metrics["tasks"] += 1
        if self.failure_injector is not None:
            self.failure_injector.on_task(attempt)
        return rdd.compute_partition(attempt.partition)

    def run(self, rdd: RDD) -> list[Any]:
        n = rdd.num_partitions
        results: dict[int, Any] = {}
        attempts: dict[int, int] = {p: 0 for p in range(n)}
        durations: list[float] = []

        pool = ThreadPoolExecutor(max_workers=self.num_executors)
        try:
            running: dict[Future, tuple[TaskAttempt, float]] = {}

            def launch(p: int, speculative: bool = False) -> None:
                att = TaskAttempt(rdd.id, p, attempts[p], speculative)
                attempts[p] += 1
                fut = pool.submit(self._run_task, rdd, att)
                running[fut] = (att, time.monotonic())
                if speculative:
                    self.metrics["speculative"] += 1

            for p in range(n):
                launch(p)

            while len(results) < n:
                done, _ = wait(list(running), timeout=0.05,
                               return_when=FIRST_COMPLETED)
                now = time.monotonic()
                for fut in done:
                    att, t0 = running.pop(fut)
                    if att.partition in results:
                        continue  # a twin already finished
                    try:
                        results[att.partition] = fut.result()
                        durations.append(now - t0)
                        if att.speculative:
                            self.metrics["speculative_wins"] += 1
                    except Exception as exc:  # lineage recompute path
                        if attempts[att.partition] > self.max_failures:
                            raise RuntimeError(
                                f"partition {att.partition} of {rdd.name} failed "
                                f"{attempts[att.partition]} times") from exc
                        self.metrics["retries"] += 1
                        log.debug("retrying partition %d of %s: %s",
                                  att.partition, rdd.name, exc)
                        launch(att.partition)
                # speculative re-execution of stragglers
                if (self.speculation and durations
                        and len(durations) >= self.speculation_quantile * n):
                    median = float(np.median(durations))
                    threshold = max(self.speculation_multiplier * median, 0.05)
                    live = {a.partition for a, _ in running.values()}
                    for fut, (att, t0) in list(running.items()):
                        p = att.partition
                        if (p not in results and now - t0 > threshold
                                and sum(1 for a, _ in running.values()
                                        if a.partition == p) == 1):
                            launch(p, speculative=True)
        finally:
            # abandoned straggler twins must not block job completion
            pool.shutdown(wait=False, cancel_futures=True)
        return [results[p] for p in range(n)]


class Context:
    """The SparkContext analogue: owns the scheduler, builds source RDDs."""

    def __init__(self, num_executors: int = 4,
                 scheduler: TaskScheduler | None = None) -> None:
        self.scheduler = scheduler or TaskScheduler(num_executors=num_executors)

    def parallelize(self, data: Iterable[Any], num_partitions: int) -> RDD:
        items = list(data)
        if num_partitions <= 0:
            raise ValueError("num_partitions must be positive")
        # Spark-style contiguous slicing.
        bounds = np.linspace(0, len(items), num_partitions + 1).astype(int)

        def compute(idx: int) -> list[Any]:
            return items[bounds[idx]:bounds[idx + 1]]

        return RDD(self, num_partitions, [], compute, name="parallelize")

    def from_partitions(self, partitions: Sequence[Any]) -> RDD:
        parts = list(partitions)

        def compute(idx: int) -> Any:
            return parts[idx]

        return RDD(self, len(parts), [], compute, name="fromPartitions")

    def union(self, rdds: Sequence[RDD]) -> RDD:
        first, *rest = rdds
        return first.union(*rest)
