"""The Spark↔MPI bridge — the paper's contribution, JAX-native.

The paper's central move (Fig. 1): the *same workers* that hold RDD
partitions flip into MPI ranks and run a collective program in place — no
driver round-trip. Here a "rank" is a mesh coordinate and the collective
program is a ``jax.shard_map``-ed function free to use ``jax.lax`` collectives
(psum == MPI_Allreduce, all_gather == MPI_Allgather, ppermute ==
MPI_Sendrecv, ...).

Three execution paths mirror the paper's Table I:

* :meth:`MPIBridge.run` / :meth:`MPIBridge.allreduce` — the Spark-MPI path:
  partitions live on devices, collectives run over the fabric (ICI/DCN on a
  real pod).
* :meth:`MPIBridge.driver_reduce` — the Spark driver-worker path: every
  partition funnels through the host (``collect`` + host sum) — the slow
  baseline.
* gradient-compressed allreduce (int8 + error feedback) — the
  distributed-optimization upgrade the paper points at for deep-learning
  pipelines ("gRPC/Ethernet ... area for future upgrades").

The bridge also implements the PMI contract from the paper: before the first
collective of a generation, workers ``put`` their coordinates into the KVS
and ``fence`` — on a real multi-host pod this is where
``jax.distributed.initialize`` handshakes; in-process it keeps the elastic
bookkeeping honest (see ``core/fault.py``).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.pmi import PMIClient, PMIServer
from repro.core.rdd import RDD, Context
from repro.utils import get_logger, make_mesh_compat, shard_map_compat

log = get_logger(__name__)


def make_worker_mesh(devices: Sequence[jax.Device] | None = None,
                     axis_name: str = "workers") -> Mesh:
    devs = list(devices) if devices is not None else jax.devices()
    return make_mesh_compat((len(devs),), (axis_name,), devices=devs)


class MPIBridge:
    """Runs SPMD collective programs over RDD partitions on a device mesh."""

    def __init__(self, mesh: Mesh | None = None, axis_name: str = "workers",
                 pmi: PMIServer | None = None) -> None:
        self.axis_name = axis_name
        self.mesh = mesh if mesh is not None else make_worker_mesh(axis_name=axis_name)
        if axis_name not in self.mesh.axis_names:
            raise ValueError(f"mesh lacks axis {axis_name!r}")
        self.world = int(np.prod(
            [self.mesh.shape[a] for a in self.mesh.axis_names]))
        # PMI wire-up: every rank publishes its coordinates, then fences.
        self.pmi = pmi or PMIServer(world_size=self.world)
        self._clients = [PMIClient(self.pmi, f"worker-{r}") for r in range(self.world)]
        for c in self._clients:
            c.put(f"coords/{c.rank}", str(self.mesh.devices.flat[c.rank]))
        # driver-coordinated fence: all ranks are in-process here, so the
        # driver commits the KVS once every put has landed (the threaded
        # fence path is exercised by tests/test_pmi.py)
        self.pmi.kvs().commit_all()

    # -- data plane -> compute plane ------------------------------------------
    def _stack_partitions(self, rdd: RDD) -> Any:
        """Materialize RDD partitions and stack them into leading-axis-sharded
        global arrays: partition p -> mesh worker p."""
        parts = rdd.collect_partitions()
        if len(parts) != self.world:
            raise ValueError(
                f"RDD has {len(parts)} partitions but bridge world is "
                f"{self.world}; repartition first (paper: one rank per worker)")
        stacked = jax.tree_util.tree_map(lambda *xs: np.stack(xs), *parts)
        sharding = NamedSharding(self.mesh, P(self.axis_name))
        return jax.tree_util.tree_map(
            lambda x: jax.device_put(x, sharding), stacked)

    def to_rdd(self, context: Context, tree: Any) -> RDD:
        """Compute plane -> data plane: split leading axis back to partitions."""
        parts = []
        for r in range(self.world):
            parts.append(jax.tree_util.tree_map(lambda x: np.asarray(x[r]), tree))
        return context.from_partitions(parts)

    # -- collective programs ---------------------------------------------------
    def spmd(self, fn: Callable[..., Any],
             out_specs: Any = None) -> Callable[..., Any]:
        """Wrap a per-rank function into a jitted shard_map over the bridge
        mesh. ``fn`` sees its rank's block (leading axis length 1) and may use
        any ``jax.lax`` collective with ``axis_name``."""
        in_specs = P(self.axis_name)
        out_specs = P(self.axis_name) if out_specs is None else out_specs
        sm = shard_map_compat(fn, mesh=self.mesh,
                              in_specs=in_specs, out_specs=out_specs)
        return jax.jit(sm)

    def run(self, rdd: RDD, fn: Callable[..., Any],
            out_specs: Any = None) -> Any:
        """Run ``fn`` as one rank per worker over the RDD's partitions."""
        stacked = self._stack_partitions(rdd)
        program = self.spmd(fn, out_specs=out_specs)
        return program(stacked)

    def allreduce(self, rdd: RDD, op: str = "sum",
                  compression: str | None = None) -> Any:
        """paper Fig. 6 ``allreduce.py``: in-place sum across workers."""
        axis = self.axis_name

        def prog(x):
            if compression == "int8":
                from repro.optim.compression import compressed_psum
                return compressed_psum(x, axis)
            if op == "sum":
                return jax.lax.psum(x, axis)
            if op == "max":
                return jax.lax.pmax(x, axis)
            if op == "mean":
                return jax.lax.pmean(x, axis)
            raise ValueError(f"unknown op {op!r}")

        out = self.run(rdd, prog)
        # Every rank holds the same reduced value; return rank 0's copy.
        return jax.tree_util.tree_map(lambda x: np.asarray(x[0]), out)

    # -- the slow path (Table I baseline) ------------------------------------
    @staticmethod
    def driver_reduce(rdd: RDD, op: str = "sum") -> Any:
        """paper Fig. 5 ``collect.py``: gather partitions to the driver and
        reduce there — the path Table I shows losing by 100×."""
        parts = rdd.collect_partitions()
        arrays = [jax.tree_util.tree_map(np.asarray, p) for p in parts]
        if op != "sum":
            raise ValueError("driver_reduce benchmark implements sum")
        acc = arrays[0]
        for a in arrays[1:]:
            acc = jax.tree_util.tree_map(np.add, acc, a)
        return acc


def rank_of(axis_name: str = "workers") -> jax.Array:
    """MPI_Comm_rank inside a collective program."""
    return jax.lax.axis_index(axis_name)


def world_of(mesh: Mesh, axis_name: str = "workers") -> int:
    return mesh.shape[axis_name]
