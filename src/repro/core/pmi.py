"""Process Management Interface (PMI) — the paper's wire-up layer, JAX-native.

The Spark-MPI paper's key enabler is a PMI server (Hydra with process launching
suppressed) that lets Spark-worker closures become MPI ranks: each worker only
needs ``PMI_PORT`` + ``PMI_ID`` to join a key-value space (KVS), exchange
connection info with ``put/get``, and synchronise with ``barrier``/``fence``.

On a TPU pod the transport wire-up itself is done by the runtime
(``jax.distributed.initialize`` + mesh construction), so the PMI layer here
keeps the *coordination* responsibilities that remain relevant at scale:

* a KVS with PMI-1 style ``put / fence / get`` semantics (gets only observe
  puts from before the last fence — the paper describes exactly this
  "barrier assures the necessary puts have been done" contract);
* worker membership with heartbeats and **generations**: when a worker dies
  or joins, the generation number bumps and the elastic runtime rebuilds the
  mesh (``core/fault.py``);
* deterministic rank assignment within a generation (the ``PMI_ID`` role).

Everything is in-process (threads stand in for hosts) but the API mirrors what
a real multi-host deployment needs, and ``launch/scripts/`` shows the SLURM
side (paper Fig. 2/4).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any

from repro.utils import get_logger

log = get_logger(__name__)


class PMIError(RuntimeError):
    pass


class KeyValueSpace:
    """PMI key-value space with put/fence/get semantics.

    Puts are staged per-worker and only become globally visible after a
    ``fence`` in which every registered worker participates (PMI-1's
    ``KVS_Commit`` + ``Barrier``). ``get`` on an uncommitted key raises —
    this is the property that makes rank wire-up race-free.
    """

    def __init__(self, name: str = "kvs_0") -> None:
        self.name = name
        self._lock = threading.Lock()
        self._committed: dict[str, Any] = {}
        self._staged: dict[int, dict[str, Any]] = {}
        self._fence_count = 0

    def put(self, rank: int, key: str, value: Any) -> None:
        with self._lock:
            self._staged.setdefault(rank, {})[key] = value

    def get(self, key: str, default: Any = PMIError) -> Any:
        with self._lock:
            if key in self._committed:
                return self._committed[key]
        if default is PMIError:
            raise PMIError(f"key {key!r} not committed in KVS {self.name!r}")
        return default

    def commit_all(self) -> None:
        """Collective fence: merge every worker's staged puts. Called by the
        barrier once all participants arrive."""
        with self._lock:
            for staged in self._staged.values():
                self._committed.update(staged)
            self._staged.clear()
            self._fence_count += 1

    @property
    def fence_count(self) -> int:
        return self._fence_count

    def snapshot(self) -> dict[str, Any]:
        with self._lock:
            return dict(self._committed)


@dataclass
class WorkerInfo:
    worker_id: str
    rank: int
    generation: int
    last_heartbeat: float = field(default_factory=time.monotonic)
    alive: bool = True
    meta: dict = field(default_factory=dict)


class PMIServer:
    """The rendezvous + membership server (paper's ``pmiserv``).

    Workers register, receive a rank within the current *generation*, heartbeat
    periodically, and participate in fences. A missed-heartbeat (or explicit
    ``fail_worker``) marks the worker dead and bumps the generation; the
    elastic controller then re-forms the worker set (smaller mesh, restored
    from checkpoint) — the Spark-MPI answer to node failure at scale.
    """

    def __init__(self, world_size: int, heartbeat_timeout: float = 5.0) -> None:
        self.world_size = world_size
        self.heartbeat_timeout = heartbeat_timeout
        self._lock = threading.Condition()
        self.generation = 0
        self._workers: dict[str, WorkerInfo] = {}
        self._kvs: dict[int, KeyValueSpace] = {0: KeyValueSpace("kvs_gen0")}
        self._barrier_arrived: set[str] = set()
        self._barrier_epoch = 0

    # -- membership -------------------------------------------------------
    def register(self, worker_id: str, meta: dict | None = None) -> WorkerInfo:
        with self._lock:
            if worker_id in self._workers and self._workers[worker_id].alive:
                return self._workers[worker_id]
            rank = len([w for w in self._workers.values()
                        if w.alive and w.generation == self.generation])
            info = WorkerInfo(worker_id=worker_id, rank=rank,
                              generation=self.generation, meta=meta or {})
            self._workers[worker_id] = info
            self._lock.notify_all()
            log.debug("PMI register %s -> rank %d (gen %d)", worker_id, rank,
                      self.generation)
            return info

    def heartbeat(self, worker_id: str) -> None:
        with self._lock:
            info = self._workers.get(worker_id)
            if info is None or not info.alive:
                raise PMIError(f"heartbeat from unknown/dead worker {worker_id}")
            info.last_heartbeat = time.monotonic()

    def alive_workers(self) -> list[WorkerInfo]:
        with self._lock:
            return sorted((w for w in self._workers.values() if w.alive),
                          key=lambda w: w.rank)

    def fail_worker(self, worker_id: str) -> int:
        """Mark a worker dead; bump generation. Returns the new generation."""
        with self._lock:
            info = self._workers.get(worker_id)
            if info is None:
                raise PMIError(f"unknown worker {worker_id}")
            info.alive = False
            return self._bump_generation_locked()

    def check_heartbeats(self) -> list[str]:
        """Watchdog: expire workers with stale heartbeats. Returns failures."""
        now = time.monotonic()
        failed = []
        with self._lock:
            for info in self._workers.values():
                if info.alive and now - info.last_heartbeat > self.heartbeat_timeout:
                    info.alive = False
                    failed.append(info.worker_id)
            if failed:
                self._bump_generation_locked()
        return failed

    def _bump_generation_locked(self) -> int:
        self.generation += 1
        # Re-rank survivors densely so the new mesh has contiguous ranks.
        survivors = sorted((w for w in self._workers.values() if w.alive),
                           key=lambda w: w.rank)
        for new_rank, info in enumerate(survivors):
            info.rank = new_rank
            info.generation = self.generation
        self._kvs[self.generation] = KeyValueSpace(f"kvs_gen{self.generation}")
        self._barrier_arrived.clear()
        self._lock.notify_all()
        log.info("PMI generation -> %d (%d alive)", self.generation, len(survivors))
        return self.generation

    # -- KVS + fence --------------------------------------------------------
    def kvs(self, generation: int | None = None) -> KeyValueSpace:
        with self._lock:
            return self._kvs[self.generation if generation is None else generation]

    def fence(self, worker_id: str, timeout: float = 30.0) -> None:
        """Collective barrier + KVS commit across the current generation."""
        deadline = time.monotonic() + timeout
        with self._lock:
            gen = self.generation
            epoch = self._barrier_epoch
            self._barrier_arrived.add(worker_id)
            n_alive = len([w for w in self._workers.values() if w.alive])
            if len(self._barrier_arrived) >= n_alive:
                self._kvs[gen].commit_all()
                self._barrier_arrived.clear()
                self._barrier_epoch += 1
                self._lock.notify_all()
                return
            while self._barrier_epoch == epoch:
                if self.generation != gen:
                    raise PMIError("generation changed during fence (worker died)")
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise PMIError(f"fence timeout for {worker_id}")
                self._lock.wait(timeout=min(remaining, 0.5))


class PMIClient:
    """Worker-side handle: the ``PMI_PORT``/``PMI_ID`` role from the paper."""

    def __init__(self, server: PMIServer, worker_id: str,
                 meta: dict | None = None) -> None:
        self._server = server
        self.worker_id = worker_id
        self.info = server.register(worker_id, meta)

    @property
    def rank(self) -> int:
        return self.info.rank

    @property
    def generation(self) -> int:
        return self.info.generation

    def put(self, key: str, value: Any) -> None:
        self._server.kvs(self.generation).put(self.rank, key, value)

    def get(self, key: str, default: Any = PMIError) -> Any:
        return self._server.kvs(self.generation).get(key, default)

    def fence(self, timeout: float = 30.0) -> None:
        self._server.fence(self.worker_id, timeout=timeout)

    def heartbeat(self) -> None:
        self._server.heartbeat(self.worker_id)
