"""Near-real-time pipeline driver: sources → micro-batches → collective job → sinks.

This is the composition layer the paper's Fig. 7 / Fig. 11 describe: a
detector (or any producer) appends to broker topics; the streaming context
discretizes the stream into micro-batch RDDs; the bridge flips the batch into
a collective program (the "MPI application"); sinks consume results
(visualization, checkpoint, downstream topics).

The pipeline tracks the paper's near-real-time criterion explicitly:
per-batch processing time vs. the acquisition interval (§III: 512 frames
arrive in ~25 s; reconstruction must keep up).

Every box in that figure is now swappable: sources come from
``repro.data.sources``, sinks from ``repro.data.sinks``, and the broker
itself may sit in another process — hand the constructor a
:class:`~repro.data.transport.RemoteBroker` and the detector's
:class:`~repro.data.ingest.IngestRunner` can run host-side at the beamline
while this pipeline reconstructs cluster-side (``docs/transport.md``;
``examples/remote_ingest.py`` runs exactly that split).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

from repro.core.bridge import MPIBridge
from repro.core.broker import Broker
from repro.core.dstream import BatchInfo, StreamingContext
from repro.core.rdd import RDD, Context
from repro.utils import get_logger

log = get_logger(__name__)


@dataclass
class PipelineConfig:
    topics: Sequence[str] = ()
    batch_interval: float = 0.1
    max_records_per_partition: int | None = None
    checkpoint_path: str | None = None
    value_decoder: Callable[[Any], Any] | None = None
    source_partitions: int = 1     # topic partitions for subscribed sources


@dataclass
class PipelineReport:
    batches: int = 0
    records: int = 0
    batch_latencies: list[float] = field(default_factory=list)

    @property
    def mean_latency(self) -> float:
        return (sum(self.batch_latencies) / len(self.batch_latencies)
                if self.batch_latencies else 0.0)

    @property
    def max_latency(self) -> float:
        return max(self.batch_latencies, default=0.0)

    def keeps_up(self, interval: float) -> bool:
        return self.max_latency <= interval


class NearRealTimePipeline:
    """Generic streaming pipeline: the app supplies ``process``.

    ``process(batch_rdd, info, bridge)`` is arbitrary — the ptychography app
    runs a shard_map'd RAAR update, the LM app a train/serve step, the
    tomography app a partition-parallel ART sweep. The pipeline owns
    scheduling, offset checkpointing, latency accounting and sinks.
    """

    def __init__(self, broker: Broker, config: PipelineConfig,
                 process: Callable[..., Any],
                 bridge: MPIBridge | None = None,
                 context: Context | None = None,
                 sources: Sequence[Any] = (),
                 sinks: Sequence[Any] = (),
                 window: Any = None,
                 window_state: Any = None) -> None:
        """Without ``window``, ``process(batch_rdd, info, bridge)`` runs once
        per micro-batch. With ``window`` (a :class:`~repro.data.window
        .WindowSpec`), records accumulate across micro-batches and
        ``process(records, window_info, bridge)`` runs once per *complete*
        window instead — "reconstruct over the last K frames" without
        app-side buffering; call :meth:`flush_windows` at end-of-stream for
        the final partial window. ``window_state`` (a :class:`~repro.data
        .state.WindowStateStore`, e.g. ``DurableStateStore``) makes the open
        window restart-safe: with ``config.checkpoint_path`` set, window
        state commits atomically with the consumed offsets, so a killed
        pipeline resumes mid-window with nothing lost or duplicated."""
        self.broker = broker
        self.config = config
        self.context = context or Context()
        self.bridge = bridge or MPIBridge()
        self.report = PipelineReport()
        self._process = process
        self._sinks: list[Callable[[BatchInfo], None]] = []
        self._keyed_sinks: list[Any] = []
        self.windower = None
        self.streaming = StreamingContext(
            self.context, broker,
            batch_interval=config.batch_interval,
            max_records_per_partition=config.max_records_per_partition,
            checkpoint_path=config.checkpoint_path)
        self.streaming.subscribe(config.topics, config.value_decoder)
        for src in sources:
            self.subscribe_source(src)
        if window_state is not None and window is None:
            raise ValueError("window_state requires a window spec")
        if window is not None:
            from repro.data.window import windowed
            on_batch = windowed(window, self._on_window, store=window_state)
            self.windower = on_batch.windower
            self.streaming.foreach_batch(on_batch)
        else:
            self.streaming.foreach_batch(self._on_batch)
        self.streaming.add_sink(self._on_sink)
        for sink in sinks:
            if isinstance(sink, tuple):      # (sink, SinkPolicy) pair
                self.add_sink(sink[0], policy=sink[1])
            else:
                self.add_sink(sink)

    def subscribe_source(self, source: Any, topic: str | None = None) -> str:
        """Feed the pipeline from a :class:`repro.data.sources.Source`."""
        return self.streaming.subscribe_source(
            source, topic=topic, partitions=self.config.source_partitions)

    def add_sink(self, sink: Any, policy: Any = None,
                 name: str | None = None) -> None:
        """Accept either a plain ``fn(BatchInfo)`` or a keyed
        :class:`repro.data.sinks.Sink` (``write_batch``): keyed sinks get the
        batch result normalized to ``(key, value)`` items, so their per-key
        idempotence upgrades replay to exactly-once.

        Without a ``policy`` the sink is written serially in the batch
        thread (the degenerate single-thread fan-out). With a
        :class:`~repro.data.delivery.SinkPolicy` it moves onto its own
        delivery lane — worker thread, bounded queue, per-sink failure
        isolation (retry / skip / dead-letter / fail-pipeline) — so a slow
        artifact store cannot stall the metrics path. Lane delivery is
        asynchronous: batches are guaranteed written only after
        :meth:`close`; a crash before that can lose up to ``queue_depth``
        queued batches for that sink (offsets were already committed), so
        the exactly-once upgrade holds for lanes only up to a clean
        shutdown. Lane counters: :meth:`delivery_report`.
        """
        if policy is not None:
            # mirror the serial path: a sink exposing BOTH surfaces
            # (MetricsSink) gets an observe lane AND a keyed lane
            delivery = self.streaming.delivery
            observes = hasattr(sink, "observe")
            keyed = hasattr(sink, "write_batch")
            if observes:
                delivery.add_batch_sink(
                    sink.observe, policy,
                    name=((name or type(sink).__name__)
                          + ("-observe" if keyed else "")),
                    # close via one lane only when the sink has two
                    sink_close=(None if keyed
                                else getattr(sink, "close", None)))
            if keyed:
                delivery.add_sink(sink, policy, name=name)
            if not observes and not keyed:
                delivery.add_batch_sink(sink, policy, name=name)
            return
        if hasattr(sink, "observe"):        # batch-level metrics sink
            self._sinks.append(sink.observe)
        if hasattr(sink, "write_batch"):
            self._keyed_sinks.append(sink)
        elif not hasattr(sink, "observe"):
            self._sinks.append(sink)

    def _on_batch(self, rdd: RDD, info: BatchInfo) -> Any:
        return self._process(rdd, info, self.bridge)

    def _on_window(self, records: list, winfo: Any) -> Any:
        return self._process(records, winfo, self.bridge)

    def flush_windows(self) -> list:
        """End-of-stream (windowed pipelines): fire the final partial window,
        deliver its results to the keyed sinks, and only then checkpoint the
        drained state — the same sinks-before-commit contract as a batch, so
        a crash anywhere in between re-fires the partial window on restart
        (idempotent keys absorb the replay) instead of losing it. Returns
        the window results (``[]`` when nothing was pending)."""
        if self.windower is None:
            return []
        snapshot = self.windower.state()
        results = self.windower.flush()
        if not results:
            return []
        try:
            if self._keyed_sinks:
                from repro.data.sinks import describe_result_items
                items = describe_result_items(results,
                                              self.streaming._batch_index)
                for sink in self._keyed_sinks:
                    sink.write_batch(items)
        except BaseException:
            self.windower.restore_state(snapshot)   # flush stays retryable
            raise
        if self.config.checkpoint_path:
            self.streaming.checkpoint_now()
        return results

    def _on_sink(self, info: BatchInfo) -> None:
        self.report.batches += 1
        self.report.records += info.num_records
        self.report.batch_latencies.append(info.processing_time)
        for sink in self._sinks:
            sink(info)
        if self._keyed_sinks:
            from repro.data.sinks import describe_result_items
            items = describe_result_items(info.result, info.index)
            for sink in self._keyed_sinks:
                sink.write_batch(items)

    # -- drive ----------------------------------------------------------------
    def run(self, max_batches: int, wait_for_data: float = 1.0) -> PipelineReport:
        self.streaming.run_batches(max_batches, wait_for_data=wait_for_data)
        return self.report

    def run_until_drained(self, producer_done: Callable[[], bool] | None = None,
                          idle_timeout: float = 2.0) -> PipelineReport:
        """Process batches until the producer finished AND the topics drained.

        With subscribed sources, ``producer_done`` defaults to "every source
        exhausted"."""
        if producer_done is None:
            producer_done = lambda: self.streaming.sources_exhausted  # noqa: E731
        last_data = time.monotonic()
        while True:
            info = self.streaming.run_one_batch()
            if info is not None:
                last_data = time.monotonic()
                continue
            if producer_done() and time.monotonic() - last_data > min(
                    idle_timeout, 10 * self.config.batch_interval):
                break
            # max(), not `x or 0.001`: the or-form is a truthiness test on
            # a time value, the same 0-vs-None conflation as the PR-8
            # deadline bugs (here it only guarded exactly-zero, so a floor
            # says what it means)
            time.sleep(max(self.config.batch_interval / 10, 0.001))
        return self.report

    # -- observability ---------------------------------------------------------
    def serve_observability(self, address: tuple[str, int] = ("127.0.0.1", 0),
                            lag_policy: Any = None):
        """Start the pipeline's HTTP observability endpoint (``/metrics``,
        ``/metrics.json``, ``/traces``, ``/health``) — delegates to
        :meth:`repro.core.dstream.StreamingContext.serve_observability`;
        stopped by :meth:`close`."""
        return self.streaming.serve_observability(address=address,
                                                  lag_policy=lag_policy)

    # -- parallel sink delivery ----------------------------------------------
    def close(self, drain: bool = True) -> None:
        """Shut down the delivery lanes (see ``StreamingContext.close``).
        Call after the last ``run*`` when sinks were added with a policy;
        ``drain=True`` guarantees every processed batch reached every sink."""
        self.streaming.close(drain=drain)

    def delivery_report(self) -> dict[str, dict[str, Any]]:
        """Per-sink-lane depth/latency/failure counters ({} when every sink
        runs serially) — the delivery-side complement of ``MetricsSink``."""
        if self.streaming._delivery is None:
            return {}
        return self.streaming.delivery.report()
