"""GPipe pipeline parallelism over the 'pod' axis.

Cross-pod DCN is ~10× slower than ICI, so the multi-pod mesh wants the
parallelism with the *least* inter-pod traffic. DP moves 2×params of
gradients per step over DCN; pipeline parallelism moves only microbatch
activations (B_mb·S·D per boundary per tick) — for the 1T config that is
three orders of magnitude less wire.

Implementation: ``shard_map`` manual over 'pod' only (``axis_names=
{'pod'}``) — GSPMD keeps handling data/model INSIDE each stage, so TP/DP
compose under the pipeline unchanged. The stacked layer params shard over
'pod' on the layer dim (each pod holds L/n_stages layers). The schedule is
plain GPipe: M microbatches, M + n_stages - 1 ticks, activations hop stages
via ``ppermute``; every stage computes every tick (the bubble is the
standard (n_stages-1)/M overhead and is *visible* in the walker FLOPs —
honest accounting). Backward works by AD: ``ppermute`` transposes to the
reverse hop, giving the mirrored backward pipeline for free.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.utils import get_logger, shard_map_compat

log = get_logger(__name__)


def gpipe_apply(stage_fn: Callable[[jax.Array, Any], jax.Array],
                stage_params: Any, mbs: jax.Array, n_stages: int,
                axis: str = "pod") -> jax.Array:
    """Run ``stage_fn`` as a GPipe pipeline inside a manual-'pod' region.

    mbs: (M, mb, S, D) microbatch activations (consumed by stage 0).
    Returns (M, mb, S, D) outputs (valid on every rank — broadcast from the
    last stage with a masked psum)."""
    r = jax.lax.axis_index(axis)
    M = mbs.shape[0]
    T = M + n_stages - 1
    perm = [(i, i + 1) for i in range(n_stages - 1)]
    zero = jnp.zeros_like(mbs[0])

    def tick(carry, t):
        prev = carry                                    # my last output
        recv = jax.lax.ppermute(prev, axis, perm)       # from stage r-1
        feed = mbs[jnp.clip(t, 0, M - 1)]
        x_in = jnp.where(r == 0, feed, recv)
        y = stage_fn(x_in, stage_params)
        return y, y

    _, ys = jax.lax.scan(tick, zero, jnp.arange(T))
    outs = ys[n_stages - 1:]                            # (M, mb, S, D)
    # only the last stage's values are real; broadcast them
    outs = jnp.where(r == n_stages - 1, outs, jnp.zeros_like(outs))
    return jax.lax.psum(outs, axis)


def pipeline_layers(run_block: Callable[[jax.Array, Any], jax.Array],
                    layer_params: Any, x: jax.Array, mesh: Mesh,
                    num_layers: int, microbatches: int,
                    axis: str = "pod") -> jax.Array:
    """Pipeline a stacked-layer transformer body over the 'pod' axis.

    x: (B, S, D) full batch activations (replicated over 'pod');
    layer_params: stacked (L, ...) pytree (sharded over 'pod' on dim 0).
    run_block(x, one_layer_params) -> x."""
    n_stages = mesh.shape[axis]
    if n_stages <= 1:
        def seq(x):
            def body(x, p):
                return run_block(x, p), None
            x, _ = jax.lax.scan(body, x, layer_params)
            return x
        return seq(x)
    assert num_layers % n_stages == 0, "layers must split evenly into stages"
    B = x.shape[0]
    assert B % microbatches == 0, "batch must split into microbatches"
    mb = B // microbatches
    mbs = x.reshape(microbatches, mb, *x.shape[1:])

    def stage_fn(x_in, params_stage):
        def body(x, p):
            return run_block(x, p), None
        x_out, _ = jax.lax.scan(body, x_in, params_stage)
        return x_out

    spec_layers = jax.tree_util.tree_map(lambda _: P(axis), layer_params)
    pipe = shard_map_compat(
        functools.partial(gpipe_apply, stage_fn, n_stages=n_stages,
                          axis=axis),
        mesh=mesh,
        in_specs=(spec_layers, P()),
        out_specs=P(),
        axis_names={axis},
        check_vma=False,
    )
    out = pipe(layer_params, mbs)
    return out.reshape(B, *x.shape[1:])
