"""Explicit-collective data-parallel training (the Spark-MPI path).

GSPMD emits whatever collectives it likes; this module instead writes the
distributed optimizer the way the paper writes MPI programs — as an explicit
rank-parallel ``shard_map`` with hand-placed collectives:

    grads  --reduce-scatter-->  1/W flat shard        (psum_scatter)
    AdamW on the shard          (ZeRO: m/v/master live sharded, flat)
    params <--all-gather--      updated flat shards   (all_gather)

plus the paper's "future upgrade": int8-compressed gradient reduction with
a pmax-shared scale (optim/compression.py) — wire bytes ÷2 vs bf16, ÷4 vs
fp32, exact int32 summation.

This is the right layout when the model is small relative to the mesh
(§Perf: a 1.8B model on 256 chips is collective-bound under TP-16; pure DP
with ZeRO + compression moves the bottleneck back to compute). Numerics are
tested against the fused-GSPMD trainer in tests/test_dp.py.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, OptimizerConfig
from repro.models.registry import get_model
from repro.optim.adamw import lr_schedule
from repro.parallel.sharding import use_mesh
from repro.utils import get_logger, shard_map_compat

log = get_logger(__name__)


def _world(mesh: Mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))


def flatten_params(params: Any, world: int) -> tuple[jax.Array, Any]:
    """Concatenate every leaf into one fp32 vector padded to world."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    flat = jnp.concatenate([l.astype(jnp.float32).reshape(-1)
                            for l in leaves])
    pad = (-flat.shape[0]) % world
    if pad:
        flat = jnp.pad(flat, (0, pad))
    meta = (treedef, [(l.shape, l.dtype) for l in leaves], pad)
    return flat, meta


def unflatten_params(flat: jax.Array, meta: Any) -> Any:
    treedef, shapes, pad = meta
    if pad:
        flat = flat[:-pad] if pad else flat
    out = []
    off = 0
    for shape, dtype in shapes:
        n = int(np.prod(shape))
        out.append(flat[off:off + n].reshape(shape).astype(dtype))
        off += n
    return jax.tree_util.tree_unflatten(treedef, out)


def init_dp_opt_state(params: Any, mesh: Mesh,
                      opt: OptimizerConfig) -> dict:
    """Flat ZeRO shards, materialized with the correct sharding."""
    world = _world(mesh)
    flat, meta = flatten_params(params, world)
    chunk = flat.shape[0] // world
    axes = tuple(mesh.axis_names)
    shard = NamedSharding(mesh, P(axes))
    zeros = jnp.zeros((world * chunk,), jnp.dtype(opt.state_dtype))
    state = {
        "m": jax.device_put(zeros, shard),
        "v": jax.device_put(zeros, shard),
        "master": jax.device_put(flat, shard),
        "step": jnp.zeros((), jnp.int32),
    }
    return state


def build_dp_train_step(config: ModelConfig, opt: OptimizerConfig,
                        mesh: Mesh, compression: str | None = None):
    """Returns (jitted_step, state_shardings). state = {params, opt}."""
    model = get_model(config)
    world = _world(mesh)
    axes = tuple(mesh.axis_names)

    def step(state: dict, batch: dict) -> tuple[dict, dict]:
        with use_mesh(None):                       # manual collectives only
            params = state["params"]

            def loss_fn(p):
                return model.loss_and_metrics(p, batch, config)

            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params)
            gflat, meta = flatten_params(grads, world)
            chunk = gflat.shape[0] // world
            g2d = gflat.reshape(world, chunk)
            if compression == "int8":
                # shared scale -> int8 ON THE WIRE (all-to-all) -> exact
                # int32 summation locally. (A psum_scatter of int32 would
                # be numerically identical but moves 4-byte words — the
                # first int8 attempt measured ZERO wire savings; see
                # EXPERIMENTS.md §Perf C2.)
                amax = jax.lax.pmax(jnp.max(jnp.abs(g2d)), axes)
                scale = jnp.maximum(amax / 127.0, 1e-12)
                q = jnp.clip(jnp.round(g2d / scale), -127, 127
                             ).astype(jnp.int8)
                qt = jax.lax.all_to_all(q, axes, 0, 0, tiled=False)
                qs = jnp.sum(qt.astype(jnp.int32), axis=0)
                g_shard = qs.astype(jnp.float32) * scale / world
            else:
                g_shard = jax.lax.psum_scatter(
                    g2d, axes, scatter_dimension=0, tiled=False) / world

            # global grad-norm clip on shards
            o = state["opt"]
            step_no = o["step"] + 1
            gn2 = jax.lax.psum(jnp.sum(jnp.square(g_shard)), axes)
            gnorm = jnp.sqrt(gn2)
            if opt.grad_clip > 0:
                g_shard = g_shard * jnp.minimum(
                    1.0, opt.grad_clip / jnp.maximum(gnorm, 1e-9))

            # AdamW on the flat shard (ZeRO-sharded m/v/master)
            lr = lr_schedule(step_no, opt)
            b1, b2 = opt.b1, opt.b2
            c1 = 1.0 - b1 ** step_no.astype(jnp.float32)
            c2 = 1.0 - b2 ** step_no.astype(jnp.float32)
            m = b1 * o["m"].astype(jnp.float32) + (1 - b1) * g_shard
            v = b2 * o["v"].astype(jnp.float32) + (1 - b2) * g_shard ** 2
            delta = (m / c1) / (jnp.sqrt(v / c2) + opt.eps)
            master = o["master"] - lr * (delta + opt.weight_decay
                                         * o["master"])
            # gather the update in bf16: params are bf16, so gathering the
            # fp32 master doubles the wire for nothing (§Perf C3)
            new_flat = jax.lax.all_gather(master.astype(jnp.bfloat16),
                                          axes, axis=0, tiled=True)
            new_params = jax.tree_util.tree_map(
                lambda a, b: a.astype(b.dtype),
                unflatten_params(new_flat, meta), params)
            sd = jnp.dtype(opt.state_dtype)
            new_state = {"params": new_params,
                         "opt": {"m": m.astype(sd), "v": v.astype(sd),
                                 "master": master, "step": step_no}}
            metrics = {**metrics, "lr": lr, "grad_norm": gnorm,
                       "total_loss": loss}
            metrics = jax.tree_util.tree_map(
                lambda x: jax.lax.pmean(x, axes), metrics)
            return new_state, metrics

    state_specs = {"params": P(),
                   "opt": {"m": P(axes), "v": P(axes), "master": P(axes),
                           "step": P()}}
    sm = shard_map_compat(step, mesh=mesh,
                          in_specs=(state_specs, P(axes)),
                          out_specs=(state_specs, P()),
                          check_vma=False)
    return jax.jit(sm, donate_argnums=(0,)), state_specs


def lower_dp_cell(config: ModelConfig, shape, mesh: Mesh,
                  opt: OptimizerConfig | None = None,
                  compression: str | None = None):
    """Lower the explicit-collective DP train step for the dry-run/walker."""
    from repro.configs import input_specs
    opt = opt or OptimizerConfig()
    model = get_model(config)
    jitted, _ = build_dp_train_step(config, opt, mesh, compression)
    param_shapes = jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0), config))
    opt_shapes = jax.eval_shape(
        functools.partial(init_dp_opt_state, mesh=mesh, opt=opt),
        param_shapes)
    return jitted.lower({"params": param_shapes, "opt": opt_shapes},
                        input_specs(config, shape)["batch"])
