"""Parallelism substrate: logical sharding rules, collectives, pipeline."""
