"""Logical-axis sharding: DP / TP / EP / FSDP / SP rules over the (pod, data,
model) production mesh.

Models annotate tensors with *logical* axis names ('batch', 'heads', 'ff',
'experts', ...); a rule table maps logical names to physical mesh axes. The
same model code then runs on a 1-device test mesh, the 16×16 single-pod mesh,
or the 2×16×16 multi-pod mesh — only the rules change. This is the standard
GSPMD recipe (t5x/MaxText-style), and it is how the Spark-MPI "collective
program" stays portable across deployments (the paper's "no changes to MPI
programs" property).

Default layout:
  * batch        -> ('pod', 'data')   pure DP; gradients all-reduce over it
  * heads/kv/ff/vocab/experts -> 'model'   Megatron TP / expert parallelism
  * expert_in    -> 'data' (opt-in)   FSDP-style weight sharding for 1T MoE
  * seq_shard    -> 'data' (opt-in)   sequence/context parallelism
  * opt state    -> extra 'data' sharding (ZeRO-1), see optim/adamw.py

Rules are per-config overridable (``ShardingRules(overrides=...)``).
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Any, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.utils import get_logger

log = get_logger(__name__)

# Logical axis -> preferred mesh axes (first existing one wins; tuples mean
# "shard over the product of these axes").
DEFAULT_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq": None,            # attention-internal sequence axis (kept whole)
    "act_seq": "model",     # residual-stream sequence axis: Megatron-style
                            # sequence parallelism (layer inputs/outputs are
                            # seq-sharded over 'model'; XLA inserts the
                            # all-gather / reduce-scatter pair per block).
                            # Dropped automatically when S % model != 0
                            # (e.g. decode S=1).
    "seq_shard": None,      # opt-in context parallelism
    "embed": None,          # d_model is kept replicated by default
    "embed_fsdp": None,     # opt-in: shard d_model dim of weights over 'data'
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ff": "model",
    "vocab": "model",
    "experts": "model",
    "experts_a2a": ("model", "data"),  # a2a EP: whole experts per device
    "expert_in": None,      # opt-in FSDP for expert weights: 'data'
    "expert_cap": "data",   # expert capacity dim follows the data shards
    "layers": None,         # scan-stacked layer dim
    "conv": None,
    "lru": "model",
    "frames": None,
    "null": None,
}


@dataclass
class ShardingRules:
    overrides: dict[str, Any] = field(default_factory=dict)

    def physical(self, logical: str) -> Any:
        table = {**DEFAULT_RULES, **self.overrides}
        if logical not in table:
            raise KeyError(f"unknown logical axis {logical!r}")
        return table[logical]

    def spec(self, logical_axes: Sequence[str | None],
             mesh: Mesh | None) -> P:
        """PartitionSpec for a tensor annotated with logical axis names.

        Mesh axes that don't exist on the current mesh (e.g. 'pod' on the
        single-pod mesh) are silently dropped — the same annotation works on
        every deployment size. Avoids double-assigning a mesh axis."""
        used: set[str] = set()
        parts: list[Any] = []
        axis_names = set(mesh.axis_names) if mesh is not None else set()
        for name in logical_axes:
            if name is None:
                parts.append(None)
                continue
            phys = self.physical(name)
            if phys is None:
                parts.append(None)
                continue
            cand = phys if isinstance(phys, tuple) else (phys,)
            cand = tuple(a for a in cand if a in axis_names and a not in used)
            if not cand:
                parts.append(None)
            elif len(cand) == 1:
                parts.append(cand[0])
                used.add(cand[0])
            else:
                parts.append(cand)
                used.update(cand)
        while parts and parts[-1] is None:
            parts.pop()
        return P(*parts)


# -- active mesh/rules context ----------------------------------------------
class _ShardingContext(threading.local):
    def __init__(self) -> None:
        self.mesh: Mesh | None = None
        self.rules: ShardingRules = ShardingRules()


_ctx = _ShardingContext()


@contextlib.contextmanager
def use_mesh(mesh: Mesh | None, rules: ShardingRules | None = None):
    """Activate a mesh + rule table for logical_constraint/named_sharding."""
    prev = (_ctx.mesh, _ctx.rules)
    _ctx.mesh = mesh
    if rules is not None:
        _ctx.rules = rules
    try:
        if mesh is not None:
            with mesh:
                yield
        else:
            yield
    finally:
        _ctx.mesh, _ctx.rules = prev


def current_mesh() -> Mesh | None:
    return _ctx.mesh


def current_rules() -> ShardingRules:
    return _ctx.rules


def drop_indivisible(spec: P, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Drop mesh axes whose size does not divide the tensor dim (e.g. 56
    query heads over a 16-way 'model' axis): the tensor falls back to coarser
    sharding instead of GSPMD padding — the divisibility waste then shows up
    honestly in the roofline as replicated compute, where the §Perf loop can
    attack it per-arch. (jit in_shardings *require* divisibility.)"""
    parts: list[Any] = []
    for i, part in enumerate(spec):
        if part is None or i >= len(shape):
            parts.append(None)
            continue
        axes = part if isinstance(part, tuple) else (part,)
        total = int(np.prod([mesh.shape[a] for a in axes]))
        if shape[i] % total != 0:
            kept = []
            size = 1
            for a in axes:  # keep a prefix that still divides
                if shape[i] % (size * mesh.shape[a]) == 0:
                    kept.append(a)
                    size *= mesh.shape[a]
            part = tuple(kept) if len(kept) > 1 else (kept[0] if kept else None)
        parts.append(part)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def logical_constraint(x: jax.Array, *logical_axes: str | None) -> jax.Array:
    """with_sharding_constraint by logical names; no-op without a mesh."""
    mesh = _ctx.mesh
    if mesh is None or np.prod(list(mesh.shape.values())) == 1:
        return x
    spec = _ctx.rules.spec(logical_axes, mesh)
    spec = drop_indivisible(spec, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(logical_axes: Sequence[str | None],
                   mesh: Mesh | None = None,
                   rules: ShardingRules | None = None) -> NamedSharding:
    mesh = mesh or _ctx.mesh
    rules = rules or _ctx.rules
    if mesh is None:
        raise ValueError("no active mesh")
    return NamedSharding(mesh, rules.spec(logical_axes, mesh))


def tree_shardings(spec_tree: Any, mesh: Mesh | None = None,
                   rules: ShardingRules | None = None) -> Any:
    """Map a pytree of logical-axis tuples to NamedShardings."""
    return jax.tree_util.tree_map(
        lambda axes: named_sharding(axes, mesh, rules),
        spec_tree, is_leaf=lambda x: isinstance(x, tuple))


def tree_specs(spec_tree: Any, mesh: Mesh, rules: ShardingRules | None = None) -> Any:
    rules = rules or _ctx.rules
    return jax.tree_util.tree_map(
        lambda axes: rules.spec(axes, mesh),
        spec_tree, is_leaf=lambda x: isinstance(x, tuple))


def tree_specs_shaped(spec_tree: Any, shape_tree: Any, mesh: Mesh,
                      rules: ShardingRules | None = None) -> Any:
    """Like tree_specs but drops axes that don't divide the actual shapes
    (required for jit in_shardings)."""
    rules = rules or _ctx.rules
    return jax.tree_util.tree_map(
        lambda axes, shp: drop_indivisible(rules.spec(axes, mesh),
                                           tuple(shp.shape), mesh),
        spec_tree, shape_tree, is_leaf=lambda x: isinstance(x, tuple))
