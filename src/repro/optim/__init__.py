"""Optimizer substrate: AdamW + ZeRO-1, LR schedules, clipping, gradient
compression with error feedback."""
from repro.optim.adamw import (adamw_update, add_zero_axis,
                               clip_by_global_norm, init_opt_state,
                               lr_schedule, zero1_state_specs)
from repro.optim.compression import (compressed_psum, dequantize_int8,
                                     ef_compress_tree, init_residual,
                                     quantize_int8)

__all__ = [
    "adamw_update", "add_zero_axis", "clip_by_global_norm", "init_opt_state",
    "lr_schedule", "zero1_state_specs", "compressed_psum", "dequantize_int8",
    "ef_compress_tree", "init_residual", "quantize_int8",
]
