"""Gradient compression for cross-fabric all-reduce (int8 + error feedback).

The paper's Table I identifies the slow transport (gRPC/Ethernet — for a TPU
pod: the DCN hop between pods) as the bottleneck for distributed deep
learning and points at it as the upgrade area. At 1000+ nodes the DCN
all-reduce of the 'pod' axis is exactly that slow link, so the framework
ships a drop-in compressed all-reduce:

  * per-tensor symmetric int8 quantization (4× fewer bytes on the wire);
  * error feedback (residual carried to the next step) — keeps SGD/Adam
    convergence (Karimireddy et al., 2019);
  * `compressed_psum` — quantize -> psum int32 -> dequantize, usable inside
    any shard_map program (the bridge exposes it as
    ``allreduce(..., compression='int8')``).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8. Returns (q, scale)."""
    x32 = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x32))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """All-reduce with int8 payload: each rank quantizes with its own scale,
    scales are all-maxed first so the sum is exact in the shared grid."""
    x32 = x.astype(jnp.float32)
    amax = jax.lax.pmax(jnp.max(jnp.abs(x32)), axis_name)
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int32)
    total = jax.lax.psum(q, axis_name)
    return (total.astype(jnp.float32) * scale).astype(x.dtype)


def ef_compress_tree(grads: Any, residual: Any
                     ) -> tuple[Any, Any, Any]:
    """Error-feedback compression of a gradient pytree.

    Returns (quantized_tree(q, scale), new_residual, dequantized_view).
    The caller reduces the quantized view across DP and applies
    ``ef_decompress_tree``; the residual (x - Q(x)) is added to the *next*
    step's gradients before compression.
    """
    def comp(g, r):
        x = g.astype(jnp.float32) + r
        q, scale = quantize_int8(x)
        deq = dequantize_int8(q, scale)
        return (q, scale), x - deq, deq

    out = jax.tree_util.tree_map(comp, grads, residual)
    qtree = jax.tree_util.tree_map(lambda t: t[0], out,
                                   is_leaf=lambda x: isinstance(x, tuple)
                                   and len(x) == 3)
    new_res = jax.tree_util.tree_map(lambda t: t[1], out,
                                     is_leaf=lambda x: isinstance(x, tuple)
                                     and len(x) == 3)
    deq = jax.tree_util.tree_map(lambda t: t[2], out,
                                 is_leaf=lambda x: isinstance(x, tuple)
                                 and len(x) == 3)
    return qtree, new_res, deq


def init_residual(params: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
