"""AdamW with fp32 master weights + ZeRO-1 state sharding (from scratch;
no optax in this container).

Memory layout at scale (the reason ZeRO-1 is not optional at 512 chips):
params may be bf16 (2 B) and TP-sharded; m/v (+ optional fp32 master) are
3×4 B/param — sharded *additionally* over the 'data' axis by giving the
optimizer state a PartitionSpec with 'data' on the first free dimension.
Under GSPMD this materializes exactly the ZeRO-1 schedule: gradients
reduce-scatter onto the state shards, the update runs sharded, and the new
params all-gather back to their TP layout.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import OptimizerConfig


# -- schedule -------------------------------------------------------------------
def lr_schedule(step: jax.Array, config: OptimizerConfig) -> jax.Array:
    """Linear warmup -> cosine decay to 10%."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(config.warmup_steps, 1), 1.0)
    t = jnp.clip((step - config.warmup_steps)
                 / jnp.maximum(config.total_steps - config.warmup_steps, 1),
                 0.0, 1.0)
    cos = 0.1 + 0.45 * (1.0 + jnp.cos(jnp.pi * t))
    return config.lr * warm * cos


# -- grad clipping ---------------------------------------------------------------
def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gnorm


# -- state -----------------------------------------------------------------------
def init_opt_state(params: Any, config: OptimizerConfig) -> dict:
    sdtype = jnp.dtype(config.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, sdtype)
    state = {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if config.master_fp32:
        state["master"] = jax.tree_util.tree_map(
            lambda p: p.astype(jnp.float32), params)
    return state


def adamw_update(params: Any, grads: Any, state: dict,
                 config: OptimizerConfig) -> tuple[Any, dict, dict]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = lr_schedule(step, config)
    if config.grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, config.grad_clip)
    else:
        gnorm = jnp.zeros(())
    b1, b2 = config.b1, config.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    ref = state.get("master", params)

    sdtype = jnp.dtype(config.state_dtype)

    def upd(p_ref, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
        mh, vh = m32 / c1, v32 / c2
        delta = mh / (jnp.sqrt(vh) + config.eps)
        p32 = p_ref.astype(jnp.float32)
        if config.weight_decay > 0 and p_ref.ndim >= 2:
            delta = delta + config.weight_decay * p32
        return p32 - lr * delta, m32.astype(sdtype), v32.astype(sdtype)

    out = jax.tree_util.tree_map(upd, ref, grads, state["m"], state["v"])
    new_ref = jax.tree_util.tree_map(lambda t: t[0], out,
                                     is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[1], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree_util.tree_map(lambda t: t[2], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"m": new_m, "v": new_v, "step": step}
    if "master" in state:
        new_state["master"] = new_ref
        new_params = jax.tree_util.tree_map(
            lambda nr, p: nr.astype(p.dtype), new_ref, params)
    else:
        new_params = jax.tree_util.tree_map(
            lambda nr, p: nr.astype(p.dtype), new_ref, params)
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, new_state, metrics


# -- ZeRO-1 sharding ------------------------------------------------------------
def add_zero_axis(spec: P, shape: tuple[int, ...], mesh: Mesh,
                  axis: str = "data") -> P:
    """Add ``axis`` to the first dimension it divides and that is unsharded.
    Falls back to the original spec when nothing fits (tiny tensors)."""
    if axis not in mesh.axis_names:
        return spec
    used = {a for part in spec if part is not None
            for a in (part if isinstance(part, tuple) else (part,))}
    if axis in used:      # already sharded over it (e.g. FSDP weights)
        return spec
    n = mesh.shape[axis]
    parts = list(spec) + [None] * (len(shape) - len(spec))
    for i, (dim, cur) in enumerate(zip(shape, parts)):
        if cur is None and dim % n == 0 and dim >= n:
            parts[i] = axis
            return P(*parts)
    return spec


def zero1_state_specs(param_specs: Any, param_shapes: Any, mesh: Mesh,
                      config: OptimizerConfig) -> dict:
    """PartitionSpec tree for the optimizer state (ZeRO-1 over 'data', and
    over 'pod' too on the multi-pod mesh — 1T-class configs need both)."""
    def zspec(spec, shape):
        if not config.zero1:
            return spec
        spec = add_zero_axis(spec, shape.shape, mesh, axis="data")
        return add_zero_axis(spec, shape.shape, mesh, axis="pod")

    mz = jax.tree_util.tree_map(zspec, param_specs, param_shapes,
                                is_leaf=lambda x: isinstance(x, P))
    state = {"m": mz, "v": mz, "step": P()}
    if config.master_fp32:
        state["master"] = mz
    return state
