"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8) d_ff=2048
vocab=163840, MoE 384 experts top-8 — trillion-param MoE (paper-table).
Sharding: experts over 'model' (EP) + expert d_model over 'data' (FSDP) +
embeddings FSDP — 1T bf16 params => ~8 GB/chip at 256-way weight sharding
(see EXPERIMENTS.md §Dry-run for measured bytes). [arXiv:2501.kimi2;
unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=2048,                     # per-expert
    vocab_size=163840,
    num_experts=384,
    experts_per_token=8,
    capacity_factor=1.25,
    hidden_act="silu",
    mlp_gated=True,
    norm="rmsnorm",
    rope_theta=50_000.0,
    remat="full",
    sharding_overrides={"expert_in": "data", "embed_fsdp": "data"},
)


def reduced() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=64, num_heads=4,
                          num_kv_heads=2, head_dim=16, d_ff=32,
                          vocab_size=256, num_experts=4,
                          experts_per_token=2, remat="none",
                          sharding_overrides={})
