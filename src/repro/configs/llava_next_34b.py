"""llava-next-34b [vlm] — 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000, anyres tiling (frontend stubbed: precomputed patch embeddings).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    hidden_act="silu",
    mlp_gated=True,
    norm="rmsnorm",
    rope_theta=5_000_000.0,
    num_image_tokens=576,          # one 24x24 anyres tile (stub embeddings)
    remat="full",
    pad_attention_heads=True,   # heads % TP != 0: pad, don't replicate (§Perf A1)
)


def reduced() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=64, num_heads=4,
                          num_kv_heads=2, head_dim=16, d_ff=128,
                          vocab_size=256, num_image_tokens=4, remat="none")
