"""internlm2-1.8b [dense] — 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92544 — SwiGLU, RMSNorm, RoPE. [arXiv:2403.17297; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-1.8b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92544,
    hidden_act="silu",
    mlp_gated=True,
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    remat="full",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=64, num_heads=4,
                          num_kv_heads=2, head_dim=16, d_ff=128,
                          vocab_size=256, remat="none")
