"""granite-moe-3b-a800m [moe] — 32L d_model=1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,                      # per-expert
    vocab_size=49155,
    num_experts=40,
    experts_per_token=8,
    capacity_factor=1.25,
    hidden_act="silu",
    mlp_gated=True,
    norm="rmsnorm",
    tie_embeddings=True,
    rope_theta=10_000.0,
    remat="full",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=64, num_heads=4,
                          num_kv_heads=2, head_dim=16, d_ff=32,
                          vocab_size=256, num_experts=4,
                          experts_per_token=2, remat="none")
