"""minitron-8b [dense] — 32L d_model=4096 32H (GQA kv=8) d_ff=16384
vocab=256000 — pruned nemotron (squared-ReLU MLP, untied 256k vocab).
[arXiv:2407.14679; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=256000,
    hidden_act="relu2",            # nemotron squared ReLU
    mlp_gated=False,
    norm="layernorm",
    rope_theta=10_000.0,
    remat="full",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=64, num_heads=4,
                          num_kv_heads=2, head_dim=16, d_ff=128,
                          vocab_size=256, remat="none")
