"""whisper-medium [audio] — 24L(enc)+24L(dec) d_model=1024 16H (MHA kv=16)
d_ff=4096 vocab=51865 — enc-dec; conv frontend STUB (precomputed frame
embeddings, 1500 frames). ``long_500k`` skipped (full attention).
[arXiv:2212.04356; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    num_layers=24,                 # decoder
    encoder_layers=24,
    encoder_seq=1500,
    is_encoder_decoder=True,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    hidden_act="gelu",
    mlp_gated=False,
    norm="layernorm",
    pos_embedding="learned",
    max_position=32_776,
    tie_embeddings=True,
    remat="full",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(num_layers=2, encoder_layers=2, encoder_seq=12,
                          d_model=64, num_heads=4, num_kv_heads=4,
                          head_dim=16, d_ff=128, vocab_size=256,
                          max_position=128, remat="none")
