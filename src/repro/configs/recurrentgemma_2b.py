"""recurrentgemma-2b [hybrid] — 26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000 — RG-LRU + local attention, pattern (rec, rec, attn),
window 2048, head_dim=256, tied embeddings, logits soft-cap 30.
Runs ``long_500k`` (constant-size recurrent state + rolling window cache).
[arXiv:2402.19427; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    hidden_act="gelu",
    mlp_gated=True,
    norm="rmsnorm",
    norm_offset=True,
    tie_embeddings=True,
    block_pattern=("rec", "rec", "attn"),
    local_window=2048,
    lru_width=2560,
    conv_width=4,
    logits_soft_cap=30.0,
    rope_theta=10_000.0,
    remat="full",
    pad_attention_heads=True,   # heads % TP != 0: pad, don't replicate (§Perf A1)                  # per-layer jax.checkpoint (unrolled)
)


def reduced() -> ModelConfig:
    return CONFIG.replace(num_layers=3, d_model=64, num_heads=4,
                          num_kv_heads=1, head_dim=16, d_ff=128,
                          vocab_size=256, local_window=8, lru_width=64)
