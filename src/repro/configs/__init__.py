"""Architecture registry + per-(arch × shape) input specs.

``ARCHS`` maps the assigned architecture ids to their config modules; every
module exports ``CONFIG`` (exact published numbers) and ``reduced()`` (tiny
same-family smoke variant). ``input_specs`` builds ShapeDtypeStruct stand-ins
for each cell — weak-type-correct, shardable, zero allocation — consumed by
the multi-pod dry-run and roofline harness.
"""
from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp

from repro.configs.base import (SHAPES, SMOKE_SHAPE, ModelConfig,
                                OptimizerConfig, RunConfig, ShapeConfig,
                                applicable_shapes)

ARCHS: dict[str, str] = {
    "llava-next-34b": "repro.configs.llava_next_34b",
    "minitron-8b": "repro.configs.minitron_8b",
    "gemma-7b": "repro.configs.gemma_7b",
    "internlm2-1.8b": "repro.configs.internlm2_1_8b",
    "starcoder2-3b": "repro.configs.starcoder2_3b",
    "whisper-medium": "repro.configs.whisper_medium",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
    "rwkv6-7b": "repro.configs.rwkv6_7b",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t_a32b",
    "granite-moe-3b-a800m": "repro.configs.granite_moe_3b_a800m",
}


def get_config(arch: str, reduced: bool = False) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    mod = importlib.import_module(ARCHS[arch])
    return mod.reduced() if reduced else mod.CONFIG


def all_archs() -> list[str]:
    return list(ARCHS)


def input_specs(config: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStructs for one (arch × shape) cell.

    train/prefill: the token batch (+ modality-stub embeddings);
    decode: a single-token batch + the KV cache / recurrent state struct.
    """
    B, S = shape.global_batch, shape.seq_len
    f32 = jnp.dtype(config.dtype)
    i32 = jnp.int32

    def tok(b, s):
        return jax.ShapeDtypeStruct((b, s), i32)

    if shape.kind in ("train", "prefill"):
        if config.family == "vlm":
            n_img = config.num_image_tokens
            batch = {"tokens": tok(B, S - n_img),
                     "image_embeds": jax.ShapeDtypeStruct(
                         (B, n_img, config.d_model), f32)}
        elif config.family == "audio":
            batch = {"tokens": tok(B, S),
                     "frames": jax.ShapeDtypeStruct(
                         (B, config.encoder_seq, config.d_model), f32)}
        else:
            batch = {"tokens": tok(B, S)}
        return {"batch": batch}

    # decode: one new token against a seq_len-deep cache/state
    from repro.models.registry import get_model
    model = get_model(config)
    cache = jax.eval_shape(lambda: model.init_cache(config, B, S))
    return {"tokens": tok(B, 1), "cache": cache}


def batch_specs_logical(config: ModelConfig, shape: ShapeConfig) -> dict:
    """Logical sharding axes for the input batch (dry-run in_shardings)."""
    if shape.kind in ("train", "prefill"):
        if config.family == "vlm":
            return {"batch": {"tokens": ("batch", "seq"),
                              "image_embeds": ("batch", "seq", "embed")}}
        if config.family == "audio":
            return {"batch": {"tokens": ("batch", "seq"),
                              "frames": ("batch", "frames", "embed")}}
        return {"batch": {"tokens": ("batch", "seq")}}
    from repro.models.registry import get_model
    model = get_model(config)
    return {"tokens": ("batch", "seq"),
            "cache": model.cache_specs(config)}


__all__ = [
    "ARCHS", "SHAPES", "SMOKE_SHAPE", "ModelConfig", "OptimizerConfig",
    "RunConfig", "ShapeConfig", "all_archs", "applicable_shapes",
    "batch_specs_logical", "get_config", "input_specs",
]
