"""starcoder2-3b [dense] — 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152 — GQA, RoPE, LayerNorm, non-gated GELU MLP.
[arXiv:2402.19173; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab_size=49152,
    hidden_act="gelu",
    mlp_gated=False,
    norm="layernorm",
    rope_theta=100_000.0,
    remat="full",
    pad_attention_heads=True,   # heads % TP != 0: pad, don't replicate (§Perf A1)
)


def reduced() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=64, num_heads=4,
                          num_kv_heads=2, head_dim=16, d_ff=128,
                          vocab_size=256, remat="none")
