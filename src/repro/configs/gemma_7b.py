"""gemma-7b [dense] — 28L d_model=3072 16H (MHA kv=16) d_ff=24576
vocab=256000 — GeGLU, head_dim=256, tied embeddings, (1+w) RMSNorm,
sqrt(d) embedding scale. [arXiv:2403.08295; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    num_layers=28,
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    hidden_act="gelu",
    mlp_gated=True,
    norm="rmsnorm",
    norm_offset=True,
    tie_embeddings=True,
    rope_theta=10_000.0,
    remat="full",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=64, num_heads=4,
                          num_kv_heads=4, head_dim=16, d_ff=128,
                          vocab_size=256, remat="none")
