"""Config system: architecture + shape + run configs.

One ``configs/<arch>.py`` per assigned architecture exports ``CONFIG``
(exact published numbers) and ``reduced()`` (a tiny same-family variant for
CPU smoke tests). Shapes are the assigned input-shape set; each arch lists
which shapes apply (``long_500k`` only for sub-quadratic families,
per the assignment).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any

import jax.numpy as jnp


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int                 # query heads (0 => attention-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 => d_model // num_heads
    # layer flavours
    hidden_act: str = "silu"       # silu | gelu | relu2
    mlp_gated: bool = True
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    norm_offset: bool = False      # gemma-style (1 + w) RMSNorm scale
    rope_theta: float = 10_000.0
    pos_embedding: str = "rope"    # rope | learned | none
    logits_soft_cap: float = 0.0
    tie_embeddings: bool = False
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.01
    # hybrid / recurrent
    block_pattern: tuple[str, ...] = ("attn",)   # cycled over layers
    local_window: int = 0          # sliding-window size for local_attn blocks
    lru_width: int = 0             # RG-LRU state width
    conv_width: int = 4
    # ssm (rwkv)
    rwkv_chunk: int = 16
    decay_lora: int = 64
    # enc-dec (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0           # precomputed frame-embedding length
    is_encoder_decoder: bool = False
    # vlm (llava)
    num_image_tokens: int = 0
    # numerics / execution
    dtype: str = "bfloat16"        # activation/compute dtype
    param_dtype: str = "bfloat16"
    remat: str = "none"            # none | full | dots
    scan_layers: bool = True
    attention_impl: str = "blocked"  # blocked | naive | pallas | triangular
    pad_attention_heads: bool = False  # pad H to the TP degree (see §Perf)
    attention_block_q: int = 512
    attention_block_kv: int = 1024
    # sharding rule overrides (logical -> mesh axes)
    sharding_overrides: dict = field(default_factory=dict, hash=False, compare=False)
    # max positions for learned embeddings
    max_position: int = 0

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def is_subquadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


# The assigned shape set (identical across the LM pool).
SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

SMOKE_SHAPE = ShapeConfig("smoke", 64, 2, "train")


def applicable_shapes(config: ModelConfig) -> list[str]:
    """Which assigned shapes run for this arch (skips recorded in DESIGN.md)."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if config.is_subquadratic:
        names.append("long_500k")
    return names


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    zero1: bool = True             # shard optimizer state over 'data'(+pod)
    master_fp32: bool = True
    state_dtype: str = "float32"   # m/v moments dtype (bf16 for 1T configs)
    compression: str | None = None  # int8 gradient compression (DP path)


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    optimizer: OptimizerConfig = OptimizerConfig()
    seed: int = 0
    checkpoint_dir: str | None = None
    checkpoint_every: int = 100
