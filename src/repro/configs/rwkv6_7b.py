"""rwkv6-7b [ssm] — 32L d_model=4096 (attention-free, 64 heads x 64)
d_ff=14336 vocab=65536 — Finch: data-dependent decay. Runs ``long_500k``
(constant-size state, no KV cache). [arXiv:2404.05892; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=64,
    num_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab_size=65536,
    norm="layernorm",
    pos_embedding="none",
    rwkv_chunk=16,
    decay_lora=64,
    remat="full",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=64, num_heads=4,
                          num_kv_heads=4, head_dim=16, d_ff=128,
                          vocab_size=256, decay_lora=8, rwkv_chunk=4,
                          remat="none")
