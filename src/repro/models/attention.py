"""Attention: GQA/MQA/MHA, RoPE, causal + sliding-window + cross, three impls.

* ``naive``   — full score matrix; oracle for tests and small shapes.
* ``blocked`` — double-scan online-softmax (flash-style dataflow in pure JAX):
                O(block_q × block_kv) live scores, exact same math. This is
                the default for dry-runs/long sequences on any backend.
* ``pallas``  — the TPU flash kernel in ``kernels/flash_attention`` (same
                blocking, VMEM-resident); validated against ``naive`` in
                interpret mode, selected via ``attention_impl='pallas'``.

GQA: K/V are repeated to the full H query heads *after* projection (and
after RoPE), keeping every attention tensor 4-D (B, S, H, hd) — the only
layout where TP-by-heads shards cleanly under GSPMD (a grouped 5-D
(B, S, KH, G, hd) layout splits the 'model' axis across two dims, which
GSPMD cannot express; it then invents cross-shard contractions — observed
as per-tile all-reduces in the dry-run, see EXPERIMENTS.md §Perf). The
repeat is free on the wire (slices locally) and the Pallas kernel avoids
the HBM copy on real hardware.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, normal_init, split_keys
from repro.parallel.sharding import logical_constraint

NEG_INF = -1e30


# -- params --------------------------------------------------------------------
def init_attention(key: jax.Array, config: ModelConfig, dtype: Any,
                   cross: bool = False, num_heads: int | None = None,
                   num_kv_heads: int | None = None) -> tuple[dict, dict]:
    d = config.d_model
    h = num_heads or config.num_heads
    kh = num_kv_heads or config.num_kv_heads
    hd = config.resolved_head_dim
    k1, k2, k3, k4 = split_keys(key, 4)
    std = 1.0 / np.sqrt(d)
    std_o = 1.0 / np.sqrt(h * hd) / np.sqrt(2.0 * config.num_layers)
    params = {
        "wq": normal_init(k1, (d, h * hd), std, dtype),
        "wk": normal_init(k2, (d, kh * hd), std, dtype),
        "wv": normal_init(k3, (d, kh * hd), std, dtype),
        "wo": normal_init(k4, (h * hd, d), std_o, dtype),
    }
    specs = {"wq": ("embed_fsdp", "heads"), "wk": ("embed_fsdp", "kv_heads"),
             "wv": ("embed_fsdp", "kv_heads"), "wo": ("heads", "embed_fsdp")}
    return params, specs


# -- masking ---------------------------------------------------------------
def _pair_mask(qpos: jax.Array, kpos: jax.Array, causal: bool,
               window: int) -> jax.Array:
    """(B, Sq, Skv) boolean mask. kpos < 0 marks padding/invalid slots."""
    valid = kpos[:, None, :] >= 0
    if causal:
        valid &= kpos[:, None, :] <= qpos[:, :, None]
    if window > 0:
        valid &= qpos[:, :, None] - kpos[:, None, :] < window
    return valid


def _split_heads(x: jax.Array, n: int, hd: int) -> jax.Array:
    return x.reshape(x.shape[:-1] + (n, hd))


# -- naive (oracle) -----------------------------------------------------------
def naive_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    qpos: jax.Array, kpos: jax.Array,
                    causal: bool = True, window: int = 0) -> jax.Array:
    """q, k, v: (B, S, H, hd) (KV already repeated) -> (B, Sq, H, hd)."""
    hd = q.shape[-1]
    scale = 1.0 / np.sqrt(hd)
    s = jnp.einsum("bqhd,bshd->bhqs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    mask = _pair_mask(qpos, kpos, causal, window)            # (B,Sq,Skv)
    s = jnp.where(mask[:, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqs,bshd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


# -- blocked (flash dataflow, pure JAX) --------------------------------------
def blocked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      qpos: jax.Array, kpos: jax.Array,
                      causal: bool = True, window: int = 0,
                      block_q: int = 512, block_kv: int = 1024,
                      skip_blocks: bool = False) -> jax.Array:
    """Online-softmax over (q-block × kv-block) tiles via nested lax.scan.

    ``skip_blocks=True`` enables the triangular schedule: kv blocks entirely
    above the causal diagonal (or outside the sliding window) contribute a
    zero-FLOP branch via lax.cond — the §Perf causal-skipping optimization.
    """
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    scale = 1.0 / np.sqrt(hd)
    bq = min(block_q, Sq)
    bkv = min(block_kv, Skv)
    nq, nk = -(-Sq // bq), -(-Skv // bkv)
    pq, pk = nq * bq - Sq, nk * bkv - Skv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq)) + ((0, 0),) * 2)
        qpos = jnp.pad(qpos, ((0, 0), (0, pq)), constant_values=-1)
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, ((0, 0), (0, pk)), constant_values=-1)

    # (n, B, blk, ...) layouts for scan
    qb = q.reshape(B, nq, bq, H, hd).transpose(1, 0, 2, 3, 4)
    qpb = qpos.reshape(B, nq, bq).transpose(1, 0, 2)
    kb = k.reshape(B, nk, bkv, H, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nk, bkv, H, hd).transpose(1, 0, 2, 3, 4)
    kpb = kpos.reshape(B, nk, bkv).transpose(1, 0, 2)

    def q_step(_, qc):
        q_i, qp_i, qi = qc
        m0 = jnp.full((B, H, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, bq), jnp.float32)
        a0 = jnp.zeros((B, bq, H, hd), jnp.float32)

        def tile(q_i, qp_i, k_j, v_j, kp_j, m, l, acc):
            s = jnp.einsum("bqhd,bshd->bhqs", q_i.astype(jnp.float32),
                           k_j.astype(jnp.float32)) * scale
            mask = _pair_mask(qp_i, kp_j, causal, window)
            s = jnp.where(mask[:, None, :, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhqs,bshd->bqhd", p, v_j.astype(jnp.float32))
            acc_new = acc * alpha.transpose(0, 2, 1)[..., None] + pv
            return m_new, l_new, acc_new

        def kv_step(carry, kc):
            m, l, acc = carry
            k_j, v_j, kp_j, kj = kc
            if skip_blocks:
                # Block-level reachability from static block layout:
                # any (q,k) pair in-tile can be unmasked?
                q_lo = qi * bq
                k_lo, k_hi = kj * bkv, kj * bkv + bkv - 1
                reachable = jnp.asarray(True)
                if causal:  # kv block entirely in the future -> skip
                    q_hi = qi * bq + bq - 1
                    reachable = k_lo <= q_hi
                if window > 0:  # kv block entirely before the window -> skip
                    reachable = jnp.logical_and(reachable,
                                                q_lo - k_hi < window)
                m, l, acc = jax.lax.cond(
                    reachable,
                    lambda m, l, acc: tile(q_i, qp_i, k_j, v_j, kp_j, m, l, acc),
                    lambda m, l, acc: (m, l, acc),
                    m, l, acc)
            else:
                m, l, acc = tile(q_i, qp_i, k_j, v_j, kp_j, m, l, acc)
            return (m, l, acc), None

        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kb, vb, kpb, jnp.arange(nk)))
        lt = l.transpose(0, 2, 1)[..., None]
        out_i = acc / jnp.maximum(lt, 1e-30)
        return None, out_i.astype(q_i.dtype)

    _, out = jax.lax.scan(q_step, None, (qb, qpb, jnp.arange(nq)))
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, nq * bq, H, hd)
    return out[:, :Sq]


# -- triangular schedule (flattened causal block sweep) -----------------------
def triangular_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                         qpos: jax.Array, kpos: jax.Array,
                         causal: bool = True, window: int = 0,
                         block: int = 512) -> jax.Array:
    """Causal blocked attention that only *issues* reachable tiles.

    The rectangular double-scan masks unreachable (q, kv) tiles but still
    executes their FLOPs; this schedule flattens the valid tile list —
    n(n+1)/2 instead of n² for causal, fewer still with a window — into ONE
    scan, so the savings are structural (visible to the HLO walker / real on
    hardware). §Perf optimization for compute-bound prefill/train cells.
    """
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    assert Sq == Skv, "triangular schedule is for self-attention"
    b = min(block, Sq)
    n = -(-Sq // b)
    pad = n * b - Sq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        qpos = jnp.pad(qpos, ((0, 0), (0, pad)), constant_values=-1)
        kpos = jnp.pad(kpos, ((0, 0), (0, pad)), constant_values=-1)
    scale = 1.0 / np.sqrt(hd)
    qb = q.reshape(B, n, b, H, hd).transpose(1, 0, 2, 3, 4)
    kb = k.reshape(B, n, b, H, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, n, b, H, hd).transpose(1, 0, 2, 3, 4)
    qpb = qpos.reshape(B, n, b).transpose(1, 0, 2)
    kpb = kpos.reshape(B, n, b).transpose(1, 0, 2)

    pairs = [(qi, ki) for qi in range(n) for ki in range(qi + 1)
             if window <= 0 or qi * b - (ki * b + b - 1) < window]
    pair_q = jnp.asarray([p[0] for p in pairs], jnp.int32)
    pair_k = jnp.asarray([p[1] for p in pairs], jnp.int32)

    m0 = jnp.full((n, B, H, b), NEG_INF, jnp.float32)
    l0 = jnp.zeros((n, B, H, b), jnp.float32)
    a0 = jnp.zeros((n, B, b, H, hd), jnp.float32)

    def step(carry, pair):
        m, l, acc = carry
        qi, ki = pair
        q_i = jax.lax.dynamic_index_in_dim(qb, qi, 0, keepdims=False)
        qp_i = jax.lax.dynamic_index_in_dim(qpb, qi, 0, keepdims=False)
        k_j = jax.lax.dynamic_index_in_dim(kb, ki, 0, keepdims=False)
        v_j = jax.lax.dynamic_index_in_dim(vb, ki, 0, keepdims=False)
        kp_j = jax.lax.dynamic_index_in_dim(kpb, ki, 0, keepdims=False)
        s = jnp.einsum("bqhd,bshd->bhqs", q_i.astype(jnp.float32),
                       k_j.astype(jnp.float32)) * scale
        mask = _pair_mask(qp_i, kp_j, causal, window)
        s = jnp.where(mask[:, None, :, :], s, NEG_INF)
        m_i = jax.lax.dynamic_index_in_dim(m, qi, 0, keepdims=False)
        l_i = jax.lax.dynamic_index_in_dim(l, qi, 0, keepdims=False)
        a_i = jax.lax.dynamic_index_in_dim(acc, qi, 0, keepdims=False)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_i - m_new)
        l_new = l_i * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhqs,bshd->bqhd", p, v_j.astype(jnp.float32))
        a_new = a_i * alpha.transpose(0, 2, 1)[..., None] + pv
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, qi, 0)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, qi, 0)
        acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, qi, 0)
        return (m, l, acc), None

    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (pair_q, pair_k))
    lt = l.transpose(0, 1, 3, 2)[..., None]                 # (n,B,b,H,1)
    out = (acc / jnp.maximum(lt, 1e-30)).astype(q.dtype)
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, n * b, H, hd)
    return out[:, :Sq]


# -- dispatch -------------------------------------------------------------------
def attention_core(q: jax.Array, k: jax.Array, v: jax.Array,
                   qpos: jax.Array, kpos: jax.Array, config: ModelConfig,
                   causal: bool = True, window: int = 0) -> jax.Array:
    impl = config.attention_impl
    Sq = q.shape[1]
    if impl == "pallas" and causal and window == 0 and Sq > 1:
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention(q, k, v, qpos, kpos)
    if impl == "naive" or Sq == 1 or q.shape[1] <= config.attention_block_q:
        return naive_attention(q, k, v, qpos, kpos, causal, window)
    if impl == "triangular" and causal and Sq == k.shape[1]:
        return triangular_attention(q, k, v, qpos, kpos, causal, window,
                                    block=config.attention_block_q)
    return blocked_attention(
        q, k, v, qpos, kpos, causal, window,
        block_q=config.attention_block_q, block_kv=config.attention_block_kv,
        skip_blocks=config.sharding_overrides.get("_skip_blocks", False))


def attention_layer(x: jax.Array, params: dict, config: ModelConfig,
                    positions: jax.Array,
                    cache: dict | None = None,
                    kv_source: jax.Array | None = None,
                    precomputed_kv: tuple[jax.Array, jax.Array] | None = None,
                    causal: bool = True, window: int = 0,
                    num_heads: int | None = None,
                    num_kv_heads: int | None = None
                    ) -> tuple[jax.Array, dict | None]:
    """Full attention layer: qkv proj, rope, core, out proj.

    ``cache`` (decode/prefill): dict with 'k','v' (B, Smax, KH, hd) rolling
    buffers and scalar 'pos' (tokens already cached). ``kv_source`` switches
    to cross-attention (keys/values projected from encoder output);
    ``precomputed_kv`` reuses cached cross K/V at decode time.
    """
    B, S, _ = x.shape
    h = num_heads or config.num_heads
    kh = num_kv_heads or config.num_kv_heads
    hd = config.resolved_head_dim
    g = h // kh
    dtype = x.dtype

    q = _split_heads(x @ params["wq"].astype(dtype), h, hd)
    if precomputed_kv is not None:
        k, v = precomputed_kv
    else:
        src = x if kv_source is None else kv_source
        k = _split_heads(src @ params["wk"].astype(dtype), kh, hd)
        v = _split_heads(src @ params["wv"].astype(dtype), kh, hd)

    cross = kv_source is not None or precomputed_kv is not None
    if config.pos_embedding == "rope" and not cross:
        q = apply_rope(q, positions, config.rope_theta)
        k = apply_rope(k, positions, config.rope_theta)

    q = logical_constraint(q, "batch", "seq", "heads", "head_dim")
    k = logical_constraint(k, "batch", "seq", "kv_heads", "head_dim")
    v = logical_constraint(v, "batch", "seq", "kv_heads", "head_dim")

    # Head padding: when the TP degree does not divide H (llava 56/16,
    # starcoder2 24/16, rgemma 10/16), unsharded heads would REPLICATE the
    # whole attention computation on every model shard. Padding H to the
    # next multiple trades (H'/H - 1) extra FLOPs for a 1/m shard — e.g.
    # llava: 64/56 = 1.14x work instead of 16x. §Perf optimization.
    from repro.parallel.sharding import current_mesh
    mesh = current_mesh()
    m = (mesh.shape.get("model", 1) if mesh is not None else 1)
    pad_h = (-h) % m if (config.pad_attention_heads and m > 1) else 0
    if pad_h:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_h), (0, 0)))
        q = logical_constraint(q, "batch", "seq", "heads", "head_dim")

    def rep(t):
        # repeat KV to full H heads (4-D TP-by-heads layout; see module doc)
        t = jnp.repeat(t, g, axis=2) if g > 1 else t
        if pad_h:
            t = jnp.pad(t, ((0, 0), (0, 0), (0, pad_h), (0, 0)))
        return logical_constraint(t, "batch", "seq", "heads", "head_dim")

    new_cache = None
    if cross:
        # cross attention: all encoder positions visible
        kpos = jnp.broadcast_to(jnp.arange(k.shape[1]), (B, k.shape[1]))
        out = attention_core(q, rep(k), rep(v), positions, kpos, config,
                             causal=False, window=0)
        new_cache = {"k": k, "v": v}
    elif cache is None:
        out = attention_core(q, rep(k), rep(v), positions, positions, config,
                             causal=causal, window=window)
    elif S > 1:
        # prefill: attend over the fresh sequence, then fill the cache
        out = attention_core(q, rep(k), rep(v), positions, positions, config,
                             causal=causal, window=window)
        ck, cv, pos = cache["k"], cache["v"], cache["pos"]
        Smax = ck.shape[1]
        if window > 0 and S >= Smax:
            # keep the last window, rotated so slot(p) == p % Smax
            shift = (S - Smax) % Smax
            ck = jnp.roll(k[:, S - Smax:].astype(ck.dtype), shift, axis=1)
            cv = jnp.roll(v[:, S - Smax:].astype(cv.dtype), shift, axis=1)
        else:
            ck = jax.lax.dynamic_update_slice(
                ck, k[:, :Smax].astype(ck.dtype), (0, pos, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cv, v[:, :Smax].astype(cv.dtype), (0, pos, 0, 0))
        new_cache = {"k": ck, "v": cv, "pos": pos + S}
    else:
        # decode with rolling-buffer cache (window archs wrap in-place)
        ck, cv, pos = cache["k"], cache["v"], cache["pos"]
        Smax = ck.shape[1]
        slot = (pos % Smax) if window > 0 else jnp.minimum(pos, Smax - 1)
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                          (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                          (0, slot, 0, 0))
        # absolute positions of cache slots; -1 marks not-yet-filled
        idx = jnp.arange(Smax)
        if window > 0:
            abs_pos = idx + ((pos - idx) // Smax) * Smax
            kpos_row = jnp.where((abs_pos >= 0) & (abs_pos <= pos),
                                 abs_pos, -1)
        else:
            kpos_row = jnp.where(idx <= pos, idx, -1)
        kpos = jnp.broadcast_to(kpos_row, (B, Smax))
        out = attention_core(q, rep(ck), rep(cv), positions, kpos, config,
                             causal=True, window=window)
        new_cache = {"k": ck, "v": cv, "pos": pos + 1}

    if pad_h:
        out = out[:, :, :h]
    out = out.reshape(B, S, h * hd)
    out = out @ params["wo"].astype(dtype)
    return out, new_cache


def init_cache(config: ModelConfig, batch: int, max_len: int,
               window: int = 0, dtype: Any = None,
               num_kv_heads: int | None = None) -> dict:
    kh = num_kv_heads or config.num_kv_heads
    hd = config.resolved_head_dim
    size = min(window, max_len) if window > 0 else max_len
    dtype = dtype or config.activation_dtype
    return {
        "k": jnp.zeros((batch, size, kh, hd), dtype),
        "v": jnp.zeros((batch, size, kh, hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


CACHE_SPECS = {"k": ("batch", "null", "kv_heads", "head_dim"),
               "v": ("batch", "null", "kv_heads", "head_dim"),
               "pos": ()}
