"""Model registry: family -> module implementing the model API.

API per family module:
    init(key, config) -> params
    param_specs(config) -> logical-axis spec pytree (matches params)
    loss_and_metrics(params, batch, config) -> (loss, metrics)
    prefill(params, batch, config, max_len) -> (last_logits, cache)
    decode_step(params, tokens, cache, config) -> (logits, cache)
    init_cache(config, batch, max_len) -> cache
    cache_specs(config) -> logical-axis spec pytree (matches cache)
"""
from __future__ import annotations

from types import ModuleType

from repro.configs.base import ModelConfig
from repro.models import rglru, rwkv6, transformer, whisper

_FAMILIES: dict[str, ModuleType] = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "audio": whisper,
    "ssm": rwkv6,
    "hybrid": rglru,
}


def get_model(config: ModelConfig) -> ModuleType:
    try:
        return _FAMILIES[config.family]
    except KeyError:
        raise ValueError(f"unknown model family {config.family!r}") from None
