"""Model substrate: the 10 assigned architectures across 6 families."""
