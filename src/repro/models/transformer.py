"""Decoder-only transformer LM: dense / MoE / VLM families.

Production-shape choices:
  * layers scanned over a stacked (L, ...) param pytree (small HLO, fast
    compile even for the 61-layer 1T MoE);
  * remat policies: none | dots | full (jax.checkpoint around the scanned
    block);
  * chunked cross-entropy: the (B, S, 256k-vocab) logits tensor is never
    materialized — the loss scans over sequence chunks and remats the
    lm-head matmul in the backward pass (memory <-> flops trade recorded in
    §Perf);
  * serve path: ``prefill`` returns last-token logits + a filled KV cache,
    ``decode_step`` appends one token (rolling-buffer for window attention).

VLM (llava-family): precomputed image patch embeddings (the stubbed anyres
frontend) are prepended to token embeddings; loss masks image positions.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.parallel.sharding import logical_constraint


# -- init ------------------------------------------------------------------
def _init_block(key: jax.Array, config: ModelConfig, dtype: Any) -> dict:
    k_attn, k_mlp, k_n1, k_n2 = L.split_keys(key, 4)
    params = {}
    params["attn"], _ = attn.init_attention(k_attn, config, dtype)
    if config.num_experts > 0:
        params["moe"], _ = moe_lib.init_moe(k_mlp, config, dtype)
    else:
        params["mlp"], _ = L.init_mlp(k_mlp, config, dtype)
    params["norm1"], _ = L.init_norm(config, dtype)
    params["norm2"], _ = L.init_norm(config, dtype)
    return params


def _block_specs(config: ModelConfig) -> dict:
    attn_s = {"wq": ("embed_fsdp", "heads"),
              "wk": ("embed_fsdp", "kv_heads"),
              "wv": ("embed_fsdp", "kv_heads"),
              "wo": ("heads", "embed_fsdp")}
    specs: dict = {"attn": attn_s}
    if config.num_experts > 0:
        ax = ("experts_a2a" if config.sharding_overrides.get("_moe_impl")
              == "a2a" else "experts")
        in_ax = "null" if ax == "experts_a2a" else "expert_in"
        specs["moe"] = {"router": ("embed", "null"),
                        "w_gate": (ax, in_ax, "ff"),
                        "w_up": (ax, in_ax, "ff"),
                        "w_down": (ax, "ff", in_ax)}
    else:
        mlp_s = {"w_up": ("embed_fsdp", "ff"), "w_down": ("ff", "embed_fsdp")}
        if config.mlp_gated:
            mlp_s["w_gate"] = ("embed_fsdp", "ff")
        specs["mlp"] = mlp_s
    norm_s = ({"scale": ("embed",), "bias": ("embed",)}
              if config.norm == "layernorm" else {"scale": ("embed",)})
    specs["norm1"] = dict(norm_s)
    specs["norm2"] = dict(norm_s)
    return specs


def init(key: jax.Array, config: ModelConfig) -> dict:
    dtype = jnp.dtype(config.param_dtype)
    k_embed, k_layers, k_final = L.split_keys(key, 3)
    embed, _ = L.init_embedding(k_embed, config, dtype)
    layer_keys = jax.random.split(k_layers, config.num_layers)
    layers = jax.vmap(lambda k: _init_block(k, config, dtype))(layer_keys)
    final_norm, _ = L.init_norm(config, dtype)
    return {"embed": embed, "layers": layers, "final_norm": final_norm}


def param_specs(config: ModelConfig) -> dict:
    embed_s = {"tok": ("vocab", "embed_fsdp")}
    if config.pos_embedding == "learned":
        embed_s["pos"] = ("null", "embed_fsdp")
    if not config.tie_embeddings:
        embed_s["lm_head"] = ("embed_fsdp", "vocab")
    block = _block_specs(config)
    layers = jax.tree_util.tree_map(
        lambda axes: ("layers",) + axes, block,
        is_leaf=lambda x: isinstance(x, tuple))
    final_s = ({"scale": ("embed",), "bias": ("embed",)}
               if config.norm == "layernorm" else {"scale": ("embed",)})
    return {"embed": embed_s, "layers": layers, "final_norm": final_s}


# -- one transformer block -----------------------------------------------------
def _block(x: jax.Array, block_params: dict, config: ModelConfig,
           positions: jax.Array, cache: dict | None
           ) -> tuple[jax.Array, jax.Array, dict | None]:
    h = L.apply_norm(x, block_params["norm1"], config)
    a, new_cache = attn.attention_layer(h, block_params["attn"], config,
                                        positions, cache=cache)
    x = x + a
    x = logical_constraint(x, "batch", "act_seq", "embed")
    h = L.apply_norm(x, block_params["norm2"], config)
    if config.num_experts > 0:
        if config.sharding_overrides.get("_moe_impl") == "a2a":
            m, aux = moe_lib.moe_layer_a2a(h, block_params["moe"], config)
        else:
            m, aux = moe_lib.moe_layer(h, block_params["moe"], config)
    else:
        m, aux = L.mlp(h, block_params["mlp"], config), jnp.zeros((), jnp.float32)
    x = x + m
    x = logical_constraint(x, "batch", "act_seq", "embed")
    return x, aux, new_cache


def _remat(fn, config: ModelConfig):
    if config.remat == "none":
        return fn
    if config.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def _run_layers(x: jax.Array, params: dict, config: ModelConfig,
                positions: jax.Array, cache: dict | None
                ) -> tuple[jax.Array, jax.Array, dict | None]:
    """Scan (or unroll) the stacked blocks; threads per-layer cache slices."""
    layers = params["layers"]
    pos_scalar = None if cache is None else cache["pos"]

    if config.scan_layers:
        def body(carry, xs):
            x, aux = carry
            if cache is None:
                block_params = xs
                layer_cache = None
            else:
                block_params, ck, cv = xs
                layer_cache = {"k": ck, "v": cv, "pos": pos_scalar}
            x, aux_i, new_cache = _block(x, block_params, config,
                                         positions, layer_cache)
            ys = (new_cache["k"], new_cache["v"]) if cache is not None else None
            return (x, aux + aux_i), ys

        body = _remat(body, config)
        xs = layers if cache is None else (layers, cache["k"], cache["v"])
        (x, aux), ys = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
        new_cache = None
        if cache is not None:
            new_cache = {"k": ys[0], "v": ys[1],
                         "pos": pos_scalar + positions.shape[1]}
        return x, aux, new_cache

    aux = jnp.zeros((), jnp.float32)
    new_k, new_v = [], []
    for i in range(config.num_layers):
        block_params = jax.tree_util.tree_map(lambda p: p[i], layers)
        layer_cache = None
        if cache is not None:
            layer_cache = {"k": cache["k"][i], "v": cache["v"][i],
                           "pos": pos_scalar}
        x, aux_i, nc = _block(x, block_params, config, positions, layer_cache)
        aux = aux + aux_i
        if nc is not None:
            new_k.append(nc["k"])
            new_v.append(nc["v"])
    new_cache = None
    if cache is not None:
        new_cache = {"k": jnp.stack(new_k), "v": jnp.stack(new_v),
                     "pos": pos_scalar + positions.shape[1]}
    return x, aux, new_cache


# -- input embedding (dense + vlm) ------------------------------------------
def _embed_inputs(params: dict, batch: dict, config: ModelConfig,
                  start_pos: jax.Array | int = 0
                  ) -> tuple[jax.Array, jax.Array]:
    tokens = batch["tokens"]
    B = tokens.shape[0]
    x = L.embed_tokens(tokens, params["embed"], config)
    if config.family == "vlm" and "image_embeds" in batch:
        img = batch["image_embeds"].astype(x.dtype)
        x = jnp.concatenate([img, x], axis=1)
    S = x.shape[1]
    positions = start_pos + jnp.broadcast_to(jnp.arange(S), (B, S))
    if config.pos_embedding == "learned":
        x = x + params["embed"]["pos"].astype(x.dtype)[positions]
    x = logical_constraint(x, "batch", "act_seq", "embed")
    return x, positions


# -- losses ---------------------------------------------------------------------
def _chunked_ce(x: jax.Array, params: dict, config: ModelConfig,
                targets: jax.Array, mask: jax.Array,
                chunk: int = 128) -> jax.Array:
    """Cross-entropy without materializing (B, S, V): scan over seq chunks,
    remat the lm-head matmul inside each chunk."""
    B, S, D = x.shape
    n = -(-S // chunk)
    pad = n * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    xb = x.reshape(B, n, chunk, D).transpose(1, 0, 2, 3)
    tb = targets.reshape(B, n, chunk).transpose(1, 0, 2)
    mb = mask.reshape(B, n, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_fn(carry, xs):
        xc, tc, mc = xs
        logits = L.lm_logits(xc, params["embed"], config)
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        tl = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        nll = (logz - tl) * mc.astype(jnp.float32)
        loss_sum, mask_sum = carry
        return (loss_sum + jnp.sum(nll),
                mask_sum + jnp.sum(mc.astype(jnp.float32))), None

    (loss_sum, mask_sum), _ = jax.lax.scan(
        chunk_fn, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xb, tb, mb))
    return loss_sum / jnp.maximum(mask_sum, 1.0)


def loss_and_metrics(params: dict, batch: dict, config: ModelConfig
                     ) -> tuple[jax.Array, dict]:
    tokens = batch["tokens"]
    x, positions = _embed_inputs(params, batch, config)
    x, aux, _ = _run_layers(x, params, config, positions, None)
    x = L.apply_norm(x, params["final_norm"], config)

    n_img = x.shape[1] - tokens.shape[1]          # 0 unless vlm
    # positions n_img + t predict token t+1
    pred = x[:, n_img:-1] if n_img == 0 else x[:, n_img - 1:-1]
    targets = tokens[:, 1:] if n_img == 0 else tokens
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones(targets.shape, jnp.float32)
    else:
        mask = mask[:, 1:] if n_img == 0 else mask
    loss = _chunked_ce(pred, params, config, targets, mask)
    total = loss + aux
    return total, {"loss": loss, "aux_loss": aux}


# -- serving -------------------------------------------------------------------
def init_cache(config: ModelConfig, batch: int, max_len: int) -> dict:
    window = config.local_window
    size = min(window, max_len) if window > 0 else max_len
    kh, hd = config.num_kv_heads, config.resolved_head_dim
    dtype = config.activation_dtype
    Lc = config.num_layers
    return {"k": jnp.zeros((Lc, batch, size, kh, hd), dtype),
            "v": jnp.zeros((Lc, batch, size, kh, hd), dtype),
            "pos": jnp.zeros((), jnp.int32)}


def cache_specs(config: ModelConfig) -> dict:
    kv = ("layers", "batch", "null", "kv_heads", "head_dim")
    return {"k": kv, "v": kv, "pos": ()}


def prefill(params: dict, batch: dict, config: ModelConfig,
            max_len: int | None = None) -> tuple[jax.Array, dict]:
    """Run the full prompt, fill the cache, return last-token logits."""
    tokens = batch["tokens"]
    B = tokens.shape[0]
    x, positions = _embed_inputs(params, batch, config)
    S_total = x.shape[1]
    cache = init_cache(config, B, max_len or S_total)
    x, _, cache = _run_layers(x, params, config, positions, cache)
    x = L.apply_norm(x, params["final_norm"], config)
    logits = L.lm_logits(x[:, -1:], params["embed"], config)
    return logits, cache


def decode_step(params: dict, tokens: jax.Array, cache: dict,
                config: ModelConfig) -> tuple[jax.Array, dict]:
    """tokens: (B, 1) -> (logits (B,1,V), updated cache)."""
    x, positions = _embed_inputs(params, {"tokens": tokens}, config,
                                 start_pos=cache["pos"])
    x, _, cache = _run_layers(x, params, config, positions, cache)
    x = L.apply_norm(x, params["final_norm"], config)
    logits = L.lm_logits(x, params["embed"], config)
    return logits, cache
