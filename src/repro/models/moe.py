"""Mixture-of-Experts layer: top-k routing, capacity-based sort dispatch, EP.

TPU adaptation notes (DESIGN.md §2): there are no per-token atomics, so the
dispatch is restructured as dense, statically-shaped tensor ops —

  1. router: (T, D) @ (D, E) -> top-k gates/indices (fp32 softmax);
  2. position-in-expert via *sorted ranks* (argsort + searchsorted), which is
     O(T·k log) memory-lean versus the O(T·k·E) one-hot cumsum;
  3. scatter into an (E, C, D) capacity buffer (tokens over capacity drop —
     Switch-style; C = T·k/E · capacity_factor);
  4. batched expert matmuls einsum('ecd,edf->ecf') — MXU-shaped;
  5. gather-weighted combine back to (T, D).

Sharding: expert dim 'experts'->'model' (EP); capacity dim 'expert_cap'->
'data' keeps each data shard's tokens in its own capacity slice; for the 1T
config the expert weights additionally shard d_model over 'data'
('expert_in'->'data'), i.e. FSDP — XLA inserts the per-layer all-gather.
The router aux loss (load-balancing) follows Switch/GShard.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import activation, normal_init, split_keys
from repro.parallel.sharding import logical_constraint
from repro.utils import shard_map_compat


def padded_experts(config: ModelConfig) -> int:
    """The a2a path pads E to a multiple of the device count so each device
    owns whole experts (e.g. kimi: 384 -> 512 on 256 chips)."""
    pad_to = int(config.sharding_overrides.get("_moe_pad_experts", 0))
    if pad_to and config.sharding_overrides.get("_moe_impl") == "a2a":
        return -(-config.num_experts // pad_to) * pad_to
    return config.num_experts


def init_moe(key: jax.Array, config: ModelConfig, dtype: Any) -> tuple[dict, dict]:
    d, f = config.d_model, config.d_ff
    e = padded_experts(config)
    k1, k2, k3, k4 = split_keys(key, 4)
    std_in = 1.0 / np.sqrt(d)
    std_out = 1.0 / np.sqrt(f) / np.sqrt(2.0 * config.num_layers)
    params = {
        "router": normal_init(k1, (d, config.num_experts), std_in,
                              jnp.float32),
        "w_gate": normal_init(k2, (e, d, f), std_in, dtype),
        "w_up": normal_init(k3, (e, d, f), std_in, dtype),
        "w_down": normal_init(k4, (e, f, d), std_out, dtype),
    }
    ax = ("experts_a2a" if config.sharding_overrides.get("_moe_impl") ==
          "a2a" else "experts")
    in_ax = ("null" if ax == "experts_a2a" else "expert_in")
    specs = {
        "router": ("embed", "null"),
        "w_gate": (ax, in_ax, "ff"),
        "w_up": (ax, in_ax, "ff"),
        "w_down": (ax, "ff", in_ax),
    }
    return params, specs


def _positions_in_expert(expert_idx: jax.Array, num_experts: int) -> jax.Array:
    """Rank of each routed slot within its expert, via stable sort.

    expert_idx: (N,) int32 -> (N,) int32 position (0-based) among slots
    routed to the same expert, ordered by original index.
    """
    n = expert_idx.shape[0]
    order = jnp.argsort(expert_idx, stable=True)              # (N,)
    sorted_e = expert_idx[order]
    first = jnp.searchsorted(sorted_e, jnp.arange(num_experts),
                             side="left")                     # (E,)
    pos_sorted = jnp.arange(n, dtype=jnp.int32) - first[sorted_e]
    pos = jnp.zeros((n,), jnp.int32).at[order].set(pos_sorted)
    return pos


def moe_layer(x: jax.Array, params: dict, config: ModelConfig
              ) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, aux_loss)."""
    B, S, D = x.shape
    E, K = config.num_experts, config.experts_per_token
    T = B * S
    xt = x.reshape(T, D)

    # -- router (fp32) ----------------------------------------------------
    logits = xt.astype(jnp.float32) @ params["router"]          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, top_idx = jax.lax.top_k(probs, K)                    # (T, K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balance aux loss
    density = jnp.mean(jax.nn.one_hot(top_idx[:, 0], E, dtype=jnp.float32),
                       axis=0)
    router_mean = jnp.mean(probs, axis=0)
    aux = jnp.sum(density * router_mean) * E * config.router_aux_loss

    # -- dispatch ----------------------------------------------------------
    capacity = int(max(1, np.ceil(T * K / E * config.capacity_factor)))
    slot_expert = top_idx.reshape(-1)                           # (T*K,)
    slot_token = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)  # (T*K,)
    slot_gate = gates.reshape(-1)
    pos = _positions_in_expert(slot_expert, E)                  # (T*K,)
    keep = pos < capacity
    safe_pos = jnp.where(keep, pos, capacity - 1)

    buf = jnp.zeros((E, capacity, D), x.dtype)
    src = jnp.where(keep[:, None], xt[slot_token], 0).astype(x.dtype)
    buf = buf.at[slot_expert, safe_pos].add(src)                # (E, C, D)
    buf = logical_constraint(buf, "experts", "expert_cap", "embed")

    # -- expert compute (batched MXU matmuls) -----------------------------
    dtype = x.dtype
    up = jnp.einsum("ecd,edf->ecf", buf, params["w_up"].astype(dtype))
    gate = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"].astype(dtype))
    h = activation(gate, config.hidden_act) * up
    h = logical_constraint(h, "experts", "expert_cap", "ff")
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(dtype))

    # -- combine -------------------------------------------------------------
    slot_out = out_buf[slot_expert, safe_pos]                   # (T*K, D)
    slot_out = jnp.where(keep[:, None], slot_out, 0)
    combined = jax.ops.segment_sum(
        slot_out * slot_gate[:, None].astype(dtype), slot_token,
        num_segments=T)
    out = combined.reshape(B, S, D).astype(x.dtype)
    out = logical_constraint(out, "batch", "seq", "embed")
    return out, aux


# -- explicit all-to-all expert parallelism (§Perf, the Spark-MPI pattern) ----
def moe_layer_a2a(x: jax.Array, params: dict, config: ModelConfig
                  ) -> tuple[jax.Array, jax.Array]:
    """MoE with hand-placed all-to-all routing under shard_map.

    The GSPMD scatter-dispatch reshards the token stream against the
    expert-sharded capacity buffer with all-gathers (measured: the dominant
    ICI term of the 1T cell). This path does what an MPI program would do:
    each device owns E/n whole experts; tokens are routed with ONE
    all-to-all out and ONE back per layer — payload ≈ k·T_local·d_model,
    independent of E. Experts are padded to a device multiple
    (``_moe_pad_experts``).
    """
    from repro.parallel.sharding import current_mesh
    mesh = current_mesh()
    if mesh is None:
        return moe_layer(x, params, config)
    # expert ownership axis order must match the 'experts_a2a' rule
    # (('model','data')) or shard_map would reshard the weights
    axes = tuple(a for a in ("model", "data") if a in mesh.axis_names
                 and mesh.shape[a] > 1)
    if not axes:
        return moe_layer(x, params, config)
    n_dev = int(np.prod([mesh.shape[a] for a in axes]))
    E_pad = params["w_up"].shape[0]
    if E_pad % n_dev:
        return moe_layer(x, params, config)
    e_per = E_pad // n_dev
    E, K = config.num_experts, config.experts_per_token

    from jax.sharding import PartitionSpec as P

    def body(x, router, w_gate, w_up, w_down):
        B, S, D = x.shape                                  # local shapes
        T = B * S
        xt = x.reshape(T, D)
        logits = xt.astype(jnp.float32) @ router           # (T, E)
        probs = jax.nn.softmax(logits, axis=-1)
        gates, top_idx = jax.lax.top_k(probs, K)
        gates = (gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9))

        density = jnp.mean(jax.nn.one_hot(top_idx[:, 0], E,
                                          dtype=jnp.float32), axis=0)
        density = jax.lax.pmean(density, axes)
        router_mean = jax.lax.pmean(jnp.mean(probs, axis=0), axes)
        aux = jnp.sum(density * router_mean) * E * config.router_aux_loss

        # route slots to the owning device
        slot_expert = top_idx.reshape(-1)                  # (T*K,)
        slot_token = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
        slot_gate = gates.reshape(-1).astype(jnp.float32)
        dest = slot_expert // e_per                        # (T*K,) device id
        cap = int(max(1, np.ceil(T * K / n_dev
                                 * config.capacity_factor)))
        pos = _positions_in_expert(dest, n_dev)
        keep = pos < cap
        safe_pos = jnp.where(keep, pos, cap - 1)
        send_x = jnp.zeros((n_dev, cap, D), x.dtype).at[dest, safe_pos].add(
            jnp.where(keep[:, None], xt[slot_token], 0).astype(x.dtype))
        send_e = jnp.full((n_dev, cap), -1, jnp.int32).at[
            dest, safe_pos].max(jnp.where(keep, slot_expert, -1))
        send_g = jnp.zeros((n_dev, cap), jnp.float32).at[
            dest, safe_pos].add(jnp.where(keep, slot_gate, 0.0))

        recv_x = jax.lax.all_to_all(send_x, axes, 0, 0, tiled=True)
        recv_e = jax.lax.all_to_all(send_e, axes, 0, 0, tiled=True)
        recv_g = jax.lax.all_to_all(send_g, axes, 0, 0, tiled=True)
        R = n_dev * cap
        rx = recv_x.reshape(R, D)
        my_lo = jax.lax.axis_index(axes) * e_per
        le = recv_e.reshape(R) - my_lo                     # local expert id
        valid = (le >= 0) & (le < e_per)

        # local re-dispatch into (e_per, cap_loc, D)
        le_safe = jnp.where(valid, le, e_per - 1)
        lpos = _positions_in_expert(le_safe, e_per)
        cap_loc = R                                        # no second drop
        buf = jnp.zeros((e_per, cap_loc, D), x.dtype).at[
            le_safe, lpos].add(jnp.where(valid[:, None], rx, 0)
                               .astype(x.dtype))
        dtype = x.dtype
        up = jnp.einsum("ecd,edf->ecf", buf, w_up.astype(dtype))
        gate = jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(dtype))
        h = activation(gate, config.hidden_act) * up
        out_buf = jnp.einsum("ecf,efd->ecd", h, w_down.astype(dtype))
        ry = jnp.where(valid[:, None], out_buf[le_safe, lpos], 0)
        ry = ry * recv_g.reshape(R, 1).astype(dtype)
        back = jax.lax.all_to_all(ry.reshape(n_dev, cap, D), axes, 0, 0,
                                  tiled=True)
        slot_out = jnp.where(keep[:, None], back[dest, safe_pos], 0)
        combined = jax.ops.segment_sum(slot_out.astype(jnp.float32),
                                       slot_token, num_segments=T)
        return combined.reshape(B, S, D).astype(x.dtype), aux

    # x arrives (batch@[pod,]data, act_seq@model); weights are per-device
    # expert blocks (pod-replicated: pod stays pure DP)
    bspec = (("pod", "data") if "pod" in mesh.axis_names else "data")
    in_specs = (P(bspec, "model", None), P(None, None),
                P(axes, None, None), P(axes, None, None),
                P(axes, None, None))
    out, aux = shard_map_compat(
        body, mesh=mesh,
        in_specs=in_specs,
        out_specs=(P(bspec, "model", None), P()),
        check_vma=False,
    )(x, params["router"], params["w_gate"], params["w_up"],
      params["w_down"])
    return out, aux
