"""Whisper-style encoder-decoder (audio family).

The conv frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings (B, encoder_seq, d_model) — the transformer
backbone (24 enc + 24 dec layers for medium) is what the cells exercise.

Structure: pre-LN everywhere (LayerNorm), non-gated GELU MLPs, MHA
(num_kv_heads == num_heads), learned positional embeddings on the decoder
(and encoder frames; the reference sinusoidal encoder table is replaced by a
learned one of the same shape — noted in DESIGN.md). Decoder layers carry
self-attention (causal, cached at decode) + cross-attention over the encoder
output (K/V computed once at prefill and reused every decode step).
``long_500k`` is skipped (full attention); decode shapes are valid
(enc-dec has a decoder).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers as L
from repro.parallel.sharding import logical_constraint


# -- init -----------------------------------------------------------------------
def _init_enc_block(key: jax.Array, config: ModelConfig, dtype: Any) -> dict:
    k1, k2 = L.split_keys(key, 2)
    p = {}
    p["attn"], _ = attn.init_attention(k1, config, dtype)
    p["mlp"], _ = L.init_mlp(k2, config, dtype)
    p["norm1"], _ = L.init_norm(config, dtype)
    p["norm2"], _ = L.init_norm(config, dtype)
    return p


def _init_dec_block(key: jax.Array, config: ModelConfig, dtype: Any) -> dict:
    k1, k2, k3 = L.split_keys(key, 3)
    p = {}
    p["self_attn"], _ = attn.init_attention(k1, config, dtype)
    p["cross_attn"], _ = attn.init_attention(k2, config, dtype)
    p["mlp"], _ = L.init_mlp(k3, config, dtype)
    p["norm1"], _ = L.init_norm(config, dtype)
    p["norm2"], _ = L.init_norm(config, dtype)
    p["norm3"], _ = L.init_norm(config, dtype)
    return p


def init(key: jax.Array, config: ModelConfig) -> dict:
    dtype = jnp.dtype(config.param_dtype)
    k_e, k_enc, k_dec, k_p = L.split_keys(key, 4)
    embed, _ = L.init_embedding(k_e, config, dtype)
    enc_layers = jax.vmap(lambda k: _init_enc_block(k, config, dtype))(
        jax.random.split(k_enc, config.encoder_layers))
    dec_layers = jax.vmap(lambda k: _init_dec_block(k, config, dtype))(
        jax.random.split(k_dec, config.num_layers))
    enc_pos = L.normal_init(k_p, (config.encoder_seq, config.d_model),
                            0.02, dtype)
    enc_norm, _ = L.init_norm(config, dtype)
    dec_norm, _ = L.init_norm(config, dtype)
    return {"embed": embed, "enc_pos": enc_pos,
            "encoder": enc_layers, "enc_norm": enc_norm,
            "decoder": dec_layers, "dec_norm": dec_norm}


def param_specs(config: ModelConfig) -> dict:
    attn_s = {"wq": ("embed_fsdp", "heads"), "wk": ("embed_fsdp", "kv_heads"),
              "wv": ("embed_fsdp", "kv_heads"), "wo": ("heads", "embed_fsdp")}
    mlp_s = {"w_up": ("embed_fsdp", "ff"), "w_down": ("ff", "embed_fsdp")}
    if config.mlp_gated:
        mlp_s["w_gate"] = ("embed_fsdp", "ff")
    norm_s = {"scale": ("embed",), "bias": ("embed",)}
    enc_block = {"attn": attn_s, "mlp": mlp_s,
                 "norm1": dict(norm_s), "norm2": dict(norm_s)}
    dec_block = {"self_attn": dict(attn_s), "cross_attn": dict(attn_s),
                 "mlp": dict(mlp_s), "norm1": dict(norm_s),
                 "norm2": dict(norm_s), "norm3": dict(norm_s)}
    stack = lambda tree: jax.tree_util.tree_map(
        lambda axes: ("layers",) + axes, tree,
        is_leaf=lambda x: isinstance(x, tuple))
    embed_s = {"tok": ("vocab", "embed_fsdp"),
               "pos": ("null", "embed_fsdp")}
    if not config.tie_embeddings:
        embed_s["lm_head"] = ("embed_fsdp", "vocab")
    return {"embed": embed_s, "enc_pos": ("frames", "embed_fsdp"),
            "encoder": stack(enc_block), "enc_norm": dict(norm_s),
            "decoder": stack(dec_block), "dec_norm": dict(norm_s)}


# -- encoder ------------------------------------------------------------------
def encode(params: dict, frames: jax.Array, config: ModelConfig) -> jax.Array:
    x = frames.astype(config.activation_dtype)
    x = x + params["enc_pos"].astype(x.dtype)[None, : x.shape[1]]
    B, T, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(T), (B, T))
    x = logical_constraint(x, "batch", "act_seq", "embed")

    def body(x, p):
        h = L.apply_norm(x, p["norm1"], config)
        a, _ = attn.attention_layer(h, p["attn"], config, positions,
                                    causal=False)
        x = x + a
        h = L.apply_norm(x, p["norm2"], config)
        x = x + L.mlp(h, p["mlp"], config)
        return logical_constraint(x, "batch", "act_seq", "embed"), None

    if config.remat != "none":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["encoder"])
    return L.apply_norm(x, params["enc_norm"], config)


# -- decoder -------------------------------------------------------------------
def _decode_layers(params: dict, x: jax.Array, config: ModelConfig,
                   positions: jax.Array, enc_out: jax.Array | None,
                   cache: dict | None) -> tuple[jax.Array, dict | None]:
    pos_scalar = None if cache is None else cache["pos"]

    def body(x, xs):
        if cache is None:
            p = xs
            layer_cache = None
            cross_kv = None
        else:
            p, sk, sv, ck, cv = xs
            layer_cache = {"k": sk, "v": sv, "pos": pos_scalar}
            cross_kv = (ck, cv) if enc_out is None else None
        h = L.apply_norm(x, p["norm1"], config)
        a, nc = attn.attention_layer(h, p["self_attn"], config, positions,
                                     cache=layer_cache)
        x = x + a
        h = L.apply_norm(x, p["norm2"], config)
        if enc_out is not None:        # train/prefill: project enc K/V fresh
            c, cross_cache = attn.attention_layer(
                h, p["cross_attn"], config, positions, kv_source=enc_out)
        else:                           # decode: reuse cached cross K/V
            c, cross_cache = attn.attention_layer(
                h, p["cross_attn"], config, positions,
                precomputed_kv=cross_kv)
        x = x + c
        h = L.apply_norm(x, p["norm3"], config)
        x = x + L.mlp(h, p["mlp"], config)
        x = logical_constraint(x, "batch", "act_seq", "embed")
        ys = None
        if cache is not None:
            ck_new = nc["k"], nc["v"]
            cr = (cross_cache["k"], cross_cache["v"]) if enc_out is not None \
                else cross_kv
            ys = (*ck_new, *cr)
        return x, ys

    if config.remat != "none":
        body = jax.checkpoint(body)
    xs = params["decoder"] if cache is None else (
        params["decoder"], cache["self_k"], cache["self_v"],
        cache["cross_k"], cache["cross_v"])
    x, ys = jax.lax.scan(body, x, xs)
    new_cache = None
    if cache is not None:
        new_cache = {"self_k": ys[0], "self_v": ys[1],
                     "cross_k": ys[2], "cross_v": ys[3],
                     "pos": pos_scalar + positions.shape[1]}
    return x, new_cache


def _embed_dec(params: dict, tokens: jax.Array, config: ModelConfig,
               start_pos) -> tuple[jax.Array, jax.Array]:
    B, S = tokens.shape
    x = L.embed_tokens(tokens, params["embed"], config)
    positions = start_pos + jnp.broadcast_to(jnp.arange(S), (B, S))
    x = x + params["embed"]["pos"].astype(x.dtype)[positions]
    return logical_constraint(x, "batch", "act_seq", "embed"), positions


# -- model API -----------------------------------------------------------------
def loss_and_metrics(params: dict, batch: dict, config: ModelConfig
                     ) -> tuple[jax.Array, dict]:
    from repro.models.transformer import _chunked_ce
    tokens = batch["tokens"]
    enc_out = encode(params, batch["frames"], config)
    x, positions = _embed_dec(params, tokens, config, 0)
    x, _ = _decode_layers(params, x, config, positions, enc_out, None)
    x = L.apply_norm(x, params["dec_norm"], config)
    targets = tokens[:, 1:]
    mask = batch.get("loss_mask")
    mask = jnp.ones(targets.shape, jnp.float32) if mask is None else mask[:, 1:]
    loss = _chunked_ce(x[:, :-1], params, config, targets, mask)
    return loss, {"loss": loss, "aux_loss": jnp.zeros((), jnp.float32)}


def init_cache(config: ModelConfig, batch: int, max_len: int) -> dict:
    kh, hd = config.num_kv_heads, config.resolved_head_dim
    Lc, T = config.num_layers, config.encoder_seq
    dtype = config.activation_dtype
    return {"self_k": jnp.zeros((Lc, batch, max_len, kh, hd), dtype),
            "self_v": jnp.zeros((Lc, batch, max_len, kh, hd), dtype),
            "cross_k": jnp.zeros((Lc, batch, T, kh, hd), dtype),
            "cross_v": jnp.zeros((Lc, batch, T, kh, hd), dtype),
            "pos": jnp.zeros((), jnp.int32)}


def cache_specs(config: ModelConfig) -> dict:
    kv = ("layers", "batch", "null", "kv_heads", "head_dim")
    return {"self_k": kv, "self_v": kv, "cross_k": kv, "cross_v": kv,
            "pos": ()}


def prefill(params: dict, batch: dict, config: ModelConfig,
            max_len: int | None = None) -> tuple[jax.Array, dict]:
    tokens = batch["tokens"]
    enc_out = encode(params, batch["frames"], config)
    cache = init_cache(config, tokens.shape[0], max_len or tokens.shape[1])
    x, positions = _embed_dec(params, tokens, config, 0)
    x, cache = _decode_layers(params, x, config, positions, enc_out, cache)
    x = L.apply_norm(x, params["dec_norm"], config)
    logits = L.lm_logits(x[:, -1:], params["embed"], config)
    return logits, cache


def decode_step(params: dict, tokens: jax.Array, cache: dict,
                config: ModelConfig) -> tuple[jax.Array, dict]:
    x, positions = _embed_dec(params, tokens, config, cache["pos"])
    x, cache = _decode_layers(params, x, config, positions, None, cache)
    x = L.apply_norm(x, params["dec_norm"], config)
    logits = L.lm_logits(x, params["embed"], config)
    return logits, cache
