"""Shared model layers: norms, MLPs, embeddings, RoPE, initializers.

Pure-function style: params are plain dict pytrees; each builder returns
``(init_fn, spec)`` metadata so the sharding layer can derive NamedShardings
without a framework dependency (no flax/haiku in this container).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.parallel.sharding import logical_constraint


# -- initializers -------------------------------------------------------------
def normal_init(key: jax.Array, shape: tuple[int, ...], std: float,
                dtype: Any) -> jax.Array:
    return (jax.random.normal(key, shape, dtype=jnp.float32) * std).astype(dtype)


def split_keys(key: jax.Array, n: int) -> list[jax.Array]:
    return list(jax.random.split(key, n))


# -- norms ---------------------------------------------------------------------
def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6,
            offset: bool = False) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    w = scale.astype(jnp.float32)
    if offset:                     # gemma-style (1 + w)
        w = 1.0 + w
    return (y * w).astype(dtype)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mean) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(dtype)


def apply_norm(x: jax.Array, params: dict, config: ModelConfig) -> jax.Array:
    if config.norm == "layernorm":
        return layernorm(x, params["scale"], params["bias"])
    return rmsnorm(x, params["scale"], offset=config.norm_offset)


def init_norm(config: ModelConfig, dtype: Any) -> tuple[dict, dict]:
    if config.norm == "layernorm":
        params = {"scale": jnp.ones((config.d_model,), dtype),
                  "bias": jnp.zeros((config.d_model,), dtype)}
        specs = {"scale": ("embed",), "bias": ("embed",)}
    else:
        init = jnp.zeros if config.norm_offset else jnp.ones
        params = {"scale": init((config.d_model,), dtype)}
        specs = {"scale": ("embed",)}
    return params, specs


# -- activations -----------------------------------------------------------
def activation(x: jax.Array, kind: str) -> jax.Array:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "relu2":             # nemotron / minitron squared ReLU
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(f"unknown activation {kind!r}")


# -- dense MLP ---------------------------------------------------------------
def init_mlp(key: jax.Array, config: ModelConfig, dtype: Any,
             d_model: int | None = None, d_ff: int | None = None
             ) -> tuple[dict, dict]:
    d = d_model or config.d_model
    f = d_ff or config.d_ff
    k1, k2, k3 = split_keys(key, 3)
    std_in = 1.0 / np.sqrt(d)
    std_out = 1.0 / np.sqrt(f) / np.sqrt(2.0 * config.num_layers)
    params = {"w_up": normal_init(k1, (d, f), std_in, dtype),
              "w_down": normal_init(k2, (f, d), std_out, dtype)}
    specs = {"w_up": ("embed_fsdp", "ff"), "w_down": ("ff", "embed_fsdp")}
    if config.mlp_gated:
        params["w_gate"] = normal_init(k3, (d, f), std_in, dtype)
        specs["w_gate"] = ("embed_fsdp", "ff")
    return params, specs


def mlp(x: jax.Array, params: dict, config: ModelConfig) -> jax.Array:
    dtype = x.dtype
    up = x @ params["w_up"].astype(dtype)
    if config.mlp_gated:
        gate = activation(x @ params["w_gate"].astype(dtype), config.hidden_act)
        h = gate * up
    else:
        h = activation(up, config.hidden_act)
    h = logical_constraint(h, "batch", "seq", "ff")
    return h @ params["w_down"].astype(dtype)


# -- embeddings ------------------------------------------------------------
def init_embedding(key: jax.Array, config: ModelConfig, dtype: Any
                   ) -> tuple[dict, dict]:
    k1, k2, k3 = split_keys(key, 3)
    params = {"tok": normal_init(k1, (config.vocab_size, config.d_model),
                                 1.0 / np.sqrt(config.d_model), dtype)}
    specs = {"tok": ("vocab", "embed_fsdp")}
    if config.pos_embedding == "learned":
        max_pos = config.max_position or 8192
        params["pos"] = normal_init(k2, (max_pos, config.d_model), 0.02, dtype)
        specs["pos"] = ("null", "embed_fsdp")
    if not config.tie_embeddings:
        params["lm_head"] = normal_init(
            k3, (config.d_model, config.vocab_size),
            1.0 / np.sqrt(config.d_model), dtype)
        specs["lm_head"] = ("embed_fsdp", "vocab")
    return params, specs


def embed_tokens(tokens: jax.Array, params: dict,
                 config: ModelConfig) -> jax.Array:
    x = params["tok"].astype(config.activation_dtype)[tokens]
    if config.name.startswith("gemma") or config.name.startswith("recurrentgemma"):
        x = x * jnp.asarray(np.sqrt(config.d_model), x.dtype)
    return x


def lm_logits(x: jax.Array, params: dict, config: ModelConfig) -> jax.Array:
    if config.tie_embeddings:
        logits = x @ params["tok"].astype(x.dtype).T
    else:
        logits = x @ params["lm_head"].astype(x.dtype)
    logits = logical_constraint(logits, "batch", "seq", "vocab")
    if config.logits_soft_cap > 0:
        cap = config.logits_soft_cap
        logits = cap * jnp.tanh(logits / cap)
    return logits


# -- RoPE -----------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)                 # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., :, None, :]                          # (..., S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- losses ------------------------------------------------------------------
def cross_entropy(logits: jax.Array, targets: jax.Array,
                  mask: jax.Array | None = None,
                  z_loss: float = 0.0) -> jax.Array:
    """Token-mean cross entropy in fp32 with optional z-loss."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    target_logit = jnp.take_along_axis(
        logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - target_logit
    if z_loss > 0:
        nll = nll + z_loss * jnp.square(logz)
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
