"""RecurrentGemma: RG-LRU recurrent blocks + local sliding-window attention.

Layer pattern (paper arXiv:2402.19427): cycles ``(rec, rec, attn)`` — two
gated-linear-recurrence blocks per local-attention block. Every temporal
block is followed by a GeGLU MLP. The RG-LRU recurrence

    r_t = σ(W_a x_t + b_a)          (recurrence gate)
    i_t = σ(W_x x_t + b_x)          (input gate)
    a_t = exp(-c · softplus(Λ) · r_t)           (c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

is a diagonal linear recurrence — evaluated with ``jax.lax.associative_scan``
(log-depth, TPU-friendly) at train/prefill and O(1) per step at decode. The
decode state is constant-size (LRU state + 3-tap conv tail + a
``local_window`` rolling KV buffer), which is why this arch runs the
``long_500k`` cell.

Layers are *unrolled* (heterogeneous block types); at 26 layers the HLO stays
small. Params/caches are per-layer dicts keyed ``layer_NN``.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers as L
from repro.parallel.sharding import logical_constraint

_C = 8.0  # RG-LRU sharpness constant


def layer_kinds(config: ModelConfig) -> list[str]:
    pat = config.block_pattern
    return [pat[i % len(pat)] for i in range(config.num_layers)]


# -- init ---------------------------------------------------------------------
def _init_rec_block(key: jax.Array, config: ModelConfig, dtype: Any) -> dict:
    d, w = config.d_model, config.lru_width or config.d_model
    ks = L.split_keys(key, 8)
    std = 1.0 / np.sqrt(d)
    stdw = 1.0 / np.sqrt(w)
    return {
        "w_in_x": L.normal_init(ks[0], (d, w), std, dtype),
        "w_in_gate": L.normal_init(ks[1], (d, w), std, dtype),
        "conv_w": L.normal_init(ks[2], (config.conv_width, w), stdw, dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "wa": L.normal_init(ks[3], (w, w), stdw, dtype),
        "ba": jnp.zeros((w,), jnp.float32),
        "wx": L.normal_init(ks[4], (w, w), stdw, dtype),
        "bx": jnp.zeros((w,), jnp.float32),
        "lam": jnp.asarray(np.linspace(0.3, 1.5, w).astype(np.float32)),
        "w_out": L.normal_init(
            ks[5], (w, d), stdw / np.sqrt(2.0 * config.num_layers), dtype),
    }


_REC_SPECS = {
    "w_in_x": ("embed_fsdp", "lru"), "w_in_gate": ("embed_fsdp", "lru"),
    "conv_w": ("conv", "lru"), "conv_b": ("lru",),
    "wa": ("null", "lru"), "ba": ("lru",),
    "wx": ("null", "lru"), "bx": ("lru",),
    "lam": ("lru",), "w_out": ("lru", "embed_fsdp"),
}


def init(key: jax.Array, config: ModelConfig) -> dict:
    dtype = jnp.dtype(config.param_dtype)
    kinds = layer_kinds(config)
    keys = L.split_keys(key, config.num_layers + 2)
    params: dict = {}
    embed, _ = L.init_embedding(keys[0], config, dtype)
    params["embed"] = embed
    for i, kind in enumerate(kinds):
        k_t, k_m = L.split_keys(keys[i + 1], 2)
        blk: dict = {}
        if kind == "rec":
            blk["rec"] = _init_rec_block(k_t, config, dtype)
        else:
            blk["attn"], _ = attn.init_attention(k_t, config, dtype)
        blk["mlp"], _ = L.init_mlp(k_m, config, dtype)
        blk["norm1"], _ = L.init_norm(config, dtype)
        blk["norm2"], _ = L.init_norm(config, dtype)
        params[f"layer_{i:02d}"] = blk
    final_norm, _ = L.init_norm(config, dtype)
    params["final_norm"] = final_norm
    return params


def param_specs(config: ModelConfig) -> dict:
    embed_s = {"tok": ("vocab", "embed_fsdp")}
    if not config.tie_embeddings:
        embed_s["lm_head"] = ("embed_fsdp", "vocab")
    norm_s = {"scale": ("embed",)}
    attn_s = {"wq": ("embed_fsdp", "heads"), "wk": ("embed_fsdp", "kv_heads"),
              "wv": ("embed_fsdp", "kv_heads"), "wo": ("heads", "embed_fsdp")}
    mlp_s = {"w_up": ("embed_fsdp", "ff"), "w_down": ("ff", "embed_fsdp"),
             "w_gate": ("embed_fsdp", "ff")}
    specs: dict = {"embed": embed_s, "final_norm": dict(norm_s)}
    for i, kind in enumerate(layer_kinds(config)):
        blk = {"mlp": dict(mlp_s), "norm1": dict(norm_s),
               "norm2": dict(norm_s)}
        if kind == "rec":
            blk["rec"] = dict(_REC_SPECS)
        else:
            blk["attn"] = dict(attn_s)
        specs[f"layer_{i:02d}"] = blk
    return specs


# -- RG-LRU core -----------------------------------------------------------
def _rg_lru(x: jax.Array, p: dict, h0: jax.Array
            ) -> tuple[jax.Array, jax.Array]:
    """x: (B, T, W) -> (y, h_last). Associative scan over time."""
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    r = jax.nn.sigmoid(x32 @ p["wa"].astype(jnp.float32) + p["ba"])
    i = jax.nn.sigmoid(x32 @ p["wx"].astype(jnp.float32) + p["bx"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r                  # (B,T,W) ≤ 0
    a = jnp.exp(log_a)
    gated = i * x32
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated

    # h_t = a_t h_{t-1} + b_t, seeded with h0: fold h0 into b_1
    b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(dtype), h[:, -1]


def _rg_lru_step(x: jax.Array, p: dict, h0: jax.Array
                 ) -> tuple[jax.Array, jax.Array]:
    """One token: x (B, W)."""
    x32 = x.astype(jnp.float32)
    r = jax.nn.sigmoid(x32 @ p["wa"].astype(jnp.float32) + p["ba"])
    i = jax.nn.sigmoid(x32 @ p["wx"].astype(jnp.float32) + p["bx"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    h = a * h0 + jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * x32)
    return h.astype(x.dtype), h


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 tail: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Depthwise causal conv; x: (B,T,W), w: (cw, W), tail: (B, cw-1, W)."""
    cw = w.shape[0]
    xt = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    y = sum(xt[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
            for i in range(cw))
    new_tail = xt[:, xt.shape[1] - (cw - 1):]
    return y + b.astype(x.dtype), new_tail


def _rec_block(x: jax.Array, p: dict, state: dict
               ) -> tuple[jax.Array, dict]:
    dtype = x.dtype
    gate = jax.nn.gelu(x @ p["w_in_gate"].astype(dtype))
    h = x @ p["w_in_x"].astype(dtype)
    h = logical_constraint(h, "batch", "seq", "lru")
    h, conv_tail = _causal_conv(h, p["conv_w"], p["conv_b"], state["conv"])
    if x.shape[1] == 1:
        y, h_last = _rg_lru_step(h[:, 0], p, state["h"])
        y = y[:, None]
    else:
        y, h_last = _rg_lru(h, p, state["h"])
    out = (y * gate) @ p["w_out"].astype(dtype)
    return out, {"h": h_last.astype(jnp.float32), "conv": conv_tail}


# -- model ------------------------------------------------------------------
def _forward(params: dict, tokens: jax.Array, config: ModelConfig,
             cache: dict | None, start_pos) -> tuple[jax.Array, dict | None]:
    B, S = tokens.shape
    x = L.embed_tokens(tokens, params["embed"], config)
    positions = start_pos + jnp.broadcast_to(jnp.arange(S), (B, S))
    x = logical_constraint(x, "batch", "act_seq", "embed")
    new_cache: dict | None = None if cache is None else {"pos": cache["pos"] + S}
    w_lru = config.lru_width or config.d_model
    cw = config.conv_width

    for i, kind in enumerate(layer_kinds(config)):
        key = f"layer_{i:02d}"

        def run_layer(x, p, layer_cache, kind=kind):
            h = L.apply_norm(x, p["norm1"], config)
            if kind == "rec":
                if layer_cache is None:
                    state = {"h": jnp.zeros((B, w_lru), jnp.float32),
                             "conv": jnp.zeros((B, cw - 1, w_lru), x.dtype)}
                    a, nc = _rec_block(h, p["rec"], state)
                    nc = None
                else:
                    a, nc = _rec_block(h, p["rec"], layer_cache)
            else:
                lc = None if layer_cache is None else \
                    {**layer_cache, "pos": cache["pos"]}
                a, nc = attn.attention_layer(h, p["attn"], config, positions,
                                             cache=lc,
                                             window=config.local_window)
                if nc is not None:
                    nc = {"k": nc["k"], "v": nc["v"]}
            x = x + a
            h = L.apply_norm(x, p["norm2"], config)
            x = x + L.mlp(h, p["mlp"], config)
            x = logical_constraint(x, "batch", "act_seq", "embed")
            return x, nc

        if config.remat != "none":
            run_layer = jax.checkpoint(run_layer)
        x, nc = run_layer(x, params[key],
                          None if cache is None else cache[key])
        if cache is not None:
            new_cache[key] = nc
    x = L.apply_norm(x, params["final_norm"], config)
    return x, new_cache


def init_cache(config: ModelConfig, batch: int, max_len: int) -> dict:
    w_lru = config.lru_width or config.d_model
    cw = config.conv_width
    window = config.local_window
    size = min(window, max_len) if window > 0 else max_len
    kh, hd = config.num_kv_heads, config.resolved_head_dim
    dtype = config.activation_dtype
    cache: dict = {"pos": jnp.zeros((), jnp.int32)}
    for i, kind in enumerate(layer_kinds(config)):
        if kind == "rec":
            cache[f"layer_{i:02d}"] = {
                "h": jnp.zeros((batch, w_lru), jnp.float32),
                "conv": jnp.zeros((batch, cw - 1, w_lru), dtype)}
        else:
            cache[f"layer_{i:02d}"] = {
                "k": jnp.zeros((batch, size, kh, hd), dtype),
                "v": jnp.zeros((batch, size, kh, hd), dtype)}
    return cache


def cache_specs(config: ModelConfig) -> dict:
    specs: dict = {"pos": ()}
    for i, kind in enumerate(layer_kinds(config)):
        if kind == "rec":
            specs[f"layer_{i:02d}"] = {"h": ("batch", "lru"),
                                       "conv": ("batch", "conv", "lru")}
        else:
            specs[f"layer_{i:02d}"] = {
                "k": ("batch", "null", "kv_heads", "head_dim"),
                "v": ("batch", "null", "kv_heads", "head_dim")}
    return specs


def loss_and_metrics(params: dict, batch: dict, config: ModelConfig
                     ) -> tuple[jax.Array, dict]:
    from repro.models.transformer import _chunked_ce
    tokens = batch["tokens"]
    x, _ = _forward(params, tokens, config, None, 0)
    targets = tokens[:, 1:]
    mask = batch.get("loss_mask")
    mask = jnp.ones(targets.shape, jnp.float32) if mask is None else mask[:, 1:]
    loss = _chunked_ce(x[:, :-1], params, config, targets, mask)
    return loss, {"loss": loss, "aux_loss": jnp.zeros((), jnp.float32)}


def prefill(params: dict, batch: dict, config: ModelConfig,
            max_len: int | None = None) -> tuple[jax.Array, dict]:
    tokens = batch["tokens"]
    cache = init_cache(config, tokens.shape[0], max_len or tokens.shape[1])
    x, cache = _forward(params, tokens, config, cache, 0)
    logits = L.lm_logits(x[:, -1:], params["embed"], config)
    return logits, cache


def decode_step(params: dict, tokens: jax.Array, cache: dict,
                config: ModelConfig) -> tuple[jax.Array, dict]:
    x, cache = _forward(params, tokens, config, cache, cache["pos"])
    logits = L.lm_logits(x, params["embed"], config)
    return logits, cache
