"""RWKV-6 "Finch" — attention-free LM with data-dependent decay.

Recurrence per head (K = V = head_dim):

    y_t = r_t^T S_{t-1}  +  (r_t · (u ⊙ k_t)) v_t
    S_t = diag(w_t) S_{t-1} + k_t v_t^T,   w_t = exp(-exp(w0 + LoRA(x_t)))

Three evaluation modes, all the same math (tested against each other):
  * ``recurrent`` — lax.scan over time (exact; decode + oracle);
  * ``chunked``   — the training/prefill path: per-chunk cumulative log-decay;
    inter-chunk contributions are (C×K)·(K×V) MXU matmuls and intra-chunk
    pairwise terms use log-space *differences* (always ≤ 0, so exp never
    overflows even with near-zero decay — the numerically safe TPU port of
    the CUDA wkv kernel, see DESIGN.md);
  * decode — O(1) state update per token; the ``long_500k`` shape runs with a
    constant-size state (no KV cache), which is why this arch keeps that cell.

Faithfulness notes: token-shift mixing uses learned per-channel lerp (the
projection-specific ddlerp LoRA of the reference implementation is reduced to
its dominant term); the decay LoRA — Finch's signature data dependence — is
kept in full. Channel mixing is the reference squared-ReLU form.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.parallel.sharding import logical_constraint

NEG_INF = -1e30


# -- init -----------------------------------------------------------------------
def _init_block(key: jax.Array, config: ModelConfig, dtype: Any) -> dict:
    d, f, dl = config.d_model, config.d_ff, config.decay_lora
    ks = L.split_keys(key, 12)
    std = 1.0 / np.sqrt(d)
    std_o = std / np.sqrt(2.0 * config.num_layers)
    p = {
        # time mixing
        "mu": jnp.full((5, d), 0.5, dtype),            # r,k,v,w,g lerp factors
        "w_r": L.normal_init(ks[0], (d, d), std, dtype),
        "w_k": L.normal_init(ks[1], (d, d), std, dtype),
        "w_v": L.normal_init(ks[2], (d, d), std, dtype),
        "w_g": L.normal_init(ks[3], (d, d), std, dtype),
        "w_o": L.normal_init(ks[4], (d, d), std_o, dtype),
        "w0": jnp.asarray(
            np.linspace(-6.0, -0.5, d).astype(np.float32)),   # decay bias
        "w_lora_a": L.normal_init(ks[5], (d, dl), std, dtype),
        "w_lora_b": L.normal_init(ks[6], (dl, d), 1e-2, dtype),
        "u": L.normal_init(ks[7], (d,), 0.5, jnp.float32),    # bonus
        "ln_x_scale": jnp.ones((d,), dtype),
        "ln_x_bias": jnp.zeros((d,), dtype),
        # channel mixing
        "cmu": jnp.full((2, d), 0.5, dtype),                  # k, r
        "w_ck": L.normal_init(ks[8], (d, f), std, dtype),
        "w_cv": L.normal_init(ks[9], (f, d), std_o, dtype),
        "w_cr": L.normal_init(ks[10], (d, d), std, dtype),
    }
    n1, _ = L.init_norm(config, dtype)
    n2, _ = L.init_norm(config, dtype)
    p["norm1"], p["norm2"] = n1, n2
    return p


def _block_specs(config: ModelConfig) -> dict:
    norm_s = ({"scale": ("embed",), "bias": ("embed",)}
              if config.norm == "layernorm" else {"scale": ("embed",)})
    return {
        "mu": ("null", "embed"), "w_r": ("embed_fsdp", "heads"),
        "w_k": ("embed_fsdp", "heads"), "w_v": ("embed_fsdp", "heads"),
        "w_g": ("embed_fsdp", "heads"), "w_o": ("heads", "embed_fsdp"),
        "w0": ("heads",), "w_lora_a": ("embed_fsdp", "null"),
        "w_lora_b": ("null", "heads"), "u": ("heads",),
        "ln_x_scale": ("embed",), "ln_x_bias": ("embed",),
        "cmu": ("null", "embed"), "w_ck": ("embed_fsdp", "ff"),
        "w_cv": ("ff", "embed_fsdp"), "w_cr": ("embed_fsdp", "null"),
        "norm1": dict(norm_s), "norm2": dict(norm_s),
    }


def init(key: jax.Array, config: ModelConfig) -> dict:
    dtype = jnp.dtype(config.param_dtype)
    k_e, k_l, k_f = L.split_keys(key, 3)
    embed, _ = L.init_embedding(k_e, config, dtype)
    layers = jax.vmap(lambda k: _init_block(k, config, dtype))(
        jax.random.split(k_l, config.num_layers))
    final_norm, _ = L.init_norm(config, dtype)
    return {"embed": embed, "layers": layers, "final_norm": final_norm}


def param_specs(config: ModelConfig) -> dict:
    embed_s = {"tok": ("vocab", "embed_fsdp")}
    if not config.tie_embeddings:
        embed_s["lm_head"] = ("embed_fsdp", "vocab")
    block = jax.tree_util.tree_map(
        lambda axes: ("layers",) + axes, _block_specs(config),
        is_leaf=lambda x: isinstance(x, tuple))
    final_s = ({"scale": ("embed",), "bias": ("embed",)}
               if config.norm == "layernorm" else {"scale": ("embed",)})
    return {"embed": embed_s, "layers": block, "final_norm": final_s}


# -- wkv cores -------------------------------------------------------------------
def _wkv_chunked(r, k, v, logw, u, state, chunk: int):
    """r,k,v,logw: (B, T, H, K) fp32; u: (H, K); state: (B, H, K, V).
    Returns (y (B,T,H,V), final_state)."""
    B, T, H, K = r.shape
    C = min(chunk, T)
    n = -(-T // C)
    pad = n * C - T
    if pad:
        z = ((0, 0), (0, pad), (0, 0), (0, 0))
        r, k, v = jnp.pad(r, z), jnp.pad(k, z), jnp.pad(v, z)
        logw = jnp.pad(logw, z)   # log w = 0 -> w = 1 keeps state intact

    def reshape(x):
        return x.reshape(B, n, C, H, K).transpose(1, 0, 2, 3, 4)

    rb, kb, vb, lwb = map(reshape, (r, k, v, logw))

    tri = jnp.tril(jnp.ones((C, C), bool), k=-1)              # s < t

    def chunk_step(S, xs):
        rc, kc, vc, lwc = xs                                   # (B, C, H, K)
        la = jnp.cumsum(lwc, axis=1)                           # inclusive
        la_prev = la - lwc                                     # exclusive
        # inter-chunk: y += (r ⊙ e^{la_prev}) S
        r_dec = rc * jnp.exp(la_prev)
        y_inter = jnp.einsum("bthk,bhkv->bthv", r_dec, S)
        # intra-chunk pairwise log-space differences (≤ 0 ⇒ exp safe)
        diff = la_prev[:, :, None] - la[:, None, :]            # (B,Ct,Cs,H,K)
        coef = jnp.where(tri[None, :, :, None, None], jnp.exp(diff), 0.0)
        eye = jnp.eye(C, dtype=coef.dtype)
        coef = coef + eye[None, :, :, None, None] * u[None, None, None]
        scores = jnp.einsum("bthk,bshk,btshk->btsh", rc, kc, coef)
        y_intra = jnp.einsum("btsh,bshv->bthv", scores, vc)
        # state to chunk end
        g = jnp.exp(la[:, -1:] - la)                           # (B,C,H,K) ≤ 1
        S_new = (jnp.exp(la[:, -1])[..., None] * S
                 + jnp.einsum("bshk,bshv->bhkv", kc * g, vc))
        return S_new, y_inter + y_intra

    state, yb = jax.lax.scan(chunk_step, state, (rb, kb, vb, lwb))
    y = yb.transpose(1, 0, 2, 3, 4).reshape(B, n * C, H, K)
    return y[:, :T], state


def _wkv_recurrent(r, k, v, logw, u, state):
    """Exact sequential scan (oracle / tiny shapes)."""
    def step(S, xs):
        rt, kt, vt, lwt = xs                                   # (B, H, K)
        y = jnp.einsum("bhk,bhkv->bhv", rt, S) + \
            jnp.einsum("bhk,hk,bhk,bhv->bhv", rt, u, kt, vt)
        S = jnp.exp(lwt)[..., None] * S + kt[..., None] * vt[..., None, :]
        return S, y

    xs = jax.tree_util.tree_map(lambda x: x.swapaxes(0, 1), (r, k, v, logw))
    state, y = jax.lax.scan(step, state, xs)
    return y.swapaxes(0, 1), state


def _wkv_step(r, k, v, logw, u, state):
    """One decode token: r,k,v,logw (B, H, K)."""
    y = jnp.einsum("bhk,bhkv->bhv", r, state) + \
        jnp.einsum("bhk,hk,bhk,bhv->bhv", r, u, k, v)
    state = jnp.exp(logw)[..., None] * state + \
        k[..., None] * v[..., None, :]
    return y, state


# -- block -----------------------------------------------------------------------
def _token_shift(x: jax.Array, prev: jax.Array) -> jax.Array:
    """xs[t] = x[t-1]; xs[0] = prev (carried across chunks/steps)."""
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def _time_mix(x, xs, p, config: ModelConfig, state, mode: str):
    B, T, D = x.shape
    H = config.num_heads
    K = config.resolved_head_dim
    dtype = x.dtype
    mu = p["mu"].astype(dtype)
    xr, xk, xv, xw, xg = (x + (xs - x) * mu[i] for i in range(5))
    r = (xr @ p["w_r"].astype(dtype)).reshape(B, T, H, K).astype(jnp.float32)
    k = (xk @ p["w_k"].astype(dtype)).reshape(B, T, H, K).astype(jnp.float32)
    v = (xv @ p["w_v"].astype(dtype)).reshape(B, T, H, K).astype(jnp.float32)
    g = xg @ p["w_g"].astype(dtype)
    # data-dependent decay (Finch): logw = -exp(w0 + tanh(x A) B) ≤ 0
    lora = jnp.tanh(xw @ p["w_lora_a"].astype(dtype)) @ p["w_lora_b"].astype(dtype)
    logw = -jnp.exp(p["w0"].astype(jnp.float32)
                    + lora.astype(jnp.float32)).reshape(B, T, H, K)
    r = logical_constraint(r, "batch", "seq", "heads", "head_dim")
    k = logical_constraint(k, "batch", "seq", "heads", "head_dim")
    u = p["u"].astype(jnp.float32).reshape(H, K)

    if mode == "chunked":
        y, state = _wkv_chunked(r, k, v, logw, u, state, config.rwkv_chunk)
    elif mode == "recurrent":
        y, state = _wkv_recurrent(r, k, v, logw, u, state)
    else:  # decode: T == 1
        y, state = _wkv_step(r[:, 0], k[:, 0], v[:, 0], logw[:, 0], u, state)
        y = y[:, None]
    # per-head groupnorm, gate, project out
    mean = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    yn = (y - mean) * jax.lax.rsqrt(var + 64e-5)
    yn = yn.reshape(B, T, D).astype(dtype)
    yn = yn * p["ln_x_scale"].astype(dtype) + p["ln_x_bias"].astype(dtype)
    out = (yn * jax.nn.silu(g)) @ p["w_o"].astype(dtype)
    return out, state


def _channel_mix(x, xs, p, config: ModelConfig):
    dtype = x.dtype
    cmu = p["cmu"].astype(dtype)
    xk = x + (xs - x) * cmu[0]
    xr = x + (xs - x) * cmu[1]
    kk = jax.nn.relu(xk @ p["w_ck"].astype(dtype))
    kk = kk * kk
    kk = logical_constraint(kk, "batch", "seq", "ff")
    return jax.nn.sigmoid(xr @ p["w_cr"].astype(dtype)) * (kk @ p["w_cv"].astype(dtype))


def _block(x, p, config: ModelConfig, state: dict, mode: str):
    h = L.apply_norm(x, p["norm1"], config)
    xs = _token_shift(h, state["tshift"])
    new_tshift = h[:, -1]
    a, S = _time_mix(h, xs, p, config, state["S"], mode)
    x = x + a
    x = logical_constraint(x, "batch", "act_seq", "embed")
    h = L.apply_norm(x, p["norm2"], config)
    xs = _token_shift(h, state["cshift"])
    new_cshift = h[:, -1]
    x = x + _channel_mix(h, xs, p, config)
    x = logical_constraint(x, "batch", "act_seq", "embed")
    return x, {"S": S, "tshift": new_tshift, "cshift": new_cshift}


# -- model API ---------------------------------------------------------------
def init_state(config: ModelConfig, batch: int) -> dict:
    H, K = config.num_heads, config.resolved_head_dim
    Lc, D = config.num_layers, config.d_model
    return {"S": jnp.zeros((Lc, batch, H, K, K), jnp.float32),
            "tshift": jnp.zeros((Lc, batch, D), config.activation_dtype),
            "cshift": jnp.zeros((Lc, batch, D), config.activation_dtype),
            "pos": jnp.zeros((), jnp.int32)}


def cache_specs(config: ModelConfig) -> dict:
    return {"S": ("layers", "batch", "heads", "null", "null"),
            "tshift": ("layers", "batch", "embed"),
            "cshift": ("layers", "batch", "embed"),
            "pos": ()}


init_cache = lambda config, batch, max_len=0: init_state(config, batch)


def _run(params: dict, tokens: jax.Array, config: ModelConfig,
         state: dict, mode: str) -> tuple[jax.Array, dict]:
    x = L.embed_tokens(tokens, params["embed"], config)
    x = logical_constraint(x, "batch", "act_seq", "embed")

    def body(carry, xs):
        x = carry
        p, S, ts, cs = xs
        x, ns = _block(x, p, config, {"S": S, "tshift": ts, "cshift": cs},
                       mode)
        return x, (ns["S"], ns["tshift"], ns["cshift"])

    if config.remat != "none":
        body = jax.checkpoint(body)
    x, (S, ts, cs) = jax.lax.scan(
        body, x, (params["layers"], state["S"], state["tshift"],
                  state["cshift"]))
    x = L.apply_norm(x, params["final_norm"], config)
    new_state = {"S": S, "tshift": ts, "cshift": cs,
                 "pos": state["pos"] + tokens.shape[1]}
    return x, new_state


def loss_and_metrics(params: dict, batch: dict, config: ModelConfig
                     ) -> tuple[jax.Array, dict]:
    from repro.models.transformer import _chunked_ce
    tokens = batch["tokens"]
    state = init_state(config, tokens.shape[0])
    x, _ = _run(params, tokens, config, state, mode="chunked")
    targets = tokens[:, 1:]
    mask = batch.get("loss_mask")
    mask = jnp.ones(targets.shape, jnp.float32) if mask is None else mask[:, 1:]
    loss = _chunked_ce(x[:, :-1], params, config, targets, mask)
    return loss, {"loss": loss, "aux_loss": jnp.zeros((), jnp.float32)}


def prefill(params: dict, batch: dict, config: ModelConfig,
            max_len: int | None = None) -> tuple[jax.Array, dict]:
    tokens = batch["tokens"]
    state = init_state(config, tokens.shape[0])
    x, state = _run(params, tokens, config, state, mode="chunked")
    logits = L.lm_logits(x[:, -1:], params["embed"], config)
    return logits, state


def decode_step(params: dict, tokens: jax.Array, cache: dict,
                config: ModelConfig) -> tuple[jax.Array, dict]:
    x, cache = _run(params, tokens, config, cache, mode="decode")
    logits = L.lm_logits(x, params["embed"], config)
    return logits, cache
