"""Sharded, async, elastic checkpointing (no orbax in this container).

Layout:  <dir>/step_<N>/
            manifest.json      — tree structure, dtypes, shapes, step
            <flat.key>.npy     — one array per leaf (host-gathered)
         <dir>/LATEST          — atomic pointer (written last)

Properties needed at 1000-node scale, all implemented and tested:
  * atomicity: writes go to ``step_N.tmp`` and are renamed only after the
    manifest is fsynced — a crash mid-save never corrupts the latest good
    checkpoint;
  * async: ``AsyncCheckpointer`` snapshots to host memory synchronously
    (cheap) and writes on a background thread — training continues;
  * elastic restore: ``restore`` takes target shardings; arrays are
    device_put with the *new* mesh layout, so a job can restart on a
    different worker count (tests shrink 8 -> 4 virtual devices);
  * bf16-safe: bfloat16 leaves are stored as uint16 with dtype recorded in
    the manifest (npy has no native bf16).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils import get_logger

log = get_logger(__name__)

_SEP = "__"


def _flatten(tree: Any) -> dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(_path_str(p) for p in path)
        out[key] = leaf
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save(directory: str, step: int, tree: Any) -> str:
    """Synchronous sharded save. Returns the checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    manifest = {"step": step, "leaves": {}}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        dtype = str(arr.dtype)
        if arr.dtype == jnp.bfloat16:
            arr = arr.view(np.uint16)
            dtype = "bfloat16"
        np.save(os.path.join(tmp, key + ".npy"), arr)
        manifest["leaves"][key] = {"dtype": dtype,
                                   "shape": list(arr.shape)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    latest_tmp = os.path.join(directory, "LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(os.path.basename(final))
        # without this the rename can publish an empty/torn pointer after
        # power loss, orphaning an otherwise-complete checkpoint
        f.flush()
        os.fsync(f.fileno())
    os.replace(latest_tmp, os.path.join(directory, "LATEST"))
    _fsync_dir(directory)
    log.info("checkpoint saved: %s", final)
    return final


def _fsync_dir(directory: str) -> None:
    """Persist the renames themselves: step_N and LATEST are directory
    entries, and surviving power loss needs the directory flushed too."""
    fd = os.open(directory, os.O_RDONLY)
    try:
        os.fsync(fd)
    except OSError:
        pass  # some filesystems refuse directory fsync; file fsyncs hold
    finally:
        os.close(fd)


def latest_step(directory: str) -> int | None:
    pointer = os.path.join(directory, "LATEST")
    if not os.path.exists(pointer):
        return None
    with open(pointer) as f:
        name = f.read().strip()
    return int(name.split("_")[-1])


def restore(directory: str, like: Any, step: int | None = None,
            shardings: Any = None) -> tuple[Any, int]:
    """Restore into the structure of ``like`` (pytree of arrays or
    ShapeDtypeStructs). ``shardings`` (matching pytree of Shardings) reshards
    for the *current* mesh — the elastic-restart path."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    flat_like = _flatten(like)
    flat_shardings = _flatten(shardings) if shardings is not None else {}
    restored: dict[str, Any] = {}
    for key in flat_like:
        meta = manifest["leaves"].get(key)
        if meta is None:
            raise KeyError(f"checkpoint {path} missing leaf {key}")
        arr = np.load(os.path.join(path, key + ".npy"))
        if meta["dtype"] == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        sh = flat_shardings.get(key)
        restored[key] = (jax.device_put(arr, sh) if sh is not None
                         else jnp.asarray(arr))
    leaves = [restored[key] for key in flat_like]
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, leaves), step


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write on a background thread."""

    def __init__(self, directory: str, keep: int = 3) -> None:
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    def save(self, step: int, tree: Any) -> None:
        self.wait()
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree)

        def _write():
            try:
                save(self.directory, step, host_tree)
                self._gc()
            except Exception as exc:  # surfaced on next wait()
                self._error = exc

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        if not os.path.isdir(self.directory):
            return
        steps = sorted(d for d in os.listdir(self.directory)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for old in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, old),
                          ignore_errors=True)
