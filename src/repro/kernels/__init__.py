"""Pallas TPU kernels for the compute hot spots the paper optimizes (SHARP's
GPU kernels -> TPU): ptycho modulus projection, RAAR combine, overlap
products, tomography ART row sweep, and flash attention for the LM serving
path. Each kernel ships kernel.py (pl.pallas_call + BlockSpec), ops.py
(jit'd wrapper with platform dispatch) and ref.py (pure-jnp oracle);
tests sweep shapes/dtypes against the oracle in interpret mode."""
