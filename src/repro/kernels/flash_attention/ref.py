"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True) -> jax.Array:
    """q, k, v: (BH, S, hd) fp32/bf16 -> (BH, Sq, hd)."""
    hd = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(hd)
    if causal:
        Sq, Skv = q.shape[1], k.shape[1]
        mask = jnp.tril(jnp.ones((Sq, Skv), bool), k=Skv - Sq)
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
