"""Jit'd wrapper: (B, S, H, hd) model layout -> kernel layout + dispatch.

Used by ``models/attention.py`` when ``attention_impl='pallas'``; pads S to
the block size, folds (B, H) into the kernel's batch axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel, ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    qpos=None, kpos=None,
                    block_q: int = 256, block_kv: int = 512,
                    use_pallas: bool | None = None) -> jax.Array:
    """q, k, v: (B, S, H, hd) (KV already repeated to H). Causal."""
    use_pallas = _on_tpu() if use_pallas is None else use_pallas
    B, S, H, hd = q.shape

    def to_bhsd(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, x.shape[1], hd)

    qb, kb, vb = map(to_bhsd, (q, k, v))
    bq = min(block_q, S)
    bkv = min(block_kv, S)
    pad = (-S) % bq if S % bq else 0
    pad = max(pad, (-S) % bkv if S % bkv else 0)
    if pad:
        qb = jnp.pad(qb, ((0, 0), (0, pad), (0, 0)))
        kb = jnp.pad(kb, ((0, 0), (0, pad), (0, 0)))
        vb = jnp.pad(vb, ((0, 0), (0, pad), (0, 0)))
    if use_pallas:
        out = kernel.flash_attention_bhsd(qb, kb, vb, block_q=bq,
                                          block_kv=bkv, causal=True,
                                          interpret=not _on_tpu())
    else:
        out = ref.attention_ref(qb, kb, vb, causal=True)
    out = out[:, :S]
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
