"""Causal flash attention (serving/prefill hot spot), Pallas TPU kernel.

Grid (B·H, n_q, n_kv), kv innermost. Running max / denominator / accumulator
live in VMEM scratch across the kv sweep for one q block (classic
flash-attention dataflow; this is what replaces the XLA blocked-attention
path's HBM round-trips for the score tiles — the dominant memory-roofline
term measured in §Perf). Causal skipping is structural: out-of-reach kv
blocks are masked via @pl.when, so no MXU work is issued for them.

Block shapes default to (block_q, head_dim) × (block_kv, head_dim) =
(256, hd) × (512, hd): for hd=128 fp32 scratch is 256·128·4 ≈ 128 KiB plus
the (256, 512) score tile ≈ 512 KiB — comfortably inside the ~16 MiB VMEM
with double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _make_kernel(scale: float, block_q: int, block_kv: int, n_kv: int,
                 causal: bool):
    def kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr):
        i_q = pl.program_id(1)
        i_k = pl.program_id(2)

        @pl.when(i_k == 0)
        def _init():
            m_scr[...] = jnp.full_like(m_scr, NEG_INF)
            l_scr[...] = jnp.zeros_like(l_scr)
            acc_scr[...] = jnp.zeros_like(acc_scr)

        def tile():
            q = q_ref[0].astype(jnp.float32)                  # (bq, hd)
            k = k_ref[0].astype(jnp.float32)                  # (bkv, hd)
            v = v_ref[0].astype(jnp.float32)
            s = jax.lax.dot_general(
                q, k, (((1,), (1,)), ((), ()))) * scale        # (bq, bkv)
            if causal:
                qpos = i_q * block_q + jax.lax.broadcasted_iota(
                    jnp.int32, (block_q, block_kv), 0)
                kpos = i_k * block_kv + jax.lax.broadcasted_iota(
                    jnp.int32, (block_q, block_kv), 1)
                s = jnp.where(kpos <= qpos, s, NEG_INF)
            m_prev = m_scr[...]
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
            p = jnp.exp(s - m_new[:, None])
            alpha = jnp.exp(m_prev - m_new)
            l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
            acc_scr[...] = (acc_scr[...] * alpha[:, None]
                            + jax.lax.dot_general(
                                p, v, (((1,), (0,)), ((), ()))))
            m_scr[...] = m_new

        if causal:
            # kv block reachable iff its first row index <= q block's last
            reachable = i_k * block_kv <= i_q * block_q + block_q - 1
            pl.when(reachable)(tile)
        else:
            tile()

        @pl.when(i_k == n_kv - 1)
        def _finish():
            l = jnp.maximum(l_scr[...], 1e-30)
            o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)

    return kernel


@functools.partial(jax.jit, static_argnames=("block_q", "block_kv",
                                              "causal", "interpret"))
def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array,
                         block_q: int = 256, block_kv: int = 512,
                         causal: bool = True,
                         interpret: bool = False) -> jax.Array:
    """q, k, v: (BH, S, hd) — S divisible by block sizes. Returns (BH, S, hd)."""
    BH, S, hd = q.shape
    Skv = k.shape[1]
    bq = min(block_q, S)
    bkv = min(block_kv, Skv)
    n_q, n_kv = S // bq, Skv // bkv
    scale = 1.0 / np.sqrt(hd)
    return pl.pallas_call(
        _make_kernel(scale, bq, bkv, n_kv, causal),
        grid=(BH, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bkv, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # running max
            pltpu.VMEM((bq,), jnp.float32),      # running denominator
            pltpu.VMEM((bq, hd), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
