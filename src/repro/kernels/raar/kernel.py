"""RAAR iteration combine (Luke 2005, paper eq. 7), Pallas TPU kernel.

    ψ' = 2β·π₂π₁ψ + (1-2β)·π₁ψ + β·(ψ - π₂ψ)

One fused elementwise pass over four complex fields (8 fp32 planes in, 2
out) — the per-iteration glue SHARP fuses on GPU; fusing it keeps the RAAR
update at one HBM round-trip instead of seven. β is compile-time static
(fixed per reconstruction).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _make_kernel(beta: float):
    def kernel(psi_re, psi_im, p1_re, p1_im, p21_re, p21_im, p2_re, p2_im,
               o_re, o_im):
        b = beta
        o_re[...] = (2.0 * b * p21_re[...] + (1.0 - 2.0 * b) * p1_re[...]
                     + b * (psi_re[...] - p2_re[...]))
        o_im[...] = (2.0 * b * p21_im[...] + (1.0 - 2.0 * b) * p1_im[...]
                     + b * (psi_im[...] - p2_im[...]))
    return kernel


@functools.partial(jax.jit,
                   static_argnames=("beta", "block_frames", "interpret"))
def raar_combine(psi_re, psi_im, p1_re, p1_im, p21_re, p21_im, p2_re, p2_im,
                 beta: float = 0.75, block_frames: int = 16,
                 interpret: bool = False):
    F, H, W = psi_re.shape
    fb = min(block_frames, F)
    grid = (-(-F // fb),)
    spec = pl.BlockSpec((fb, H, W), lambda i: (i, 0, 0))
    out_shape = [jax.ShapeDtypeStruct((F, H, W), psi_re.dtype)] * 2
    return pl.pallas_call(
        _make_kernel(beta),
        grid=grid,
        in_specs=[spec] * 8,
        out_specs=[spec, spec],
        out_shape=out_shape,
        interpret=interpret,
    )(psi_re, psi_im, p1_re, p1_im, p21_re, p21_im, p2_re, p2_im)
