"""Pure-jnp oracle for the RAAR combine kernel."""
from __future__ import annotations

import jax


def raar_combine_ref(psi_re, psi_im, p1_re, p1_im, p21_re, p21_im,
                     p2_re, p2_im, beta: float = 0.75):
    o_re = (2 * beta * p21_re + (1 - 2 * beta) * p1_re
            + beta * (psi_re - p2_re))
    o_im = (2 * beta * p21_im + (1 - 2 * beta) * p1_im
            + beta * (psi_im - p2_im))
    return o_re, o_im


def raar_combine_complex(psi: jax.Array, p1: jax.Array, p21: jax.Array,
                         p2: jax.Array, beta: float = 0.75) -> jax.Array:
    return 2 * beta * p21 + (1 - 2 * beta) * p1 + beta * (psi - p2)
