"""Jit'd wrapper for the RAAR combine (complex in/out, platform dispatch)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.raar import kernel, ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def raar_combine(psi: jax.Array, p1: jax.Array, p21: jax.Array,
                 p2: jax.Array, beta: float = 0.75,
                 use_pallas: bool | None = None) -> jax.Array:
    use_pallas = _on_tpu() if use_pallas is None else use_pallas
    if not use_pallas:
        return ref.raar_combine_complex(psi, p1, p21, p2, beta)
    planes = []
    for z in (psi, p1, p21, p2):
        planes += [jnp.real(z).astype(jnp.float32),
                   jnp.imag(z).astype(jnp.float32)]
    o_re, o_im = kernel.raar_combine(*planes, beta=beta,
                                     interpret=not _on_tpu())
    return jax.lax.complex(o_re, o_im)
