"""Jit'd wrapper: platform dispatch for the modulus projection.

On TPU the Pallas kernel runs compiled; elsewhere (this CPU container) it
runs in interpret mode — same kernel body, Python-interpreted, used by the
shape/dtype sweep tests against ref.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.modulus import kernel, ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def modulus_project(psi_f: jax.Array, mag: jax.Array,
                    use_pallas: bool | None = None) -> jax.Array:
    """psi_f: complex64 (F, H, W); mag: fp32 (F, H, W) -> complex64."""
    use_pallas = _on_tpu() if use_pallas is None else use_pallas
    re = jnp.real(psi_f).astype(jnp.float32)
    im = jnp.imag(psi_f).astype(jnp.float32)
    if use_pallas:
        ore, oim = kernel.modulus_project(re, im, mag,
                                          interpret=not _on_tpu())
    else:
        ore, oim = ref.modulus_project_ref(re, im, mag)
    return jax.lax.complex(ore, oim)
