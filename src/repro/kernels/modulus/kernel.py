"""Modulus-constraint projection π₁ (ptychography), Pallas TPU kernel.

    π₁(ψ)(q) = F⁻¹[ I(q) · Fψ(q) / |Fψ(q)| ]

The FFTs stay in XLA (TPU has native FFT); this kernel fuses the elementwise
magnitude renormalization — the per-frame hot loop SHARP runs as a CUDA
kernel. Complex data travels as separate re/im planes (TPU VREGs are real).

Blocking: frames are tiled along the leading axis; each (fb, H, W) block of
the five planes (re, im, mag -> out_re, out_im) resides in VMEM. For
128×128 frames and fb=16 the working set is 16·64 KiB·5 ≈ 5 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-12


def _modulus_kernel(re_ref, im_ref, mag_ref, ore_ref, oim_ref):
    re = re_ref[...]
    im = im_ref[...]
    mag = mag_ref[...]
    norm = jax.lax.rsqrt(re * re + im * im + EPS)
    scale = mag * norm
    ore_ref[...] = re * scale
    oim_ref[...] = im * scale


@functools.partial(jax.jit, static_argnames=("block_frames", "interpret"))
def modulus_project(re: jax.Array, im: jax.Array, mag: jax.Array,
                    block_frames: int = 16,
                    interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """re, im, mag: (F, H, W) fp32 -> (out_re, out_im)."""
    F, H, W = re.shape
    fb = min(block_frames, F)
    grid = (-(-F // fb),)
    spec = pl.BlockSpec((fb, H, W), lambda i: (i, 0, 0))
    out_shape = [jax.ShapeDtypeStruct((F, H, W), re.dtype)] * 2
    ore, oim = pl.pallas_call(
        _modulus_kernel,
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=out_shape,
        interpret=interpret,
    )(re, im, mag)
    return ore, oim
