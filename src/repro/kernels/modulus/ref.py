"""Pure-jnp oracle for the modulus projection kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.modulus.kernel import EPS


def modulus_project_ref(re: jax.Array, im: jax.Array, mag: jax.Array
                        ) -> tuple[jax.Array, jax.Array]:
    norm = jax.lax.rsqrt(re * re + im * im + EPS)
    scale = mag * norm
    return re * scale, im * scale


def modulus_project_complex(psi_f: jax.Array, mag: jax.Array) -> jax.Array:
    """Complex-typed reference used by the solver-level tests."""
    scale = mag / jnp.maximum(jnp.abs(psi_f), jnp.sqrt(EPS))
    return psi_f * scale
