"""Overlap-update products (ptychography, paper eqs. 4-5), Pallas TPU kernel.

Per frame j the probe/object updates need the complex products

    num_j = ψ_j · conj(w_j)      (w = probe for the object update,
    den_j = |w_j|²                object patch for the probe update)

SHARP computes these inside CUDA kernels with atomics for the scatter; on
TPU the scatter-add runs as an XLA segment-sum over precomputed patch
indices (apps/ptycho/solver.py) while this kernel fuses the per-frame
products — one VMEM pass over 4 input planes, 3 outputs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _overlap_kernel(a_re, a_im, b_re, b_im, n_re, n_im, den):
    bre = b_re[...]
    bim = b_im[...]
    are = a_re[...]
    aim = a_im[...]
    # a · conj(b)
    n_re[...] = are * bre + aim * bim
    n_im[...] = aim * bre - are * bim
    den[...] = bre * bre + bim * bim


@functools.partial(jax.jit, static_argnames=("block_frames", "interpret"))
def overlap_products(a_re, a_im, b_re, b_im, block_frames: int = 16,
                     interpret: bool = False):
    """a, b: (F, H, W) fp32 planes -> (num_re, num_im, |b|²)."""
    F, H, W = a_re.shape
    fb = min(block_frames, F)
    grid = (-(-F // fb),)
    spec = pl.BlockSpec((fb, H, W), lambda i: (i, 0, 0))
    out_shape = [jax.ShapeDtypeStruct((F, H, W), a_re.dtype)] * 3
    return pl.pallas_call(
        _overlap_kernel,
        grid=grid,
        in_specs=[spec] * 4,
        out_specs=[spec] * 3,
        out_shape=out_shape,
        interpret=interpret,
    )(a_re, a_im, b_re, b_im)
