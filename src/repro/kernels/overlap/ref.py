"""Pure-jnp oracle for the overlap products kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def overlap_products_ref(a_re, a_im, b_re, b_im):
    n_re = a_re * b_re + a_im * b_im
    n_im = a_im * b_re - a_re * b_im
    den = b_re * b_re + b_im * b_im
    return n_re, n_im, den


def overlap_products_complex(a: jax.Array, b: jax.Array
                             ) -> tuple[jax.Array, jax.Array]:
    """(a · conj(b), |b|²)."""
    return a * jnp.conj(b), jnp.square(jnp.abs(b))
