"""Jit'd wrapper for overlap products (complex in/out, platform dispatch)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.overlap import kernel, ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def overlap_products(a: jax.Array, b: jax.Array,
                     use_pallas: bool | None = None
                     ) -> tuple[jax.Array, jax.Array]:
    """a, b complex (F, H, W) -> (a·conj(b) complex, |b|² fp32)."""
    use_pallas = _on_tpu() if use_pallas is None else use_pallas
    if not use_pallas:
        return ref.overlap_products_complex(a, b)
    b = jnp.broadcast_to(b, a.shape)
    n_re, n_im, den = kernel.overlap_products(
        jnp.real(a).astype(jnp.float32), jnp.imag(a).astype(jnp.float32),
        jnp.real(b).astype(jnp.float32), jnp.imag(b).astype(jnp.float32),
        interpret=not _on_tpu())
    return jax.lax.complex(n_re, n_im), den
