"""ART row-action sweep (tomography, paper Fig. 12), Pallas TPU kernel.

Kaczmarz/ART is inherently sequential over rays:

    for each ray j:   f += β · (b_j - ⟨A_j, f⟩) / ‖A_j‖² · A_j

TomViz runs this as a Python/NumPy loop; SHARP-era GPUs would need global
synchronization per row. The TPU-idiomatic port: the image f lives in VMEM
as an output block with a CONSTANT index map — Pallas keeps it resident
across sequential grid steps (grid = (iters, rows)) while the rows of the
(pre-normalized) system matrix stream HBM→VMEM one block at a time. The
per-step work (dot + axpy over Ncol) is VPU-shaped; data movement is one
row per step, i.e. the streaming bound the roofline predicts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _make_kernel(beta: float):
    def kernel(a_ref, b_ref, rip_ref, f0_ref, f_ref):
        it = pl.program_id(0)
        j = pl.program_id(1)

        @pl.when(jnp.logical_and(it == 0, j == 0))
        def _():
            f_ref[...] = f0_ref[...]

        row = a_ref[0, :]
        f = f_ref[...]
        resid = (b_ref[0] - jnp.sum(row * f)) * rip_ref[0]
        f_ref[...] = f + beta * resid * row

    return kernel


@functools.partial(jax.jit,
                   static_argnames=("beta", "iters", "interpret"))
def art_sweep(A: jax.Array, b: jax.Array, inv_rip: jax.Array,
              f0: jax.Array, beta: float = 1.0, iters: int = 1,
              interpret: bool = False) -> jax.Array:
    """A: (Nrow, Ncol) fp32; b: (Nrow,); inv_rip: (Nrow,) = 1/‖A_j‖²;
    f0: (Ncol,) initial image. Returns f after ``iters`` full sweeps."""
    nrow, ncol = A.shape
    return pl.pallas_call(
        _make_kernel(beta),
        grid=(iters, nrow),
        in_specs=[
            pl.BlockSpec((1, ncol), lambda i, j: (j, 0)),   # row stream
            pl.BlockSpec((1,), lambda i, j: (j,)),
            pl.BlockSpec((1,), lambda i, j: (j,)),
            pl.BlockSpec((ncol,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((ncol,), lambda i, j: (0,)),  # VMEM-resident
        out_shape=jax.ShapeDtypeStruct((ncol,), jnp.float32),
        interpret=interpret,
    )(A, b, inv_rip, f0)
