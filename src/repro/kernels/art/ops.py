"""Jit'd wrapper for the ART sweep (platform dispatch + row-norm precompute)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.art import kernel, ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def art_reconstruct_slice(A: jax.Array, b: jax.Array, f0: jax.Array,
                          beta: float = 1.0, iters: int = 1,
                          use_pallas: bool | None = None) -> jax.Array:
    """One tilt-series slice: A (Nrow, Ncol), b (Nrow,), f0 (Ncol,)."""
    use_pallas = _on_tpu() if use_pallas is None else use_pallas
    rip = jnp.sum(A * A, axis=1)
    inv_rip = jnp.where(rip > 0, 1.0 / jnp.maximum(rip, 1e-12), 0.0)
    if use_pallas:
        return kernel.art_sweep(A, b, inv_rip, f0, beta=beta, iters=iters,
                                interpret=not _on_tpu())
    return ref.art_sweep_ref(A, b, inv_rip, f0, beta=beta, iters=iters)
