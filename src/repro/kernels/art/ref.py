"""Pure-jnp oracle for the ART sweep (paper Fig. 12 inner loop)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def art_sweep_ref(A: jax.Array, b: jax.Array, inv_rip: jax.Array,
                  f0: jax.Array, beta: float = 1.0,
                  iters: int = 1) -> jax.Array:
    def row_step(f, xs):
        row, bj, irip = xs
        resid = (bj - jnp.dot(row, f)) * irip
        return f + beta * resid * row, None

    def sweep(f, _):
        f, _ = jax.lax.scan(row_step, f, (A, b, inv_rip))
        return f, None

    f, _ = jax.lax.scan(sweep, f0, None, length=iters)
    return f
