import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax-importing module: jax locks the device count on
# first init. 512 placeholder host devices let jax.make_mesh build the
# production meshes; nothing is allocated (inputs are ShapeDtypeStructs).

"""Multi-pod dry-run: lower + compile EVERY (arch × shape × mesh) cell.

(No ``from __future__ import annotations`` here: the XLA_FLAGS lines above
must stay the first statements of the module.)

For each cell this prints ``compiled.memory_analysis()`` (proves the program
fits / records honest bytes-per-device) and ``compiled.cost_analysis()``,
runs the trip-count-aware HLO cost walk (launch/hlocost.py), derives the
three roofline terms, and appends a JSON record under
``results/dryrun/<mesh>/<arch>__<shape>.json`` (resumable; failures recorded
with tracebacks — a sharding mismatch here is a bug in the system).

Usage:
    python -m repro.launch.dryrun --arch gemma-7b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all --mesh both --skip-existing
"""

import argparse
import gzip
import json
import time
import traceback

import jax
import numpy as np

from repro.configs import (SHAPES, all_archs, applicable_shapes, get_config)
from repro.utils import peak_memory_bytes
from repro.configs.base import ModelConfig, OptimizerConfig, ShapeConfig
from repro.launch import mesh as mesh_lib
from repro.launch.hlocost import hlo_cost
from repro.models.registry import get_model
from repro.training import lower_cell

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "results", "dryrun")


def model_param_counts(config: ModelConfig) -> tuple[int, int]:
    """(total, active-per-token) parameter counts."""
    model = get_model(config)
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0), config))
    total = sum(int(np.prod(l.shape))
                for l in jax.tree_util.tree_leaves(shapes))
    active = total
    if config.num_experts > 0:
        from repro.models.moe import padded_experts
        per_expert = config.d_model * config.d_ff * (3 if config.mlp_gated
                                                     else 2)
        # padded experts (a2a EP) contribute memory but no active compute
        expert_total_padded = (config.num_layers * padded_experts(config)
                               * per_expert)
        expert_active = (config.num_layers * config.experts_per_token
                         * per_expert)
        active = total - expert_total_padded + expert_active
    return total, active


def model_flops(config: ModelConfig, shape: ShapeConfig) -> float:
    """Analytical 'useful' FLOPs per step (the 6·N·D yardstick + attention)."""
    _, n_active = model_param_counts(config)
    B, S = shape.global_batch, shape.seq_len
    hd = config.resolved_head_dim
    h = config.num_heads
    if shape.kind == "train":
        tokens = B * S
        base = 6.0 * n_active * tokens
        if config.family in ("dense", "moe", "vlm", "audio"):
            n_attn = config.num_layers + config.encoder_layers
            base += 6.0 * B * S * S * h * hd * n_attn / 2  # causal half
        elif config.family == "hybrid":
            n_attn = sum(k == "attn" for k in
                         __import__("repro.models.rglru",
                                    fromlist=["layer_kinds"]).layer_kinds(config))
            w = min(config.local_window, S)
            base += 6.0 * B * S * w * h * hd * n_attn
        return base
    if shape.kind == "prefill":
        tokens = B * S
        base = 2.0 * n_active * tokens
        if config.family in ("dense", "moe", "vlm", "audio"):
            n_attn = config.num_layers + config.encoder_layers
            base += 2.0 * B * S * S * h * hd * n_attn / 2
        elif config.family == "hybrid":
            n_attn = sum(k == "attn" for k in
                         __import__("repro.models.rglru",
                                    fromlist=["layer_kinds"]).layer_kinds(config))
            base += 2.0 * B * S * min(config.local_window, S) * h * hd * n_attn
        return base
    # decode: one token, full cache read
    base = 2.0 * n_active * B
    if config.family in ("dense", "moe", "vlm", "audio"):
        base += 4.0 * B * S * h * hd * config.num_layers
    elif config.family == "hybrid":
        n_attn = sum(k == "attn" for k in
                     __import__("repro.models.rglru",
                                fromlist=["layer_kinds"]).layer_kinds(config))
        base += 4.0 * B * min(config.local_window, S) * h * hd * n_attn
    elif config.family == "ssm":
        base += 4.0 * B * config.num_layers * config.num_heads * hd * hd
    return base


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             outdir: str, save_hlo: bool = False,
             overrides: dict | None = None, tag: str = "") -> dict:
    config = get_config(arch)
    trainer = compression = None
    opt = None
    if overrides:
        overrides = dict(overrides)
        trainer = overrides.pop("_trainer", None)
        compression = overrides.pop("_compression", None)
        opt_kw = overrides.pop("_opt", None)
        if opt_kw:
            opt = OptimizerConfig(**opt_kw)
        if overrides:
            config = config.replace(**overrides)
    shape = SHAPES[shape_name]
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    rec: dict = {"arch": arch, "shape": shape_name,
                 "mesh": "2x16x16" if multi_pod else "16x16",
                 "chips": n_chips, "tag": tag, "ok": False}
    t0 = time.time()
    try:
        if trainer == "dp":
            from repro.parallel.dp import lower_dp_cell
            lowered = lower_dp_cell(config, shape, mesh, opt=opt,
                                    compression=compression)
        else:
            lowered, kind = lower_cell(config, shape, mesh, opt=opt)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)
        ma = compiled.memory_analysis()
        print(f"--- {arch} × {shape_name} × {rec['mesh']} memory_analysis:")
        print(f"    args={ma.argument_size_in_bytes/2**30:.3f}GiB "
              f"out={ma.output_size_in_bytes/2**30:.3f}GiB "
              f"temp={ma.temp_size_in_bytes/2**30:.3f}GiB "
              f"peak={peak_memory_bytes(ma)/2**30:.3f}GiB per device")
        ca = compiled.cost_analysis()
        print(f"    cost_analysis: flops={ca.get('flops', 0):.3e} "
              f"bytes={ca.get('bytes accessed', 0):.3e} (body-once, see walker)")
        rec["memory"] = {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "peak_bytes": peak_memory_bytes(ma),
        }
        rec["xla_cost"] = {"flops": ca.get("flops", 0.0),
                           "bytes": ca.get("bytes accessed", 0.0)}
        t2 = time.time()
        txt = compiled.as_text()
        cost = hlo_cost(txt, pod_size=256 if multi_pod else 0)
        rec["walk_s"] = round(time.time() - t2, 1)
        rec["hlo_cost"] = cost
        # roofline terms (per-chip costs; see EXPERIMENTS.md §Roofline)
        mf = model_flops(config, shape)
        n_total, n_active = model_param_counts(config)
        compute_s = cost["flops"] / mesh_lib.PEAK_FLOPS_BF16
        memory_s = cost["bytes"] / mesh_lib.HBM_BW
        coll_s = cost["ici_bytes"] / mesh_lib.ICI_BW
        dcn_s = cost["dcn_bytes"] / mesh_lib.DCN_BW
        dominant = max((("compute", compute_s), ("memory", memory_s),
                        ("collective", coll_s + dcn_s)), key=lambda kv: kv[1])
        rec["roofline"] = {
            "compute_s": compute_s, "memory_s": memory_s,
            "collective_s": coll_s, "dcn_s": dcn_s,
            "dominant": dominant[0],
            "model_flops": mf,
            "model_flops_per_chip": mf / n_chips,
            "useful_ratio": (mf / n_chips) / max(cost["flops"], 1.0),
            "params_total": n_total, "params_active": n_active,
        }
        rec["ok"] = True
        if save_hlo:
            with gzip.open(os.path.join(
                    outdir, f"{arch}__{shape_name}{tag}.hlo.txt.gz"),
                    "wt") as f:
                f.write(txt)
        print(f"    roofline: compute={compute_s*1e3:.2f}ms "
              f"memory={memory_s*1e3:.2f}ms ici={coll_s*1e3:.2f}ms "
              f"dcn={dcn_s*1e3:.2f}ms dominant={dominant[0]} "
              f"useful={rec['roofline']['useful_ratio']:.2f}")
    except Exception:
        rec["error"] = traceback.format_exc()
        print(f"!!! {arch} × {shape_name} FAILED:\n{rec['error']}")
    os.makedirs(outdir, exist_ok=True)
    with open(os.path.join(outdir, f"{arch}__{shape_name}{tag}.json"),
              "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--override", default=None,
                    help="JSON dict of ModelConfig overrides (perf iters)")
    ap.add_argument("--tag", default="",
                    help="suffix for the result JSON (perf iters)")
    args = ap.parse_args()
    overrides = json.loads(args.override) if args.override else None

    cells: list[tuple[str, str]] = []
    if args.all:
        for arch in all_archs():
            for sh in applicable_shapes(get_config(arch)):
                cells.append((arch, sh))
        # cheap cells first so results stream in
        def cost_key(cell):
            cfg = get_config(cell[0])
            return (cfg.num_layers * cfg.d_model * cfg.d_model
                    * (3 if cell[1] == "train_4k" else 1))
        cells.sort(key=cost_key)
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    base_out = args.out or os.path.normpath(RESULTS)
    n_ok = n_fail = n_skip = 0
    for multi in meshes:
        outdir = os.path.join(base_out, "multi" if multi else "single")
        for arch, sh in cells:
            path = os.path.join(outdir, f"{arch}__{sh}.json")
            if args.skip_existing and os.path.exists(path):
                with open(path) as f:
                    if json.load(f).get("ok"):
                        n_skip += 1
                        continue
            rec = run_cell(arch, sh, multi, outdir, save_hlo=args.save_hlo,
                           overrides=overrides, tag=args.tag)
            n_ok += rec["ok"]
            n_fail += not rec["ok"]
    print(f"dry-run done: ok={n_ok} fail={n_fail} skipped={n_skip}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
