"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state): single-pod (16, 16) = 256 chips, multi-pod (2, 16, 16) =
512 chips across a DCN 'pod' axis. The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import; everything else in the repo sees the real device count.
"""
from __future__ import annotations

import jax

from repro.utils import make_mesh_compat


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2) -> jax.sharding.Mesh:
    """Small mesh for multi-device subprocess tests."""
    return make_mesh_compat((data, model), ("data", "model"))


# Hardware model for the roofline (TPU v5e-class, per assignment):
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (we report per-chip wire bytes / this)
DCN_BW = 6.25e9                 # bytes/s per chip across pods (assumed, noted)
