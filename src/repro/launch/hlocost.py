"""Trip-count-aware HLO cost model (the §Roofline engine).

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE — for
scan-over-layers models that undercounts FLOPs by ~num_layers×. This walker
parses optimized HLO text and accumulates, recursively from ENTRY:

  * flops            — dot ops: 2 · |output| · K (batch/contracting dims from
                       the instruction attributes); while bodies multiplied by
                       ``known_trip_count`` from backend_config;
  * bytes            — Σ (operand + output bytes) over executed instructions
                       (the fusion-boundary HBM-traffic model; parameters /
                       GTEs / bitcasts / tuples excluded);
  * collective wire bytes per chip — all-reduce 2·b·(n-1)/n, all-gather /
                       reduce-scatter / all-to-all b·(n-1)/n,
                       collective-permute b; group size n parsed from
                       ``replica_groups`` (both explicit ``{{0,1},..}`` and
                       iota ``[G,S]<=[N]`` forms); collectives whose groups
                       span pod boundaries are tallied separately as DCN.

Validated against hand-countable programs in ``tests/test_hlocost.py``
(matmul chains, scans, psums at several mesh sizes).
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field

import numpy as np

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "f8e5m2": 1, "f8e4m3": 1, "f8e4m3fn": 1, "f8e4m3b11fnuz": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 0.5, "u4": 0.5, "pred": 1,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SKIP_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "partition-id", "replica-id", "iota"}

_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "all-reduce-start", "all-gather-start",
                "collective-permute-start", "reduce-scatter-start",
                "ragged-all-to-all"}


# -- shape parsing ------------------------------------------------------------
def shape_bytes(type_str: str) -> float:
    """Total bytes of a (possibly tuple) HLO type string."""
    return sum(_elem_count(s) * _DTYPE_BYTES.get(s[0], 4)
               for s in _iter_shapes(type_str))


def shape_elems(type_str: str) -> int:
    return int(sum(_elem_count(s) for s in _iter_shapes(type_str)))


_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _iter_shapes(type_str: str):
    for m in _SHAPE_RE.finditer(type_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",") if d) if dims else ()
        yield (dtype, shape)


def _elem_count(s) -> int:
    _, shape = s
    return int(np.prod(shape)) if shape else 1


def _dims_of(type_str: str) -> tuple[int, ...]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return ()
    return tuple(int(d) for d in m.group(2).split(",") if d)


# -- HLO parsing --------------------------------------------------------------
@dataclass
class Instruction:
    name: str
    type_str: str
    op: str
    operands: list[str]
    attrs: str
    raw: str
    root: bool = False


@dataclass
class Computation:
    name: str
    instructions: list[Instruction] = field(default_factory=list)
    table: dict[str, Instruction] = field(default_factory=dict)


_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_INST_RE = re.compile(
    r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^=]*?\)|[a-z0-9]+\[[\d,]*\](?:{[^}]*})?))\s+"
    r"([\w\-]+)\((.*?)\)(.*)$")


def parse_hlo(text: str) -> tuple[dict[str, Computation], str]:
    """Returns ({name: computation}, entry_name)."""
    comps: dict[str, Computation] = {}
    current: Computation | None = None
    entry = None
    comment_re = re.compile(r"/\*[^*]*\*/")
    for line in text.splitlines():
        if "/*" in line:
            line = comment_re.sub("", line)
        stripped = line.strip()
        if stripped.startswith("}"):
            current = None
            continue
        m = _COMP_RE.match(stripped)
        if m and " = " not in stripped:
            current = Computation(m.group(1))
            comps[current.name] = current
            if stripped.startswith("ENTRY"):
                entry = current.name
            continue
        if current is None:
            continue
        mi = _INST_RE.match(line)
        if not mi:
            continue
        root_flag, name, type_str, op, operand_str, attrs = mi.groups()
        operands = []
        depth = 0
        cur = ""
        for ch in operand_str:
            if ch == "(" or ch == "{" or ch == "[":
                depth += 1
            elif ch == ")" or ch == "}" or ch == "]":
                depth -= 1
            if ch == "," and depth == 0:
                operands.append(cur.strip())
                cur = ""
            else:
                cur += ch
        if cur.strip():
            operands.append(cur.strip())
        operands = [o.lstrip("%").split(" ")[-1].lstrip("%") for o in operands]
        inst = Instruction(name, type_str, op, operands, attrs, line,
                           root=bool(root_flag))
        current.instructions.append(inst)
        current.table[name] = inst
    if entry is None:
        raise ValueError("no ENTRY computation found")
    return comps, entry


# -- per-instruction costs ---------------------------------------------------
_TRIP_RE = re.compile(r'known_trip_count.{0,6}n.{0,4}?(\d+)')
_CALL_RE = re.compile(r'(?:calls|to_apply|body|condition)=%?([\w.\-]+)')
_COND_BRANCH_RE = re.compile(r'branch_computations={([^}]*)}')
_GROUPS_EXPL_RE = re.compile(r'replica_groups=\{(\{[^=]*?\})\}')
_GROUPS_IOTA_RE = re.compile(
    r'replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?')
_DOT_CONTRACT_RE = re.compile(r'lhs_contracting_dims=\{([\d,]*)\}')
_DOT_BATCH_RE = re.compile(r'lhs_batch_dims=\{([\d,]*)\}')


def _dot_flops(inst: Instruction, comp: Computation) -> float:
    out_elems = shape_elems(inst.type_str)
    lhs = comp.table.get(inst.operands[0])
    if lhs is None:
        return 0.0
    lhs_dims = _dims_of(lhs.type_str)
    mc = _DOT_CONTRACT_RE.search(inst.attrs)
    contract = [int(d) for d in mc.group(1).split(",") if d] if mc else []
    k = int(np.prod([lhs_dims[d] for d in contract])) if contract else 1
    return 2.0 * out_elems * k


def _replica_groups(attrs: str, pod_size: int) -> tuple[int, bool]:
    """Returns (group_size, crosses_pod)."""
    m = _GROUPS_IOTA_RE.search(attrs)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        reshape_dims = [int(d) for d in m.group(3).split(",")]
        n = int(np.prod(reshape_dims))
        ids = np.arange(n).reshape(reshape_dims)
        if m.group(4):
            perm = [int(d) for d in m.group(4).split(",")]
            ids = ids.transpose(perm)
        groups = ids.reshape(g, s)
        crosses = bool(pod_size and np.any(
            (groups // pod_size) != (groups[:, :1] // pod_size)))
        return s, crosses
    m = _GROUPS_EXPL_RE.search(attrs)
    if m:
        first = m.group(1).split("}")[0].strip("{} ")
        ids = [int(x) for x in first.split(",") if x.strip()]
        crosses = bool(pod_size and ids and
                       any(i // pod_size != ids[0] // pod_size for i in ids))
        return max(len(ids), 1), crosses
    return 1, False


def _collective_bytes(inst: Instruction, comp: Computation,
                      pod_size: int) -> tuple[float, bool]:
    """Per-chip wire bytes for one collective op."""
    n, crosses = _replica_groups(inst.attrs, pod_size)
    if n <= 1:
        return 0.0, crosses
    op = inst.op.replace("-start", "")
    out_b = shape_bytes(inst.type_str)
    in_b = sum(shape_bytes(comp.table[o].type_str)
               for o in inst.operands if o in comp.table)
    frac = (n - 1) / n
    if op == "all-reduce":
        return 2.0 * in_b * frac, crosses
    if op == "all-gather":
        return out_b * frac, crosses
    if op == "reduce-scatter":
        return in_b * frac, crosses
    if op in ("all-to-all", "ragged-all-to-all"):
        return in_b * frac, crosses
    if op == "collective-permute":
        return in_b, crosses
    return 0.0, crosses


# -- recursive walk ------------------------------------------------------------
@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    ici_bytes: float = 0.0
    dcn_bytes: float = 0.0
    collectives: dict = field(default_factory=dict)
    transcendentals: float = 0.0

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        self.ici_bytes += mult * other.ici_bytes
        self.dcn_bytes += mult * other.dcn_bytes
        self.transcendentals += mult * other.transcendentals
        for k, v in other.collectives.items():
            self.collectives[k] = self.collectives.get(k, 0.0) + mult * v

    def as_dict(self) -> dict:
        return {"flops": self.flops, "bytes": self.bytes,
                "ici_bytes": self.ici_bytes, "dcn_bytes": self.dcn_bytes,
                "transcendentals": self.transcendentals,
                "collectives": dict(self.collectives)}


_TRANSCENDENTAL_OPS = {"exponential", "log", "tanh", "rsqrt", "sqrt", "power",
                       "cosine", "sine", "logistic", "exponential-minus-one"}

_SLICE_OPS = {"dynamic-slice", "slice", "gather"}


def _fusion_input_bytes(inst: Instruction, comp: Computation,
                        comps: dict[str, Computation]) -> float:
    """HBM read bytes for a fusion's operands. A scan body reads its stacked
    xs through dynamic-slice: the real traffic is the SLICE, not the whole
    stacked buffer — count the slice sizes when an operand's only uses inside
    the fused computation are slicing ops."""
    called = None
    for sub in _CALL_RE.findall(inst.attrs):
        if sub in comps:
            called = comps[sub]
            break
    total = 0.0
    params: dict[int, str] = {}
    if called is not None:
        for ci in called.instructions:
            if ci.op == "parameter":
                m = re.search(r"parameter\((\d+)\)", ci.raw)
                if m:
                    params[int(m.group(1))] = ci.name
    for pos, opnd in enumerate(inst.operands):
        full = shape_bytes(comp.table[opnd].type_str)             if opnd in comp.table else 0.0
        if called is None or pos not in params:
            total += full
            continue
        pname = params[pos]
        uses = [ci for ci in called.instructions if pname in ci.operands]
        if uses and all(u.op in _SLICE_OPS for u in uses):
            total += sum(shape_bytes(u.type_str) for u in uses)
        else:
            total += full
    return total


def _fusion_output_bytes(inst: Instruction,
                         comps: dict[str, Computation]) -> float:
    """HBM write bytes for a fusion's output. In-place dynamic-update-slice
    fusions (scan carries) write only the updated region."""
    for sub in _CALL_RE.findall(inst.attrs):
        called = comps.get(sub)
        if called is None:
            continue
        for ci in called.instructions:
            if ci.root and ci.op == "dynamic-update-slice":
                upd = ci.operands[1] if len(ci.operands) > 1 else None
                if upd and upd in called.table:
                    # read-modify-write of the updated region
                    return 2.0 * shape_bytes(called.table[upd].type_str)
    return shape_bytes(inst.type_str)


def _comp_cost(comp: Computation, comps: dict[str, Computation],
               pod_size: int, memo: dict[str, Cost],
               in_fusion: bool = False) -> Cost:
    key = comp.name + ("#f" if in_fusion else "")
    if key in memo:
        return memo[key]
    total = Cost()
    for inst in comp.instructions:
        op = inst.op
        if op in _SKIP_OPS:
            continue
        if op == "while":
            body = _CALL_RE.findall(inst.attrs)
            mt = _TRIP_RE.search(inst.attrs)
            trips = int(mt.group(1)) if mt else 1
            for sub in body:
                if sub in comps:
                    total.add(_comp_cost(comps[sub], comps, pod_size, memo),
                              mult=trips)
            continue
        if op == "conditional":
            mb = _COND_BRANCH_RE.search(inst.attrs)
            branches = []
            if mb:
                branches = [b.strip().lstrip("%")
                            for b in mb.group(1).split(",")]
            else:
                branches = _CALL_RE.findall(inst.attrs)
            sub_costs = [_comp_cost(comps[b], comps, pod_size, memo)
                         for b in branches if b in comps]
            if sub_costs:
                # static schedule: both branches occupy the program; take max
                best = max(sub_costs, key=lambda c: c.flops + c.bytes)
                total.add(best)
            continue
        if op == "call":
            for sub in _CALL_RE.findall(inst.attrs):
                if sub in comps:
                    total.add(_comp_cost(comps[sub], comps, pod_size, memo))
            continue
        if op == "fusion":
            # bytes at the fusion boundary (slice/in-place aware);
            # flops from dots inside
            if not in_fusion:
                total.bytes += (_fusion_input_bytes(inst, comp, comps)
                                + _fusion_output_bytes(inst, comps))
            for sub in _CALL_RE.findall(inst.attrs):
                if sub in comps:
                    c = _comp_cost(comps[sub], comps, pod_size, memo,
                                   in_fusion=True)
                    total.flops += c.flops
                    total.transcendentals += c.transcendentals
            continue
        if op in _COLLECTIVES:
            wire, crosses = _collective_bytes(inst, comp, pod_size)
            if crosses:
                total.dcn_bytes += wire
            else:
                total.ici_bytes += wire
            base = op.replace("-start", "")
            total.collectives[base] = total.collectives.get(base, 0.0) + wire
            if not in_fusion:
                total.bytes += shape_bytes(inst.type_str)
            continue
        if op in ("all-reduce-done", "all-gather-done", "async-done",
                  "collective-permute-done", "copy-done", "copy-start"):
            continue
        # generic op
        if op in ("dot", "dot-general"):
            total.flops += _dot_flops(inst, comp)
        elif op == "convolution":
            # no convs in this repo's models (conv frontend is stubbed;
            # rglru conv is expressed as shifted multiplies)
            total.flops += 2.0 * shape_elems(inst.type_str)
        elif op in _TRANSCENDENTAL_OPS:
            total.transcendentals += shape_elems(inst.type_str)
        if not in_fusion:
            out_b = shape_bytes(inst.type_str)
            if op in _SLICE_OPS:
                in_b = out_b                 # read only the sliced region
            elif op == "dynamic-update-slice":
                upd = (shape_bytes(comp.table[inst.operands[1]].type_str)
                       if len(inst.operands) > 1
                       and inst.operands[1] in comp.table else out_b)
                in_b = upd                   # in-place RMW of the region
                out_b = upd
            else:
                in_b = sum(shape_bytes(comp.table[o].type_str)
                           for o in inst.operands if o in comp.table)
            total.bytes += out_b + in_b
    memo[key] = total
    return total


def hlo_cost(text: str, pod_size: int = 0) -> dict:
    """Walk optimized HLO text; returns per-chip cost dict.

    ``pod_size``: devices per pod (256 for the production meshes) — used to
    split collective bytes into ICI vs DCN."""
    comps, entry = parse_hlo(text)
    memo: dict[str, Cost] = {}
    cost = _comp_cost(comps[entry], comps, pod_size, memo)
    return cost.as_dict()


def while_breakdown(text: str, pod_size: int = 0) -> list[dict]:
    """Per-while-loop cost attribution (nested, with cumulative trip
    multipliers) — the §Perf tool for identifying which loop (layers scan,
    attention q/kv scans, CE chunks, MoE dispatch) owns each roofline term.
    Returns rows {path, trips, total_trips, flops, bytes, ici_bytes}."""
    comps, entry = parse_hlo(text)
    memo: dict[str, Cost] = {}
    rows: list[dict] = []

    def visit(comp: Computation, mult: float, depth: int, path: str) -> None:
        for inst in comp.instructions:
            if inst.op != "while":
                continue
            mt = _TRIP_RE.search(inst.attrs)
            trips = int(mt.group(1)) if mt else 1
            subs = [s for s in _CALL_RE.findall(inst.attrs) if s in comps]
            body_cost = Cost()
            for s in subs:
                body_cost.add(_comp_cost(comps[s], comps, pod_size, memo))
            label = f"{path}/while@{inst.name}[{trips}]"
            rows.append({
                "path": label, "depth": depth, "trips": trips,
                "total_trips": mult * trips,
                "flops": body_cost.flops * mult * trips,
                "bytes": body_cost.bytes * mult * trips,
                "ici_bytes": body_cost.ici_bytes * mult * trips,
                "carry_type": inst.type_str[:200],
            })
            for s in subs:
                visit(comps[s], mult * trips, depth + 1, label)

    visit(comps[entry], 1.0, 0, "entry")
    return rows
