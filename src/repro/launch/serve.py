"""Serving driver: batched request streaming through the Spark-MPI stack.

Requests (prompts) arrive on a broker topic; the streaming context forms
micro-batches; each batch is prefilled once and decoded for N tokens with
the cached serve step — the near-real-time loop of the paper with an LM as
the "MPI application". Reports per-batch latency vs. the batch interval.

    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
        --reduced --requests 16 --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import Broker, Context, StreamingContext
from repro.models.registry import get_model
from repro.training import build_serve_fns
from repro.utils import get_logger

log = get_logger(__name__)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    config = get_config(args.arch, reduced=args.reduced)
    model = get_model(config)
    params = model.init(jax.random.PRNGKey(args.seed), config)
    prefill, decode = build_serve_fns(config)
    prefill = jax.jit(prefill)
    decode = jax.jit(decode, donate_argnums=(2,))

    broker = Broker()
    broker.create_topic("requests", partitions=1)
    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        broker.produce("requests", {
            "id": i,
            "prompt": rng.integers(0, config.vocab_size,
                                   (args.prompt_len,), dtype=np.int32)})

    ctx = Context()
    sc = StreamingContext(ctx, broker, max_records_per_partition=args.batch)
    sc.subscribe(["requests"])
    results: dict[int, list[int]] = {}

    def on_batch(rdd, info):
        reqs = rdd.collect()
        if not reqs:
            return None
        while len(reqs) < args.batch:         # pad the last micro-batch
            reqs.append(reqs[-1])
        prompts = jnp.asarray(np.stack([r["prompt"] for r in reqs]))
        batch = {"tokens": prompts}
        max_len = args.prompt_len + args.gen
        logits, cache = model.prefill(params, batch, config, max_len=max_len)
        tokens = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        outs = [np.asarray(tokens)[:, 0]]
        for _ in range(args.gen - 1):
            logits, cache = decode(params, tokens, cache)
            tokens = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            outs.append(np.asarray(tokens)[:, 0])
        gen = np.stack(outs, axis=1)
        for r, g in zip(reqs, gen):
            results.setdefault(int(r["id"]), list(map(int, g)))
        return len(reqs)

    sc.foreach_batch(on_batch)
    t0 = time.time()
    while len(results) < args.requests:
        if sc.run_one_batch() is None:
            break
    dt = time.time() - t0
    rep = sc.realtime_report()
    n_tok = sum(len(v) for v in results.values())
    log.info("served %d requests, %d tokens in %.2fs (%.1f tok/s; "
             "mean batch %.3fs)", len(results), n_tok, dt, n_tok / dt,
             rep.get("mean_processing_s", 0.0))
    sample = results.get(0, [])[:8]
    log.info("request 0 -> %s", sample)


if __name__ == "__main__":
    main()
