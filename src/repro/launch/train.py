"""Training driver: streaming micro-batch LM training on the Spark-MPI stack.

The paper's pattern end-to-end: a token producer appends micro-batches to
the broker; the StreamingContext discretizes them into batch RDDs; each
batch becomes one collective train step on the mesh (the "MPI application");
checkpoints are sharded+async; crash/elastic restart resumes from offsets +
checkpoint.

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --reduced --steps 50 --batch 4 --seq 128 --ckpt-dir /tmp/ck

Full-scale configs are exercised via launch/dryrun.py (this container is one
CPU); --reduced runs the real loop on the reduced config.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointer, latest_step, restore
from repro.configs import get_config
from repro.configs.base import OptimizerConfig
from repro.core import Broker, Context, StreamingContext
from repro.training import build_train_step, init_state
from repro.utils import get_logger, tree_any_nan

log = get_logger(__name__)


def synthetic_producer(broker: Broker, config, steps: int, batch: int,
                       seq: int, seed: int = 0) -> None:
    """Stands in for the detector/corpus: one record per sequence."""
    rng = np.random.default_rng(seed)
    for _ in range(steps * batch):
        rec = {"tokens": rng.integers(
            0, config.vocab_size, (seq,), dtype=np.int32)}
        if config.family == "vlm":
            rec["image_embeds"] = rng.standard_normal(
                (config.num_image_tokens, config.d_model)).astype(np.float32)
        if config.family == "audio":
            rec["frames"] = rng.standard_normal(
                (config.encoder_seq, config.d_model)).astype(np.float32)
        broker.produce("tokens", rec)


def assemble_batch(records: list[dict], config) -> dict:
    batch = {"tokens": jnp.asarray(np.stack([r["tokens"] for r in records]))}
    if config.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            np.stack([r["image_embeds"] for r in records]), jnp.bfloat16)
    if config.family == "audio":
        batch["frames"] = jnp.asarray(
            np.stack([r["frames"] for r in records]), jnp.bfloat16)
    return batch


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args()

    config = get_config(args.arch, reduced=args.reduced)
    if config.family == "vlm" and args.seq <= config.num_image_tokens:
        args.seq = config.num_image_tokens + args.seq
    opt = OptimizerConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                          total_steps=args.steps, zero1=False)

    # data plane: broker + streaming context
    broker = Broker()
    broker.create_topic("tokens", partitions=2)
    synthetic_producer(broker, config, args.steps, args.batch, args.seq,
                       args.seed)
    ctx = Context()
    sc = StreamingContext(ctx, broker,
                          max_records_per_partition=args.batch,
                          checkpoint_path=(f"{args.ckpt_dir}/offsets.json"
                                           if args.ckpt_dir else None))
    sc.subscribe(["tokens"])

    # compute plane
    state = init_state(jax.random.PRNGKey(args.seed), config, opt)
    start_step = 0
    ckpt = AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    if args.resume and args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        state, start_step = restore(args.ckpt_dir,
                                    jax.eval_shape(lambda: state))
        log.info("resumed from step %d", start_step)
    step_fn = jax.jit(build_train_step(config, opt), donate_argnums=(0,))

    stats = {"step": start_step, "state": state, "t0": time.time(),
             "tokens": 0}

    def on_batch(rdd, info):
        records = rdd.collect()[: args.batch]
        if len(records) < args.batch:
            return None
        batch = assemble_batch(records, config)
        stats["state"], metrics = step_fn(stats["state"], batch)
        stats["step"] += 1
        stats["tokens"] += int(np.prod(batch["tokens"].shape))
        s = stats["step"]
        if s % args.log_every == 0 or s == start_step + 1:
            dt = time.time() - stats["t0"]
            log.info("step %d loss %.4f lr %.2e gnorm %.2f | %.0f tok/s",
                     s, float(metrics["loss"]), float(metrics["lr"]),
                     float(metrics["grad_norm"]), stats["tokens"] / dt)
        if ckpt and s % args.ckpt_every == 0:
            ckpt.save(s, stats["state"])
        return float(metrics["loss"])

    sc.foreach_batch(on_batch)
    while stats["step"] < start_step + args.steps:
        if sc.run_one_batch() is None:
            break
    if ckpt:
        ckpt.save(stats["step"], stats["state"])
        ckpt.wait()
    if tree_any_nan(stats["state"]["params"]):
        raise SystemExit("NaN in parameters")
    rep = sc.realtime_report()
    log.info("done: %d steps, %.0f rec/s, mean batch %.3fs",
             stats["step"], rep.get("throughput_rec_per_s", 0),
             rep.get("mean_processing_s", 0))


if __name__ == "__main__":
    main()
