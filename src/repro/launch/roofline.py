"""Roofline table generator: aggregates dry-run JSON records into the
EXPERIMENTS.md §Roofline markdown table.

    PYTHONPATH=src python -m repro.launch.roofline [--mesh single] [--md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

RESULTS = os.path.normpath(os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "results", "dryrun"))

BOTTLENECK_FIXES = {
    "compute": "more chips / lower remat recompute / triangular attention",
    "memory": "Pallas flash attention (VMEM-resident score tiles) / wider "
              "fusion / bf16 intermediates",
    "collective": "re-layout parallelism (less TP for small models, EP "
                  "dispatch locality for MoE) / compressed or overlapped "
                  "collectives",
}


def load(mesh: str = "single", tag: str = "") -> list[dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(RESULTS, mesh, f"*{tag}.json"))):
        r = json.load(open(f))
        if r.get("ok") and r.get("tag", "") == tag:
            rows.append(r)
    return rows


def table(rows: list[dict], md: bool = True) -> str:
    out = []
    hdr = ("arch", "shape", "compute_s", "memory_s", "ici_s", "dcn_s",
           "dominant", "MODEL_FLOPS", "useful", "peak_GiB")
    if md:
        out.append("| " + " | ".join(hdr) + " |")
        out.append("|" + "---|" * len(hdr))
    else:
        out.append(",".join(hdr))
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        rf = r["roofline"]
        cells = (r["arch"], r["shape"], f"{rf['compute_s']:.3f}",
                 f"{rf['memory_s']:.3f}", f"{rf['collective_s']:.3f}",
                 f"{rf['dcn_s']:.3f}", rf["dominant"],
                 f"{rf['model_flops']:.2e}", f"{rf['useful_ratio']:.2f}",
                 f"{r['memory']['peak_bytes'] / 2**30:.1f}")
        out.append(("| " + " | ".join(cells) + " |") if md
                   else ",".join(cells))
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--tag", default="")
    ap.add_argument("--md", action="store_true", default=True)
    args = ap.parse_args()
    rows = load(args.mesh, args.tag)
    print(table(rows, md=args.md))
    doms = {}
    for r in rows:
        doms.setdefault(r["roofline"]["dominant"], []).append(
            f"{r['arch']}×{r['shape']}")
    print()
    for dom, cells in sorted(doms.items()):
        print(f"**{dom}-bound** ({len(cells)}): {', '.join(cells)}")
        print(f"  -> {BOTTLENECK_FIXES[dom]}")


if __name__ == "__main__":
    main()
