"""Windowing over micro-batches: tumbling and sliding count/time windows.

Spark's DStream API exposes ``window(windowLength, slideInterval)`` over
micro-batches; this module reproduces that composition for
:class:`~repro.core.dstream.StreamingContext`. A window function wraps a
user batch function: records accumulate across micro-batches and the user
function fires once per *complete* window, e.g. "reconstruct over the last K
frame batches" (the paper §III accumulates 512-frame acquisitions the same
way — app-side buffering that this module absorbs into the platform).

Count windows index records; time windows bucket by the arrival micro-batch's
schedule time (micro-batch semantics: all records in a batch share its
timestamp, exactly Spark's discretization).

The open window is consumer *state*: records already pulled off the broker
but not yet fired. Left in memory it dies with the process — after the
offsets checkpointed past it — so a crash mid-window silently loses records.
Hand the windower a :class:`~repro.data.state.WindowStateStore`
(``Windower(spec, fn, store=...)`` / ``windowed(spec, fn, store=...)``) and
:class:`~repro.core.dstream.StreamingContext` commits the window state
atomically with the consumed offsets each batch, restoring both together on
restart (see ``repro/data/state.py`` for the both-or-neither argument).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

from repro.core.dstream import BatchInfo
from repro.core.rdd import RDD
from repro.data.state import WindowState, WindowStateStore


@dataclass(frozen=True)
class WindowSpec:
    """``size``/``slide`` in records (``kind="count"``) or seconds
    (``kind="time"``). ``slide`` defaults to ``size`` (tumbling); a smaller
    slide overlaps windows (sliding)."""
    size: float
    slide: float | None = None
    kind: str = "count"

    def __post_init__(self) -> None:
        if self.kind not in ("count", "time"):
            raise ValueError(f"kind {self.kind!r} not in ('count', 'time')")
        if self.size <= 0:
            raise ValueError("window size must be > 0")
        if self.slide is not None and self.slide <= 0:
            raise ValueError("window slide must be > 0")

    @property
    def stride(self) -> float:
        return self.slide if self.slide is not None else self.size


@dataclass
class WindowInfo:
    """Metadata handed to the window function alongside the records."""
    index: int                       # 0-based window sequence number
    start: float                     # first record index / window start time
    end: float                       # one-past-last index / window end time
    num_records: int = 0
    batches: list[int] = field(default_factory=list)   # contributing batches
    partial: bool = False            # True only for an end-of-stream flush


@dataclass
class _Pending:
    value: Any
    ts: float          # arrival time relative to stream epoch
    batch: int


class Windower:
    """Accumulates records across micro-batches and fires complete windows.

    Use via :func:`windowed`, or drive ``push``/``flush`` directly. The
    window function receives ``(records, WindowInfo)`` and its return values
    are collected as the wrapped batch function's result.
    """

    def __init__(self, spec: WindowSpec,
                 fn: Callable[[list[Any], WindowInfo], Any],
                 store: WindowStateStore | None = None) -> None:
        self.spec = spec
        self.fn = fn
        self.store = store               # committed by the StreamingContext
        self._buf: list[_Pending] = []
        self._evicted = 0                # records dropped off the front
        self._t0: float | None = None    # stream epoch (time kind)
        self._windows_fired = 0

    # -- restartable state --------------------------------------------------
    def state(self) -> WindowState:
        """Snapshot the restartable state (shallow: record values shared)."""
        return WindowState(buf=[(p.value, p.ts, p.batch) for p in self._buf],
                           evicted=self._evicted, t0=self._t0,
                           windows_fired=self._windows_fired)

    def restore_state(self, state: WindowState) -> None:
        """Adopt a previously committed state — the restart path, and the
        rollback path when a batch fails after pushing (the replay must not
        find its records already half-pushed)."""
        self._buf = [_Pending(v, ts, b) for v, ts, b in state.buf]
        self._evicted = state.evicted
        self._t0 = state.t0
        self._windows_fired = state.windows_fired

    # -- record intake ------------------------------------------------------
    def push(self, records: list[Any], info: BatchInfo) -> list[Any]:
        """Add one micro-batch worth of records; fire any complete windows.
        Returns the list of window-function results fired by this push."""
        t = info.scheduled_at
        if self._t0 is None:
            self._t0 = t
        rel = t - self._t0
        self._buf.extend(_Pending(v, rel, info.index) for v in records)
        if self.spec.kind == "count":
            return self._fire_count()
        return self._fire_time(now=rel)

    def flush(self) -> list[Any]:
        """End-of-stream: fire one final partial window if records remain.

        The partial ``WindowInfo`` keeps the complete-window contract that
        ``end`` is an *exclusive bound* on the contents: one past the last
        record index (count kind), or the open window's scheduled end
        ``start + size`` (time kind — every buffered ``ts`` is below it,
        exactly the bounds the window would have reported had it closed).
        """
        if not self._buf:
            return []
        if self.spec.kind == "count":
            start = float(self._evicted)
            end = start + len(self._buf)
        else:
            start = self._windows_fired * self.spec.stride
            end = start + self.spec.size
        result = self._fire(self._buf, start, end, partial=True)
        self._buf = []
        return [result]

    # -- firing -------------------------------------------------------------
    def _fire(self, pend: list[_Pending], start: float, end: float,
              partial: bool = False) -> Any:
        info = WindowInfo(index=self._windows_fired, start=start, end=end,
                          num_records=len(pend),
                          batches=sorted({p.batch for p in pend}),
                          partial=partial)
        self._windows_fired += 1
        return self.fn([p.value for p in pend], info)

    def _fire_count(self) -> list[Any]:
        size, stride = int(self.spec.size), int(self.spec.stride)
        out = []
        while len(self._buf) >= size:
            start = float(self._evicted)
            out.append(self._fire(self._buf[:size], start, start + size))
            self._buf = self._buf[stride:]
            self._evicted += stride
        return out

    def _fire_time(self, now: float) -> list[Any]:
        size, stride = self.spec.size, self.spec.stride
        out = []
        while True:
            w_start = self._windows_fired * stride
            w_end = w_start + size
            if now < w_end:       # window still open
                break
            in_window = [p for p in self._buf if w_start <= p.ts < w_end]
            out.append(self._fire(in_window, w_start, w_end))
            next_start = self._windows_fired * stride
            keep = [p for p in self._buf if p.ts >= next_start]
            self._evicted += len(self._buf) - len(keep)
            self._buf = keep
        return out


def windowed(spec: WindowSpec,
             fn: Callable[[list[Any], WindowInfo], Any],
             windower_out: list | None = None,
             store: WindowStateStore | None = None
             ) -> Callable[[RDD, BatchInfo], Any]:
    """Wrap a window function as a ``foreach_batch`` function.

    ``sc.foreach_batch(windowed(WindowSpec(size=64), fn))`` collects each
    micro-batch RDD, accumulates, and calls ``fn(records, window_info)``
    whenever a window completes; the batch result is the (possibly empty)
    list of window results. Pass ``windower_out=[]`` to receive the
    :class:`Windower` (index 0) for end-of-stream ``flush()``.

    The returned function carries its :class:`Windower` as a ``windower``
    attribute; ``StreamingContext.foreach_batch`` auto-attaches it to the
    context's commit protocol (rollback on a failed batch and — with a
    ``store`` and a ``checkpoint_path`` — restart-safe window state,
    committed atomically with the consumed offsets).
    """
    w = Windower(spec, fn, store=store)
    if windower_out is not None:
        windower_out.append(w)

    def on_batch(rdd: RDD, info: BatchInfo) -> list[Any]:
        return w.push(rdd.collect(), info)

    on_batch.windower = w
    return on_batch
