"""Ingest runtime: pump sources into broker topics with bounded-queue
backpressure (DELTA's generator process, minus MPI).

The paper's near-real-time criterion — per-batch processing time must stay
under the batch interval — is only meaningful if overload is *observable*.
An unbounded broker log hides it: producers never block, consumers just fall
further behind. :class:`IngestRunner` bounds the produced-but-unconsumed lag
per topic and applies a policy when the bound is hit:

- ``block``  — the source waits (lossless; the instrument must buffer),
- ``drop``   — newest records are discarded (lossy, bounded lag),
- ``sample`` — keep every k-th record (graceful degradation: the stream
  thins instead of stalling, CFAA's approach of decimating sensor streams).

Lag is measured against the consumer's committed offsets (a
:class:`~repro.core.dstream.StreamingContext`), so backpressure reflects what
the pipeline has actually processed, not just what it has been handed.

The runner is transport-agnostic: ``broker`` may be the in-process
:class:`~repro.core.broker.Broker` or a
:class:`~repro.data.transport.RemoteBroker` speaking to a consumer-side
:class:`~repro.data.transport.BrokerServer`. In the remote topology pass the
same client as ``consumer=`` (it exposes ``lag()`` computed from the offsets
the consumer committed broker-side), and producer backpressure keeps working
across the process/host boundary.

Produce is *batched*: polled records buffer per source and flush through
``broker.produce_many`` (one call per partition) when ``flush_records`` /
``flush_bytes`` worth have accumulated or the oldest buffered record ages
past ``flush_interval``. Over the socket transport that amortizes one frame
per batch instead of one round trip per record — the dominant cost PR 2's
``ingest/remote_transport`` benchmark exposed. Buffered records count
against ``max_pending``, so the backpressure bounds are unchanged.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

from repro.core.broker import Broker
from repro.data.sources import Source
from repro.utils import get_logger

log = get_logger(__name__)

POLICIES = ("block", "drop", "sample")


@dataclass(frozen=True)
class IngestConfig:
    """Per-source ingest knobs."""
    topic: str
    partitions: int = 1            # topic created with this many if missing
    poll_batch: int = 64           # max records per source poll
    policy: str = "block"          # block | drop | sample when over max_pending
    # Bound on produced-but-unconsumed records. "block" never exceeds it;
    # "drop"/"sample" check at poll granularity, so the observed lag is
    # bounded by max_pending + poll_batch. Records buffered for a batched
    # produce count against the bound (the runner subtracts them from room).
    max_pending: int = 1024
    sample_stride: int = 4         # "sample": keep 1 of every stride records
    rate_limit: float | None = None  # producer-side cap, records/s
    # Batched produce: polled records buffer until one of these trips, then
    # flush as one produce_many per partition (one transport frame instead of
    # one per record — the fast path bench_ingest prices). flush_records=1
    # restores PR 2's per-record produce.
    flush_records: int = 64        # flush when this many records buffered
    flush_bytes: int = 1 << 20     # ... or the buffered payload estimate hits
    flush_interval: float = 0.02   # ... or the oldest buffered record ages out
    # When the topic is consumed by a consumer group (repro.data.groups),
    # name it here: backpressure then measures lag against the *group's*
    # broker-committed offsets (group members never advance the default
    # group's offsets, so the runner's usual lag signal would read the
    # whole log as unconsumed and block forever).
    consumer_group: str = ""
    # Payload codec (repro.data.codec) applied to every value at the flush
    # boundary — the DELTA-style "reduce at the source" hook. None inherits
    # the topic's own codec (create_topic(codec=...)); topics this runner
    # creates are created *with* this codec so late-joining producers
    # inherit it too. Values are self-describing, so consumers decode with
    # no configuration (StreamingContext/TopicSource already do).
    codec: str | None = None

    def __post_init__(self) -> None:
        if self.policy not in POLICIES:
            raise ValueError(f"policy {self.policy!r} not in {POLICIES}")
        if self.flush_records < 1:
            raise ValueError("flush_records must be >= 1")


@dataclass
class SourceMetrics:
    """Per-source throughput/lag accounting."""
    topic: str = ""
    produced: int = 0
    dropped: int = 0
    sampled_out: int = 0
    polls: int = 0
    produce_calls: int = 0         # broker produce/produce_many round trips
    blocked_s: float = 0.0
    started_at: float = 0.0
    last_produce_at: float = 0.0
    max_observed_lag: int = 0

    @property
    def throughput(self) -> float:
        """Records/s over the active window (0 before any produce)."""
        dt = self.last_produce_at - self.started_at
        return self.produced / dt if dt > 0 else 0.0

    def as_dict(self) -> dict:
        return {"topic": self.topic, "produced": self.produced,
                "dropped": self.dropped, "sampled_out": self.sampled_out,
                "polls": self.polls, "produce_calls": self.produce_calls,
                "blocked_s": round(self.blocked_s, 4),
                "throughput_rec_per_s": round(self.throughput, 1),
                "max_observed_lag": self.max_observed_lag}


def _estimate_bytes(value) -> int:
    """Cheap payload-size estimate for the flush_bytes threshold."""
    nbytes = getattr(value, "nbytes", None)
    if nbytes is not None:
        return int(nbytes)
    if isinstance(value, (bytes, bytearray, memoryview, str)):
        return len(value)
    return 64


def _deep_bytes(value) -> int:
    """Container-walking size estimate for the codec byte counters (codec'd
    values are dicts wrapping arrays/blobs, which _estimate_bytes treats as
    opaque 64-byte objects)."""
    nbytes = getattr(value, "nbytes", None)
    if nbytes is not None:
        return int(nbytes)
    if isinstance(value, (bytes, bytearray, memoryview, str)):
        return len(value)
    if isinstance(value, dict):
        return sum(_deep_bytes(v) for v in value.values()) + 16 * len(value)
    if isinstance(value, (list, tuple)):
        return sum(_deep_bytes(v) for v in value) + 8 * len(value)
    return 8


@dataclass
class _Entry:
    source: Source
    config: IngestConfig
    metrics: SourceMetrics
    rr: int = 0                    # round-robin partition cursor
    partitions: int = 1            # cached topic partition count (see add())
    buf: list = field(default_factory=list)   # (key, value, partition)
    buf_bytes: int = 0
    buf_oldest: float = 0.0        # monotonic time of oldest buffered record
    # effective payload codec, resolved once in add() (config override, else
    # the topic's create_topic codec); None = raw, nothing touches the value
    codec: Any = None
    # registry instruments, resolved once in add() so the pump loop pays a
    # plain attribute read per event, never a registry lookup
    m_polls: Any = None
    m_produced: Any = None
    m_dropped: Any = None
    m_sampled: Any = None
    m_blocked: Any = None
    m_flush: Any = None
    m_codec_in: Any = None
    m_codec_out: Any = None


class IngestRunner:
    """Pumps N sources into broker topics, on a thread or inline.

    ``lag_of(topic)`` reports produced-but-unconsumed records; pass
    ``consumer=StreamingContext`` to derive it from committed offsets, or a
    custom callable. With neither, lag is always 0 and backpressure is off.
    """

    def __init__(self, broker: Broker, consumer=None,
                 lag_of: Callable[[str], int] | None = None,
                 idle_sleep: float = 0.002) -> None:
        self.broker = broker
        if lag_of is not None:
            self._lag_of = lag_of
        elif consumer is not None:
            self._lag_of = consumer.lag
        else:
            self._lag_of = lambda topic: 0
        self._entries: list[_Entry] = []
        self._idle_sleep = idle_sleep
        self._pumping = False
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    def add(self, source: Source, config: IngestConfig) -> SourceMetrics:
        if config.topic not in self.broker.topics():
            try:
                if config.codec is not None:
                    self.broker.create_topic(config.topic, config.partitions,
                                             codec=config.codec)
                else:
                    self.broker.create_topic(config.topic, config.partitions)
            except ValueError:
                # another producer won the check-then-create race, or a
                # retried remote create whose first ack was lost — either
                # way the topic exists now, which is all add() needs
                pass
        m = SourceMetrics(topic=config.topic)
        # partition count is immutable per topic: query once, not per poll
        # (over RemoteBroker that query is a full round trip)
        n = self.broker.num_partitions(config.topic)
        e = _Entry(source, config, m, partitions=n)
        # effective codec, resolved once: the config's override, else the
        # topic's own (pre-existing topics keep their create_topic codec).
        # "raw"/None both mean "leave the value alone" — skip the encode
        # call entirely on that hot path.
        name = config.codec
        if name is None:
            topic_codec = getattr(self.broker, "topic_codec", None)
            if topic_codec is not None:
                name = topic_codec(config.topic)
        if name is not None:
            from repro.data.codec import get_codec
            codec = get_codec(name)
            e.codec = None if codec.name == "raw" else codec
        self._register_metrics(e)
        self._entries.append(e)
        return m

    def _register_metrics(self, e: _Entry) -> None:
        # constructor-time import: repro.data.metrics must not be imported at
        # module scope here (repro.data.__init__ import cycle)
        from repro.data.metrics import COUNT_BUCKETS, get_registry
        reg = get_registry()
        topic = e.config.topic
        labels = {"topic": topic}
        e.m_polls = reg.counter(
            "ingest_polls_total", help="source poll() calls", labels=labels)
        e.m_produced = reg.counter(
            "ingest_produced_records_total",
            help="records handed to the broker", labels=labels)
        e.m_dropped = reg.counter(
            "ingest_dropped_records_total",
            help="records shed by the drop policy", labels=labels)
        e.m_sampled = reg.counter(
            "ingest_sampled_out_records_total",
            help="records thinned away by the sample policy", labels=labels)
        e.m_blocked = reg.counter(
            "ingest_blocked_seconds_total",
            help="time the block policy held the source", labels=labels)
        e.m_flush = reg.histogram(
            "ingest_flush_records", help="records per batched flush",
            labels=labels, buckets=COUNT_BUCKETS)
        if e.codec is not None:
            codec_labels = {"topic": topic, "codec": e.codec.name}
            e.m_codec_in = reg.counter(
                "ingest_codec_bytes_in",
                help="estimated value bytes entering the codec at flush",
                labels=codec_labels)
            e.m_codec_out = reg.counter(
                "ingest_codec_bytes_out",
                help="estimated value bytes after codec encode",
                labels=codec_labels)
        reg.gauge("ingest_lag", help="produced-but-unconsumed records",
                  labels=labels,
                  callback=lambda e=e: self._lag(e))

    @property
    def metrics(self) -> list[SourceMetrics]:
        return [e.metrics for e in self._entries]

    def _lag(self, e: _Entry) -> int:
        """The entry's backpressure signal: the consumer group's broker-side
        committed offsets when ``config.consumer_group`` names one, else the
        runner-level ``lag_of``/consumer."""
        if e.config.consumer_group:
            return self.broker.lag(e.config.topic,
                                   group=e.config.consumer_group)
        return self._lag_of(e.config.topic)

    def lag_snapshot(self) -> dict[str, int]:
        """Current produced-but-unconsumed lag per topic — the live signal
        (``max_observed_lag`` is a high-water mark and never drains) that
        :class:`~repro.core.fault.LagPolicy` scales the worker set on."""
        return {e.config.topic: self._lag(e) for e in self._entries}

    @property
    def done(self) -> bool:
        """Every source exhausted AND its records handed to the broker.

        A source reports ``exhausted`` the moment its last ``poll`` returns,
        which is *before* those records reach the broker — a visible window
        when produce crosses a socket (RemoteBroker), and wider still with
        batched produce (records sit in the flush buffer). Reading
        ``exhausted`` first, then the buffers, then the pump-in-progress flag
        closes it: if the flag is clear and the buffers are empty after
        exhaustion was observed, the pump that drained the source has fully
        produced.
        """
        exhausted = all(e.source.exhausted for e in self._entries)
        flushed = all(not e.buf for e in self._entries)
        return exhausted and flushed and not self._pumping

    # -- one pump step -----------------------------------------------------
    def _produce(self, e: _Entry, records) -> None:
        """Buffer polled records for a batched flush; flush immediately when
        a size threshold trips (the deadline is pump()'s job)."""
        if not records:
            return
        cfg = e.config
        now = time.monotonic()
        for key, value in records:
            if not e.buf:
                e.buf_oldest = now
            e.buf.append((key, value, e.rr % e.partitions))
            e.buf_bytes += _estimate_bytes(value)
            e.rr += 1
            if (len(e.buf) >= cfg.flush_records
                    or e.buf_bytes >= cfg.flush_bytes):
                self._flush(e, now)

    def _flush(self, e: _Entry, now: float | None = None) -> int:
        """Hand the buffered records to the broker: one ``produce_many`` per
        partition (one transport frame each), preserving per-partition order.
        Returns the number of records flushed."""
        if not e.buf:
            return 0
        buf, e.buf, e.buf_bytes = e.buf, [], 0
        now = time.monotonic() if now is None else now
        by_partition: dict[int, list] = {}
        if e.codec is not None:
            # the source→broker encode boundary: values are codec'd here and
            # travel encoded through the broker, the durable log, and the
            # replication path; consumers decode at subscribe
            encode = e.codec.encode
            bytes_in = bytes_out = 0
            for i, (key, value, partition) in enumerate(buf):
                bytes_in += _deep_bytes(value)
                value = encode(value)
                bytes_out += _deep_bytes(value)
                buf[i] = (key, value, partition)
            e.m_codec_in.inc(bytes_in)
            e.m_codec_out.inc(bytes_out)
        for key, value, partition in buf:
            by_partition.setdefault(partition, []).append((key, value))
        produce_many = getattr(self.broker, "produce_many", None)
        for partition, pairs in by_partition.items():
            if produce_many is not None and len(pairs) > 1:
                produce_many(e.config.topic, pairs, partition=partition,
                             timestamp=now)
            else:
                for key, value in pairs:
                    self.broker.produce(e.config.topic, value, key=key,
                                        partition=partition, timestamp=now)
            e.metrics.produce_calls += (1 if produce_many is not None
                                        and len(pairs) > 1 else len(pairs))
        e.metrics.produced += len(buf)
        e.metrics.last_produce_at = now
        e.m_produced.inc(len(buf))
        e.m_flush.observe(len(buf))
        return len(buf)

    def _pump_one(self, e: _Entry) -> int:
        """Poll one source once, apply rate limit + backpressure policy.
        Returns records polled into the pipeline (for idle detection)."""
        src, cfg, m = e.source, e.config, e.metrics
        if src.exhausted:
            return 0
        if m.started_at == 0.0:
            m.started_at = time.monotonic()
        want = cfg.poll_batch
        if cfg.rate_limit is not None:
            elapsed = time.monotonic() - m.started_at
            due = int(cfg.rate_limit * elapsed) + 1
            want = min(want, max(0, due - m.produced - len(e.buf)))
            if want == 0:
                return 0
        lag = self._lag(e)
        m.max_observed_lag = max(m.max_observed_lag, lag)
        # records buffered for the next flush are already claimed pipeline
        # room: count them, or batching would overshoot max_pending
        room = cfg.max_pending - lag - len(e.buf)
        if room <= 0:
            if cfg.policy == "block":
                # the broker may still have space the buffer is holding;
                # push the buffer through so the consumer sees it, then wait
                self._flush(e)
                m.blocked_s += self._idle_sleep
                e.m_blocked.inc(self._idle_sleep)
                return 0                  # do not poll; source waits
            records = src.poll(want)
            m.polls += 1
            e.m_polls.inc()
            if cfg.policy == "drop":
                m.dropped += len(records)
                e.m_dropped.inc(len(records))
                return 0
            # sample: thin to 1/stride, hard-capped so lag never exceeds
            # max_pending + poll_batch even when the consumer is stalled
            kept = records[::cfg.sample_stride]
            hard_room = cfg.max_pending + cfg.poll_batch - lag - len(e.buf)
            kept = kept[:max(0, hard_room)]
            m.sampled_out += len(records) - len(kept)
            e.m_sampled.inc(len(records) - len(kept))
            self._produce(e, kept)
            return len(kept)
        if cfg.policy == "block":
            want = min(want, room)
        records = src.poll(want)
        m.polls += 1
        e.m_polls.inc()
        self._produce(e, records)
        return len(records)

    def pump(self) -> int:
        """One round over all sources; returns total records moved (polled
        into the pipeline or flushed to the broker)."""
        self._pumping = True
        try:
            moved = sum(self._pump_one(e) for e in self._entries)
            now = time.monotonic()
            for e in self._entries:
                # deadline flush: no record waits in the buffer past
                # flush_interval, and an exhausted source drains immediately
                if e.buf and (e.source.exhausted
                              or now - e.buf_oldest >= e.config.flush_interval):
                    moved += self._flush(e, now)
            return moved
        finally:
            self._pumping = False

    # -- drive -------------------------------------------------------------
    def run_inline(self, timeout: float | None = None) -> None:
        """Pump until every source is exhausted (tests/benchmarks)."""
        # `is not None`, not truthiness: timeout=0 must mean "one pass, then
        # give up immediately", never the accidental "wait forever"
        deadline = (time.monotonic() + timeout) if timeout is not None \
            else None
        while not self.done:
            if self.pump() == 0:
                if deadline is not None and time.monotonic() > deadline:
                    log.warning("ingest run_inline timed out; %d sources "
                                "unfinished",
                                sum(not e.source.exhausted
                                    for e in self._entries))
                    return
                time.sleep(self._idle_sleep)

    def start(self) -> None:
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="ingest-runner")
        self._thread.start()

    def _loop(self) -> None:
        while not self._stop.is_set():
            if self.pump() == 0:
                if self.done:
                    return
                self._stop.wait(self._idle_sleep)

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10)
            self._thread = None

    def join(self, timeout: float | None = None) -> bool:
        """Wait for the background pump to finish all sources."""
        if self._thread is None:
            return self.done
        self._thread.join(timeout)
        return self.done


def ingest_all(broker: Broker, pairs: Sequence[tuple[Source, IngestConfig]],
               consumer=None) -> list[SourceMetrics]:
    """Convenience: pump every (source, config) pair to completion inline."""
    runner = IngestRunner(broker, consumer=consumer)
    out = [runner.add(s, c) for s, c in pairs]
    runner.run_inline()
    return out
