"""Data sources: the paper's "augment the Kafka Receiver with interfaces to
other data sources" future-work item, made concrete.

A :class:`Source` is anything that can be polled for ``(key, value)`` records.
Replayable sources additionally support ``seek(offset)`` so a restarted
pipeline can resume from a :class:`~repro.core.dstream.StreamProgress`
checkpoint — the same property that makes the broker's offset-addressed logs
fault tolerant carries back one layer, to the instrument itself.

Concrete sources mirror the reference systems:

- :class:`DetectorSource` — the paper §III ptychography detector, wrapping the
  frame simulator in ``apps/ptycho/sim.py`` (DELTA's ``generator.py`` reads a
  diagnostic the same way: a dataloader fronted by a paced emit loop).
- :class:`ProjectionSource` — the paper §IV TEM tilt series, one sinogram
  slice per record.
- :class:`FileReplaySource` — DELTA's generator-from-disk idiom
  (``sources/dataloader.py``): deterministic replay of an NPZ or JSONL
  capture.
- :class:`SyntheticRateSource` — a clocked record generator for load tests
  and backpressure experiments.
- :class:`TopicSource` — re-ingest an existing broker topic, which is how
  multi-stage pipelines chain (DELTA's processor→backend hand-off).
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Callable, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.core.broker import Broker, OffsetRange

RecordKV = tuple[bytes | None, Any]


@runtime_checkable
class Source(Protocol):
    """Pollable record source. ``poll`` returns at most ``max_records``
    ``(key, value)`` pairs; an empty list means "nothing available *now*",
    which is final only once ``exhausted`` is true."""

    def poll(self, max_records: int) -> list[RecordKV]: ...

    @property
    def exhausted(self) -> bool: ...


@runtime_checkable
class ReplayableSource(Source, Protocol):
    """A source whose records are a deterministic indexed sequence, so
    ``seek(n)`` repositions to the n-th record (restart/resume support)."""

    def seek(self, offset: int) -> None: ...

    @property
    def position(self) -> int: ...


class SequenceSource:
    """Base for replayable sources backed by an indexable record sequence.

    Subclasses implement ``__len__`` and ``record_at(i)``; this base supplies
    the ``Source``/``ReplayableSource`` surface plus optional pacing: with
    ``interval > 0``, records are released no faster than one per ``interval``
    seconds (the acquisition-rate simulation DELTA's generator does with its
    ``time.sleep`` between chunks).
    """

    def __init__(self, interval: float = 0.0) -> None:
        self._cursor = 0
        self._interval = float(interval)
        self._clock_start: float | None = None
        self._released = 0     # pacing budget consumed (independent of seek)

    def __len__(self) -> int:  # pragma: no cover - abstract
        raise NotImplementedError

    def record_at(self, i: int) -> RecordKV:  # pragma: no cover - abstract
        raise NotImplementedError

    def _allowed_now(self, want: int) -> int:
        if self._interval <= 0:
            return want
        now = time.monotonic()
        if self._clock_start is None:
            self._clock_start = now
        due = int((now - self._clock_start) / self._interval) + 1
        return max(0, min(want, due - self._released))

    def poll(self, max_records: int) -> list[RecordKV]:
        end = min(len(self), self._cursor + self._allowed_now(max_records))
        out = [self.record_at(i) for i in range(self._cursor, end)]
        self._released += end - self._cursor
        self._cursor = end
        return out

    @property
    def exhausted(self) -> bool:
        return self._cursor >= len(self)

    @property
    def position(self) -> int:
        return self._cursor

    def seek(self, offset: int) -> None:
        if offset < 0 or offset > len(self):
            raise ValueError(
                f"seek({offset}) outside [0, {len(self)}]")
        self._cursor = offset


class DetectorSource(SequenceSource):
    """Ptychography detector (paper §III): frames from the simulator in scan
    order. By default the value is the frame index (downstream solvers index
    the shared measurement set, as the seed example did); with
    ``emit_frames=True`` each value is ``(index, magnitude_frame)`` so the
    payload itself rides the stream.
    """

    def __init__(self, problem: Any, max_frames: int | None = None,
                 frame_interval: float = 0.0, emit_frames: bool = False) -> None:
        super().__init__(interval=frame_interval)
        self.problem = problem
        self._n = problem.num_frames if max_frames is None else min(
            max_frames, problem.num_frames)
        self._emit_frames = emit_frames

    def __len__(self) -> int:
        return self._n

    def record_at(self, i: int) -> RecordKV:
        key = f"frame-{i:06d}".encode()
        if self._emit_frames:
            return key, (i, np.asarray(self.problem.magnitudes[i]))
        return key, i


class ProjectionSource(SequenceSource):
    """TEM tilt series (paper §IV): one record per sinogram slice,
    ``value = (slice_index, sinogram_row)`` — exactly the ``(i, sino[i])``
    records the seed tomography example built by hand."""

    def __init__(self, sinogram: np.ndarray, interval: float = 0.0) -> None:
        super().__init__(interval=interval)
        self._sino = np.asarray(sinogram)

    def __len__(self) -> int:
        return len(self._sino)

    def record_at(self, i: int) -> RecordKV:
        return f"slice-{i:06d}".encode(), (i, self._sino[i])


class FileReplaySource(SequenceSource):
    """Replay a capture from disk with deterministic ordering.

    ``.npz``: one record per array, ordered by sorted key name.
    ``.jsonl``: one record per line, file order, value = parsed object.

    This is DELTA's generator-from-disk idiom: the instrument is replaced by
    a file, everything downstream is unchanged.
    """

    def __init__(self, path: str, interval: float = 0.0) -> None:
        super().__init__(interval=interval)
        self.path = path
        if path.endswith(".npz"):
            with np.load(path) as z:
                self._keys = sorted(z.files)
                self._values = [np.asarray(z[k]) for k in self._keys]
        elif path.endswith(".jsonl"):
            with open(path) as f:
                lines = [ln for ln in f if ln.strip()]
            self._keys = [f"line-{i:06d}" for i in range(len(lines))]
            self._values = [json.loads(ln) for ln in lines]
        else:
            raise ValueError(f"unsupported replay format: {path!r} "
                             "(want .npz or .jsonl)")

    def __len__(self) -> int:
        return len(self._keys)

    def record_at(self, i: int) -> RecordKV:
        return self._keys[i].encode(), self._values[i]


class SyntheticRateSource(SequenceSource):
    """Clocked generator: emits ``value_fn(i)`` at ``rate`` records/second,
    ``total`` records in all (``None`` = unbounded). The load-test knob for
    the ingest runtime: crank ``rate`` past what the pipeline sustains and
    watch the backpressure policy engage."""

    UNPACED_RATE = 1e6     # rates at/above this skip the pacing clock

    def __init__(self, rate: float, total: int | None = None,
                 value_fn: Callable[[int], Any] | None = None) -> None:
        if rate <= 0:
            raise ValueError("rate must be > 0")
        super().__init__(
            interval=0.0 if rate >= self.UNPACED_RATE else 1.0 / rate)
        self._total = total
        self._value_fn = value_fn or (lambda i: i)

    def __len__(self) -> int:
        return self._total if self._total is not None else (1 << 62)

    def record_at(self, i: int) -> RecordKV:
        return f"rec-{i:09d}".encode(), self._value_fn(i)


class TopicSource:
    """Re-ingest an existing broker topic: the chaining primitive for
    multi-stage pipelines (stage 1's :class:`~repro.data.sinks.TopicSink`
    becomes stage 2's source).

    Polls partitions in order from per-partition offsets. ``exhausted`` is
    never true for a live topic unless ``stop_at_end`` is set, in which case
    the source drains the topic as of each poll. ``seek(n)`` takes a *total*
    record position (the same contract ``position`` reports), distributed
    over partitions in drain order against current end offsets — exact for
    bulk polls over a quiescent topic, approximate if the log grew since.
    """

    def __init__(self, broker: Broker, topic: str,
                 stop_at_end: bool = False) -> None:
        self.broker = broker
        self.topic = topic
        self.stop_at_end = stop_at_end
        self._offsets = [0] * broker.num_partitions(topic)

    def poll(self, max_records: int) -> list[RecordKV]:
        # codec'd topics (repro.data.codec) decode here, at the consume
        # boundary, so re-ingest stages see the same values a subscriber
        # would — and a chained stage's own codec re-encodes on its flush
        from repro.data.codec import maybe_decode
        out: list[RecordKV] = []
        for p, start in enumerate(self._offsets):
            if len(out) >= max_records:
                break
            until = min(self.broker.end_offset(self.topic, p),
                        start + max_records - len(out))
            if until <= start:
                continue
            recs = self.broker.read(OffsetRange(self.topic, p, start, until))
            out.extend((r.key, maybe_decode(r.value)) for r in recs)
            self._offsets[p] = until
        return out

    @property
    def exhausted(self) -> bool:
        if not self.stop_at_end:
            return False
        return all(off >= self.broker.end_offset(self.topic, p)
                   for p, off in enumerate(self._offsets))

    @property
    def position(self) -> int:
        return sum(self._offsets)

    def seek(self, offset: int) -> None:
        remaining = offset
        for p in range(len(self._offsets)):
            take = min(remaining, self.broker.end_offset(self.topic, p))
            self._offsets[p] = take
            remaining -= take


def save_npz_capture(path: str, records: Sequence[tuple[str, np.ndarray]]) -> str:
    """Write an NPZ capture that :class:`FileReplaySource` replays in the
    given order (keys are prefixed with their index to pin the sort)."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    arrays = {f"{i:06d}-{name}": np.asarray(v)
              for i, (name, v) in enumerate(records)}
    np.savez(path, **arrays)
    return path
