"""Data ingestion & sink subsystem: sources → ingest → windows → sinks.

This package is the paper's future-work item made real — "augment the Kafka
Receiver with interfaces to other data sources" — shaped after DELTA's
generator/reader/backend split. Map from class to concept:

================================  =============================================
Class                             Reproduces
================================  =============================================
``sources.Source``                Kafka Receiver / DELTA reader: pollable
                                  ``(key, value)`` record stream
``sources.DetectorSource``        paper §III ptychography detector (frame
                                  simulator fronted as a stream)
``sources.ProjectionSource``      paper §IV TEM tilt series, slice records
``sources.FileReplaySource``      DELTA ``sources/dataloader.py``: replay a
                                  capture from disk, deterministically
``sources.SyntheticRateSource``   clocked load generator (rate in records/s)
``sources.TopicSource``           re-ingest a broker topic → multi-stage
                                  pipelines (DELTA processor chaining)
``ingest.IngestRunner``           DELTA ``generator.py``: pump sources into
                                  transport, paced, with bounded-lag
                                  backpressure (block/drop/sample)
``window.WindowSpec/windowed``    Spark DStream ``window(length, slide)``
                                  over micro-batches (tumbling + sliding)
``sinks.NpzDirectorySink``        checkpoint/artifact store (idempotent files)
``sinks.TopicSink``               DELTA backend-chaining: results → next topic
``sinks.MetricsSink``             latency/throughput aggregation (Fig. 9/10
                                  accounting) feeding ``PipelineReport``
``sinks.CallbackSink``            visualization hook (ParaViewWeb stand-in)
``delivery.DeliveryRuntime``      Kafka Connect-style sink delivery: one
                                  worker lane (thread + bounded queue) per
                                  sink, per-sink :class:`~repro.data
                                  .delivery.SinkPolicy` (retry / skip /
                                  dead-letter topic / fail-pipeline,
                                  timeout, queue block-or-drop)
``groups.GroupCoordinator``       Kafka group coordinator: broker-hosted
                                  membership, heartbeat liveness,
                                  generation-fenced commits, sticky
                                  partition assignment
``groups.GroupConsumer``          Kafka consumer-group member: consumes only
                                  assigned partitions, hands open-window
                                  state to the next owner through
                                  per-partition durable checkpoints
``transport.BrokerServer``        Kafka broker process: serves partition logs
                                  over TCP / Unix sockets to other processes
``transport.RemoteBroker``        Kafka client / paper's ZeroMQ direction:
                                  the ``Broker`` surface spoken over a socket
                                  (same-host producers negotiate shared-
                                  memory ``'S'`` frames: bulk bytes skip the
                                  socket entirely)
``codec.Codec``                   DELTA's reduce-at-the-source role: per-
                                  topic payload codecs (lossy ``int8``
                                  quantization, lossless ``zlib``) applied
                                  at the ingest flush boundary, decoded at
                                  subscribe, opaque to log + replication
``durable_log.DurablePartitionLog``  Kafka's on-disk log segments: records
                                  survive a broker restart, torn tails are
                                  truncated by the recovery scan
``replication.ReplicaFollower``   Kafka follower replica: pulls the
                                  leader's segment frames byte-for-byte,
                                  promotable on leader death
``replication.FailoverBroker``    Kafka client leader failover: epoch
                                  fencing plus an unreplicated-batch resend
                                  window, so no committed record is lost
``state.DurableStateStore``       Flink-style window state backend: the open
                                  window spilled to disk (snapshot + delta
                                  frames), committed atomically with the
                                  offset checkpoint so restarts resume
                                  mid-window
``metrics.MetricsRegistry``       Prometheus-style pull-model telemetry:
                                  counters/gauges/histograms every layer
                                  registers into, plus ring-buffer series
                                  and batch-epoch trace spans (DELTA's
                                  MongoDB timing store, CFAA's InfluxDB
                                  points — kept in-process)
``obs_server.ObservabilityServer``  the scrape endpoint over it: ``/metrics``
                                  (Prometheus text), ``/metrics.json``,
                                  ``/traces``, ``/health``
================================  =============================================

All sinks are idempotent by key, upgrading the dstream layer's at-least-once
replay to exactly-once end-to-end.
"""
from repro.data.codec import (Codec, CodecBroker, UnknownCodecError,
                              codec_names, get_codec, maybe_decode,
                              register_codec)
from repro.data.delivery import (DeliveryFailed, DeliveryRuntime, LaneMetrics,
                                 SinkLane, SinkPolicy, SinkTimeoutError)
from repro.data.durable_log import (DurableLogFactory, DurablePartitionLog,
                                    LogCorruptionError)
from repro.data.groups import (GroupConsumer, GroupCoordinator, GroupError,
                               GroupMember, StaleGenerationError,
                               sticky_assign)
from repro.data.ingest import (IngestConfig, IngestRunner, SourceMetrics,
                               ingest_all)
from repro.data.metrics import (BatchSpan, Counter, Gauge, Histogram,
                                MetricsRegistry, NullRegistry, SPAN_STAGES,
                                TraceLog, disabled, get_registry,
                                set_registry)
from repro.data.obs_server import (ObservabilityServer, lag_health,
                                   serve_observability)
from repro.data.replication import FailoverBroker, ReplicaFollower
from repro.data.sinks import (CallbackSink, KeyedSink, MetricsSink,
                              NpzDirectorySink, Sink, TopicSink,
                              describe_result_items, fan_out)
from repro.data.sources import (DetectorSource, FileReplaySource,
                                ProjectionSource, ReplayableSource,
                                SequenceSource, Source, SyntheticRateSource,
                                TopicSource, save_npz_capture)
from repro.data.state import (DurableStateStore, InMemoryStateStore,
                              WindowState, WindowStateStore)
from repro.data.transport import (BrokerServer, FrameError, RemoteBroker,
                                  TransportError, parse_address, serve_broker)
from repro.data.window import WindowInfo, WindowSpec, Windower, windowed

__all__ = [
    "Source", "ReplayableSource", "SequenceSource",
    "DetectorSource", "ProjectionSource", "FileReplaySource",
    "SyntheticRateSource", "TopicSource", "save_npz_capture",
    "IngestConfig", "IngestRunner", "SourceMetrics", "ingest_all",
    "WindowSpec", "WindowInfo", "Windower", "windowed",
    "WindowState", "WindowStateStore", "InMemoryStateStore",
    "DurableStateStore",
    "Sink", "KeyedSink", "NpzDirectorySink", "TopicSink", "MetricsSink",
    "CallbackSink", "describe_result_items", "fan_out",
    "DeliveryRuntime", "SinkPolicy", "SinkLane", "LaneMetrics",
    "DeliveryFailed", "SinkTimeoutError",
    "BrokerServer", "RemoteBroker", "serve_broker", "parse_address",
    "TransportError", "FrameError",
    "Codec", "CodecBroker", "UnknownCodecError", "get_codec", "codec_names",
    "maybe_decode", "register_codec",
    "GroupCoordinator", "GroupMember", "GroupConsumer", "sticky_assign",
    "GroupError", "StaleGenerationError",
    "DurablePartitionLog", "DurableLogFactory", "LogCorruptionError",
    "ReplicaFollower", "FailoverBroker",
    "MetricsRegistry", "Counter", "Gauge", "Histogram", "NullRegistry",
    "get_registry", "set_registry", "disabled",
    "TraceLog", "BatchSpan", "SPAN_STAGES",
    "ObservabilityServer", "lag_health", "serve_observability",
]
