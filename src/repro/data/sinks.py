"""Sinks: where micro-batch results go (paper Fig. 7's right-hand side —
visualization, storage, downstream topics; DELTA's ``backends/``).

The dstream layer gives at-least-once delivery: a batch whose sink failed is
replayed at the same offsets. Sinks here are **idempotent by key** — a
``(key, value)`` written twice is skipped the second time — which upgrades
the end-to-end contract to exactly-once, the same argument DELTA makes for
its MongoDB backend (unique run/chunk indices) and Kafka makes for
transactional producers.

``write_batch`` is the one entry point; ``describe_result_items`` maps an
arbitrary batch result onto keyed items (lists of ``(key, value)`` pass
through; anything else becomes a single ``batch-NNNNNN`` item).
"""
from __future__ import annotations

import os
import threading
from typing import Any, Callable, Iterable, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.core.broker import Broker
from repro.utils import get_logger

log = get_logger(__name__)

KeyedItem = tuple[str, Any]


@runtime_checkable
class Sink(Protocol):
    """Batch-oriented keyed sink. Returns the number of items actually
    written (duplicates skipped — idempotence is part of the contract)."""

    def write_batch(self, items: Sequence[KeyedItem]) -> int: ...

    def close(self) -> None: ...


def describe_result_items(result: Any, batch_index: int) -> list[KeyedItem]:
    """Normalize a batch result into keyed items for a sink.

    A list of ``(key, value)`` pairs (keys str or bytes) passes through;
    ``None`` produces nothing; any other value becomes one item keyed by the
    batch index, so replaying the batch overwrites rather than duplicates.
    """
    if result is None:
        return []
    if isinstance(result, list) and all(
            isinstance(x, tuple) and len(x) == 2
            and isinstance(x[0], (str, bytes)) for x in result):
        return [(k.decode() if isinstance(k, bytes) else k, v)
                for k, v in result]
    return [(f"batch-{batch_index:06d}", result)]


class KeyedSink:
    """Base: in-process dedupe by key. Subclasses implement ``_write_one``;
    ``_already_stored`` lets a subclass extend idempotence across restarts
    (e.g. files on disk)."""

    def __init__(self) -> None:
        self._seen: set[str] = set()
        self._lock = threading.Lock()
        self.written = 0
        self.skipped = 0

    def _write_one(self, key: str, value: Any) -> None:  # pragma: no cover
        raise NotImplementedError

    def _already_stored(self, key: str) -> bool:
        return False

    def write_batch(self, items: Sequence[KeyedItem], *,
                    overwrite: bool = False) -> int:
        """``overwrite=True`` bypasses dedupe for keys that must track the
        latest run (e.g. a final-result artifact) — use sparingly; it trades
        away the exactly-once property for those keys."""
        n = 0
        for key, value in items:
            with self._lock:
                dup = (not overwrite
                       and (key in self._seen or self._already_stored(key)))
                self._seen.add(key)
                if dup:
                    self.skipped += 1
            if dup:
                continue
            self._write_one(key, value)
            # counter under the lock, write outside it: lanes sharing a
            # sink race on the ints (the PR-6 MetricsSink bug), but a slow
            # _write_one must not serialize the whole fan-out
            with self._lock:
                self.written += 1
            n += 1
        return n

    def close(self) -> None:
        pass


class NpzDirectorySink(KeyedSink):
    """Checkpoint-style artifact store: one ``<key>.npz`` per item under
    ``directory``. Values may be an array, a dict of arrays, or a scalar.
    Idempotent across restarts: an existing file is never rewritten."""

    def __init__(self, directory: str) -> None:
        super().__init__()
        self.directory = directory
        os.makedirs(directory, exist_ok=True)

    def path_for(self, key: str) -> str:
        safe = key.replace(os.sep, "_")
        return os.path.join(self.directory, f"{safe}.npz")

    def _already_stored(self, key: str) -> bool:
        return os.path.exists(self.path_for(key))

    def _write_one(self, key: str, value: Any) -> None:
        arrays = (dict(value) if isinstance(value, dict)
                  else {"value": np.asarray(value)})
        arrays = {k: np.asarray(v) for k, v in arrays.items()}
        path = self.path_for(key)
        # write via an open handle: np.savez would append ".npz" to a bare
        # tmp name, and a ".tmp.npz" suffix would show up in keys_on_disk()
        # if we crashed before the rename
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            np.savez(f, **arrays)
            # flush+fsync before the rename, or a crash can leave `path`
            # naming torn bytes — and _already_stored would then skip the
            # rewrite forever (idempotence turns the corruption permanent)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)

    def keys_on_disk(self) -> list[str]:
        return sorted(f[:-4] for f in os.listdir(self.directory)
                      if f.endswith(".npz"))


class TopicSink(KeyedSink):
    """Pipe results into a downstream broker topic — DELTA's backend-chaining
    and the paper's multi-stage pipelines: this topic is the next stage's
    :class:`~repro.data.sources.TopicSource`."""

    def __init__(self, broker: Broker, topic: str, partitions: int = 1) -> None:
        super().__init__()
        self.broker = broker
        self.topic = topic
        if topic not in broker.topics():
            broker.create_topic(topic, partitions)
        self._rr = 0

    def _write_one(self, key: str, value: Any) -> None:
        n = self.broker.num_partitions(self.topic)
        self.broker.produce(self.topic, value, key=key.encode(),
                            partition=self._rr % n)
        self._rr += 1


class CallbackSink(KeyedSink):
    """Hand each new ``(key, value)`` to a callable (live plots, asserts)."""

    def __init__(self, fn: Callable[[str, Any], None]) -> None:
        super().__init__()
        self._fn = fn

    def _write_one(self, key: str, value: Any) -> None:
        self._fn(key, value)


class MetricsSink:
    """Latency/throughput aggregation over batches — feeds the same numbers
    as :class:`~repro.core.pipeline.PipelineReport` for sink-side accounting.

    This is a *batch* sink: call ``observe(info)`` per
    :class:`~repro.core.dstream.BatchInfo` (or register the instance with
    ``StreamingContext.add_sink`` / ``NearRealTimePipeline`` — it is
    callable). ``write_batch`` also counts keyed items, so it composes in a
    fan-out next to a storage sink.
    """

    def __init__(self) -> None:
        # both surfaces (observe + write_batch) may run on different
        # delivery-lane worker threads; one lock keeps the counters and the
        # report() snapshot consistent
        self._lock = threading.Lock()
        self.batches = 0
        self.records = 0
        self.items = 0
        self.latencies: list[float] = []

    def observe(self, info: Any) -> None:
        with self._lock:
            self.batches += 1
            self.records += info.num_records
            self.latencies.append(info.processing_time)

    __call__ = observe

    def write_batch(self, items: Sequence[KeyedItem]) -> int:
        with self._lock:
            self.items += len(items)
        return 0

    def close(self) -> None:
        pass

    def report(self) -> dict[str, float]:
        with self._lock:
            batches, records, items = self.batches, self.records, self.items
            latencies = list(self.latencies)
        if not latencies:
            return {"batches": batches, "records": records, "items": items}
        total = max(sum(latencies), 1e-9)
        return {
            "batches": batches,
            "records": records,
            "items": items,
            "mean_latency_s": sum(latencies) / len(latencies),
            "max_latency_s": max(latencies),
            "throughput_rec_per_s": records / total,
        }


def fan_out(sinks: Iterable[Sink]) -> Callable[[Sequence[KeyedItem]], int]:
    """Write the same items to several sinks; returns total writes."""
    sinks = list(sinks)

    def write(items: Sequence[KeyedItem]) -> int:
        return sum(s.write_batch(items) for s in sinks)

    return write
