"""Broker HA: follower replication of durable segments, with failover.

The paper's pipeline leans on Kafka for the property that a beamline never
stops producing: the broker must survive the loss of the machine it runs on.
Kafka gets this from replicated partitions — followers fetch the leader's log
segments byte-for-byte and one of them takes over on leader death, fenced by
a monotonically increasing *leader epoch*. This module reproduces that design
over the repo's own primitives (``docs/replication.md`` is the full story):

- :class:`ReplicaFollower` attaches to a primary's
  :class:`~repro.data.transport.BrokerServer` and pulls committed record
  *frames* per (topic, partition) through the durable log's replication
  cursor. One ``replica_sync`` round trip is one whole pull round: the
  follower's local next-offsets go up (doubling as its high-watermark
  report — the primary-side map producers consult to learn what is safely
  replicated), topic layout plus every partition's new frames come back.
  The CRC frame format of :mod:`repro.data.durable_log` **is** the wire
  format: frames ship verbatim as one blob with a length list, the follower
  re-verifies every CRC and appends the same bytes to its own
  :class:`~repro.data.durable_log.DurableLogFactory` root, so primary and
  follower logs are byte-identical with dense equal offsets.

- :class:`FailoverBroker` is the client-side half: a
  :class:`~repro.core.broker.Broker` duck type over *several* addresses
  (primary + standby followers). It discovers the current primary by probing
  ``broker_epoch``, and when the primary dies mid-call it *promotes* a
  follower at a strictly higher epoch (``promote`` op — the follower starts
  accepting writes, rebuilding group/committed offsets from the replicated
  ``__commits`` topic), re-sends its unconfirmed produce batches, re-points
  itself, and *fences* the old primary should it ever return
  (``fence`` op → :class:`~repro.core.broker.BrokerFencedError` on every
  write a zombie would otherwise accept).

Durability contract (the crash window, quantified by
``bench_ingest:failover_gap``): replication is asynchronous — a batch acked
by the primary may not have reached the follower when the primary dies. The
client therefore keeps every produced batch in a *resend window* until a
follower's reported high-watermark covers it; on failover the window is
re-sent to the new primary. Combined with the idempotent-by-key sinks
downstream this means **no committed record is lost and duplicates are
absorbed**: at-least-once across a failover, exactly-once end-to-end — the
same contract a plain :class:`~repro.data.transport.RemoteBroker` retry
already has. With no follower attached ``replica_hwm`` is empty and the
window collapses to "primary ack = committed", i.e. exactly the pre-HA
behavior.
"""
from __future__ import annotations

import os
import threading
import time
from typing import Any, Callable, Sequence

from repro.core.broker import (COMMIT_TOPIC, Broker, BrokerFencedError,
                               NotPrimaryError, OffsetRange, Record,
                               _route_partition)
from repro.data.durable_log import _REC_HEADER, DurableLogFactory
from repro.data.transport import (FrameError, RemoteBroker, TransportError,
                                  decode_message, serve_broker)
from repro.utils import get_logger

log = get_logger(__name__)

# Errors that mean "this broker cannot serve the call, another one might":
# connectivity loss, a fenced zombie, an unpromoted replica. Everything else
# (GroupError, ValueError, ...) is the caller's problem and propagates.
_FAILOVER_ERRORS = (TransportError, BrokerFencedError, NotPrimaryError)

_EPOCH_FILE = "EPOCH"


def _read_epoch(root: str) -> int:
    try:
        with open(os.path.join(root, _EPOCH_FILE)) as fh:
            return int(fh.read().strip() or 0)
    except (FileNotFoundError, ValueError):
        return 0


def _write_epoch(root: str, epoch: int) -> None:
    """Durably record the epoch this broker last served at, so a restarted
    promoted broker resumes *above* it instead of back at 0 (where the
    fencing comparison would no longer protect the log)."""
    tmp = os.path.join(root, _EPOCH_FILE + ".tmp")
    with open(tmp, "w") as fh:
        fh.write(str(int(epoch)))
        fh.flush()
        os.fsync(fh.fileno())
    os.replace(tmp, os.path.join(root, _EPOCH_FILE))


class ReplicaFollower:
    """Pull-replicate a primary broker's partition logs into a local one.

    ``primary_address`` is the primary's :class:`BrokerServer` address;
    ``root`` the follower-local :class:`DurableLogFactory` directory. The
    follower's own broker starts as a read-only replica
    (``writable=False`` — produce/commit/join raise
    :class:`NotPrimaryError` until promotion) and can be served to clients
    from the start via :meth:`serve`; a
    :class:`FailoverBroker` promotes it through that server when the
    primary dies. The pull loop:

    1. sends ``{topic: [next_offset, ...]}`` — the replication cursors,
       which the primary also records as this replica's high-watermarks —
       in a single ``replica_sync`` round trip,
    2. mirrors any new primary topics locally (same partition counts),
    3. appends the raw CRC frames that came back *verbatim*
       (CRC re-verified) with
       :meth:`~repro.data.durable_log.DurablePartitionLog.append_frames`,
    4. on promotion (detected by the local broker turning writable) writes
       ``root/EPOCH`` and stops pulling — this broker is the primary now.

    A primary outage does not kill the loop: it idles and retries, so a
    recovered (re-fenced) primary's history is still drained if promotion
    never happened.
    """

    def __init__(self, primary_address: Any, root: str,
                 replica_id: str | None = None, poll_interval: float = 0.02,
                 max_bytes: int = 4 * 1024 * 1024,
                 commit_topic: str | None = COMMIT_TOPIC,
                 **log_kwargs: Any) -> None:
        self.root = str(root)
        self.factory = DurableLogFactory(self.root, **log_kwargs)
        self.broker = Broker(log_factory=self.factory,
                             commit_topic=commit_topic, writable=False,
                             epoch=_read_epoch(self.root))
        self.factory.restore(self.broker)   # reopen a prior run's segments
        # persist the epoch the moment a client promotes us through the
        # server — the pull loop may be mid-sleep, and a crash before its
        # next wakeup must not lose the promotion
        self.broker.on_promote = lambda b: _write_epoch(self.root, b.epoch)
        self.primary = RemoteBroker(primary_address, connect_timeout=2.0,
                                    max_retries=1, retry_delay=0.05)
        self.replica_id = replica_id or f"replica-{os.getpid()}"
        self.poll_interval = poll_interval
        self.max_bytes = max_bytes
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()
        self._server = None
        # stats lock: the pull thread writes lag/frames counters that
        # sync_once callers and gauge scrapes read — and it doubles as the
        # follower's seam for the chaos suites' lock-order harness
        from repro.data.locktrace import new_lock
        self._stats_lock = new_lock("ReplicaFollower._stats_lock")
        self._last_lag = 0
        self.frames_replicated = 0
        from repro.data.metrics import get_registry
        reg = get_registry()
        self._m_frames = reg.counter(
            "replication_frames_total",
            "record frames pulled from the primary and appended locally")
        self._m_rounds = reg.counter(
            "replication_rounds_total",
            "replication pull rounds completed against the primary")
        reg.gauge("replication_lag_records",
                  "records the primary holds that this follower does not "
                  "(as of the last pull round)",
                  callback=lambda: self._last_lag)

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> "ReplicaFollower":
        self._stop.clear()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="replica-follower")
        self._thread.start()
        return self

    def serve(self, address: Any = ("127.0.0.1", 0)) -> Any:
        """Serve the follower-local broker (read-only until promoted) and
        return the bound address — what a :class:`FailoverBroker` lists as
        the standby."""
        self._server = serve_broker(self.broker, address)
        return self._server.address

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10)
            self._thread = None
        if self._server is not None:
            self._server.stop()
            self._server = None
        self.primary.close()
        for topic in self.broker.topics():
            for plog in self.broker._topic(topic):
                closer = getattr(plog, "close", None)
                if closer is not None:
                    closer()

    def __enter__(self) -> "ReplicaFollower":
        return self.start()

    def __exit__(self, *exc: Any) -> None:
        self.stop()

    # -- promotion ---------------------------------------------------------
    @property
    def promoted(self) -> bool:
        return self.broker.broker_epoch()["writable"]

    def promote(self, epoch: int) -> dict:
        """In-process promotion (tests, single-process topologies); remote
        clients promote through :meth:`serve`'s server instead."""
        return self.broker.promote(epoch)

    # -- pull loop ---------------------------------------------------------
    def hwms(self) -> dict[str, list[int]]:
        """This follower's replicated next-offsets per (topic, partition)."""
        return {topic: [plog.end_offset()
                        for plog in self.broker._topic(topic)]
                for topic in self.broker.topics()}

    def sync_once(self) -> int:
        """One pull round — a single ``replica_sync`` round trip: report
        the local next-offsets as high-watermarks (even on idle rounds: the
        first report is what makes the primary's ``replica_hwm()``
        non-empty, arming producers' resend windows), mirror any new
        topics, and append the frames that came back. Returns the number of
        frames replicated (0 = fully caught up)."""
        resp = self.primary.replica_sync(self.replica_id, self.hwms(),
                                         max_bytes=self.max_bytes)
        synced, lag = 0, 0
        local = set(self.broker.topics())
        for topic, nparts in resp["topics"].items():
            if topic not in local:
                self.broker.create_topic(topic, nparts)
        for topic, entries in resp["parts"].items():
            plogs = self.broker._topic(topic)
            for p, (blob, lengths, nxt, end) in enumerate(entries):
                plog = plogs[p]
                if lengths:
                    self._append(plog, blob, lengths)
                    synced += len(lengths)
                lag += max(0, end - plog.end_offset())
        with self._stats_lock:
            # pull thread writes, gauge scrapes and test assertions read
            self.frames_replicated += synced
            self._last_lag = lag
        self._m_frames.inc(synced)
        self._m_rounds.inc()
        return synced

    @staticmethod
    def _append(plog, blob: bytes, lengths: Sequence[int]) -> None:
        if sum(lengths) != len(blob):
            raise FrameError(
                f"replication blob is {len(blob)} bytes but its frame "
                f"lengths sum to {sum(lengths)} (truncated in transit)")
        view = memoryview(blob)
        frames: list[bytes] = []
        cut = 0
        for size in lengths:
            frames.append(bytes(view[cut:cut + size]))
            cut += size
        appender = getattr(plog, "append_frames", None)
        if appender is not None:           # durable log: verbatim bytes,
            appender(frames)               # CRC-verified before landing
            return
        # in-memory local log: verify the frame, then decode and append
        import zlib
        for frame in frames:
            length, crc = _REC_HEADER.unpack_from(frame)
            body = memoryview(frame)[_REC_HEADER.size:]
            if length != len(body) or zlib.crc32(body) != crc:
                raise FrameError("replicated frame failed its CRC check")
            key, value, ts = decode_message(bytearray(body))
            plog.append(key, value, ts)

    def _run(self) -> None:
        while not self._stop.is_set():
            if self.promoted:
                # a FailoverBroker promoted us through the server: record
                # the epoch durably and leave the follower role for good
                _write_epoch(self.root, self.broker.epoch)
                log.info("replica %s promoted to primary at epoch %d",
                         self.replica_id, self.broker.epoch)
                return
            try:
                self.sync_once()
            except _FAILOVER_ERRORS + (FrameError, OSError) as e:
                # primary gone (or mid-restart): idle until it returns or a
                # client promotes us — both are normal, neither kills the loop
                log.debug("replication pull failed (primary down?): %s", e)
            except (KeyError, ValueError) as e:
                log.warning("replication pull skipped a round: %s", e)
            # pace every round: back-to-back pulls against a live primary
            # measurably tax its produce hot path (the guard in
            # bench_ingest:replication_overhead), and one max_bytes-sized
            # pull per poll_interval already sustains ~200 MB/s at the
            # defaults — replication lag is bounded by one poll plus one
            # transfer, not by how often the follower can hammer the wire
            self._stop.wait(self.poll_interval)


class _Pending:
    """One produced-but-not-yet-replicated batch in the resend window."""

    __slots__ = ("topic", "needs", "payload")

    def __init__(self, topic: str, needs: dict[int, int],
                 payload: tuple) -> None:
        self.topic = topic
        self.needs = needs                 # partition -> required next offset
        self.payload = payload             # (op, args, kwargs) for resend


class FailoverBroker:
    """Client-side HA wrapper: a :class:`Broker` duck type over a primary
    and its standby replicas, with automatic failover.

    ``addresses`` lists every broker server (primary + followers served via
    :meth:`ReplicaFollower.serve`) in any order; the current primary is
    discovered by probing ``broker_epoch``. Every broker call goes to the
    active primary; when it fails with a connectivity or fencing error the
    wrapper *fails over*: probe all addresses, promote the best reachable
    candidate at a strictly higher epoch, re-send the unconfirmed produce
    window, fence any stale writable broker, bump :attr:`failovers` and
    notify listeners — then the call retries transparently. Producers and
    consumers built on the ``Broker`` duck type (``IngestRunner``,
    ``StreamingContext``, ``GroupConsumer``) ride through a primary SIGKILL
    without code changes; consumers watch :attr:`failovers` to know the
    offset space may have rewound (``StreamingContext`` rebases to committed
    offsets when it changes).

    The resend window is the durability half (module docstring): produced
    batches are held until ``replica_hwm`` shows a follower covering their
    offsets, and re-sent to the new primary on failover. Duplicates are
    possible (at-least-once), lost committed records are not — except
    records no follower ever saw *and* whose producer also died, the
    irreducible async-replication window ``docs/replication.md`` tabulates.
    """

    def __init__(self, addresses: Sequence[Any], connect_timeout: float = 2.0,
                 max_retries: int = 2, retry_delay: float = 0.05,
                 confirm_interval: float = 0.05) -> None:
        if not addresses:
            raise ValueError("FailoverBroker needs at least one address")
        self._addrs = list(addresses)
        self._clients: dict[Any, RemoteBroker] = {
            addr: RemoteBroker(addr, connect_timeout=connect_timeout,
                               max_retries=max_retries,
                               retry_delay=retry_delay)
            for addr in self._addrs}
        from repro.data.locktrace import new_rlock  # lock seam (chaos suites)
        self._lock = new_rlock("FailoverBroker._lock")
        self._pending: list[_Pending] = []
        self._nparts_cache: dict[str, int] = {}
        self._listeners: list[Callable[["FailoverBroker"], None]] = []
        self._confirm_interval = confirm_interval
        self._last_confirm = 0.0
        self.epoch = 0
        self.failovers = 0
        from repro.data.metrics import get_registry
        reg = get_registry()
        self._m_failovers = reg.counter(
            "replication_failovers_total",
            "primary failovers performed (follower promoted + repointed)")
        reg.gauge("replication_pending_batches",
                  "produced batches awaiting follower replication "
                  "(the failover resend window)",
                  callback=lambda: len(self._pending))
        self._active = self._elect(avoid=None)[0]

    # -- membership --------------------------------------------------------
    @property
    def active_address(self) -> Any:
        return self._active

    def add_failover_listener(
            self, fn: Callable[["FailoverBroker"], None]) -> None:
        """``fn(self)`` runs after each completed failover (promotion +
        resend + fencing) — e.g. to re-point monitoring."""
        self._listeners.append(fn)

    def _client(self, addr: Any) -> RemoteBroker:
        return self._clients[addr]

    def _probe(self) -> dict[Any, dict]:
        states: dict[Any, dict] = {}
        for addr in self._addrs:
            try:
                states[addr] = self._client(addr).broker_epoch()
            except _FAILOVER_ERRORS:
                continue
        return states

    def _elect(self, avoid: Any) -> tuple[Any, bool]:
        """Pick (or make) a primary. Prefers an already-writable broker at
        our epoch or above; otherwise promotes the best reachable candidate
        at a strictly higher epoch. Returns ``(address, promoted)``."""
        states = self._probe()
        if not states:
            raise TransportError(
                f"no broker reachable among {self._addrs!r}")
        writable = sorted(
            ((st["epoch"], addr) for addr, st in states.items()
             if st["writable"] and st["epoch"] >= self.epoch
             and addr != avoid),
            reverse=True)
        if writable:
            epoch, addr = writable[0]
            self.epoch = max(self.epoch, epoch)
            return addr, False
        new_epoch = max([self.epoch]
                        + [st["epoch"] for st in states.values()]) + 1
        candidates = [a for a in states if a != avoid] or list(states)
        for addr in candidates:
            try:
                self._client(addr).promote(new_epoch)
            except _FAILOVER_ERRORS + (ValueError,) as e:
                log.warning("promotion of %r at epoch %d failed: %s",
                            addr, new_epoch, e)
                continue
            self.epoch = new_epoch
            return addr, True
        raise TransportError(
            f"no promotable broker among {self._addrs!r} "
            f"(epoch {new_epoch})")

    def _failover(self) -> None:
        failed = self._active
        addr, promoted = self._elect(avoid=failed)
        self._active = addr
        self.failovers += 1
        self._m_failovers.inc()
        log.warning("failed over from %r to %r (epoch %d, promoted=%s, "
                    "resending %d pending batches)", failed, addr,
                    self.epoch, promoted, len(self._pending))
        self._resend_pending()
        self.fence_stale()
        self._nparts_cache.clear()
        for fn in list(self._listeners):
            try:
                fn(self)
            except Exception as e:        # listener bugs don't block traffic
                log.warning("failover listener raised %r", e)

    def fence_stale(self) -> list[Any]:
        """Fence every reachable non-active broker still writable at an
        older epoch (a zombie primary that came back). Returns the addresses
        fenced. Runs after each failover; call it directly when a known-dead
        primary is restarted."""
        fenced = []
        for addr, st in self._probe().items():
            if addr == self._active:
                continue
            if st["writable"] and st["epoch"] < self.epoch:
                try:
                    self._client(addr).fence(self.epoch)
                    fenced.append(addr)
                except _FAILOVER_ERRORS + (ValueError,):
                    continue
        return fenced

    # -- call plumbing -----------------------------------------------------
    def _call(self, op: str, *args: Any, **kwargs: Any) -> Any:
        last: Exception | None = None
        with self._lock:
            for _ in range(len(self._addrs) + 1):
                try:
                    return getattr(self._client(self._active),
                                   op)(*args, **kwargs)
                except _FAILOVER_ERRORS as e:
                    last = e
                    self._failover()
        raise TransportError(
            f"{op} failed despite failover across {self._addrs!r}: {last}"
        ) from last

    def _nparts(self, topic: str) -> int:
        n = self._nparts_cache.get(topic)
        if n is None:
            n = self._nparts_cache[topic] = self._call("num_partitions",
                                                       topic)
        return n

    # -- resend window -----------------------------------------------------
    def _track(self, topic: str, pairs: Sequence[tuple],
               partition: int | None, offsets: Sequence[int],
               payload: tuple) -> None:
        nparts = self._nparts(topic)
        needs: dict[int, int] = {}
        for (key, _value), off in zip(pairs, offsets):
            p = partition if partition is not None \
                else _route_partition(key, nparts)
            needs[p] = max(needs.get(p, 0), off + 1)
        self._pending.append(_Pending(topic, needs, payload))

    def _resend_pending(self) -> None:
        """Replay the unconfirmed window against the (new) active primary.
        The new primary's log may be missing the unreplicated tail, so each
        batch's required offsets are recomputed from the re-append."""
        client = self._client(self._active)
        for entry in self._pending:
            op, args, kwargs = entry.payload
            result = getattr(client, op)(*args, **kwargs)
            if op == "produce":
                pairs, offsets = [(kwargs.get("key"), args[1])], [result]
            else:
                pairs, offsets = args[1], result
            topic = args[0]
            nparts = client.num_partitions(topic)
            needs: dict[int, int] = {}
            for (key, _value), off in zip(pairs, offsets):
                p = kwargs.get("partition")
                if p is None:
                    p = _route_partition(key, nparts)
                needs[p] = max(needs.get(p, 0), off + 1)
            entry.needs = needs

    def _confirm(self) -> None:
        self._last_confirm = time.monotonic()
        try:
            hwms = self._call("replica_hwm")
        except TransportError:
            return
        if not hwms:
            # nobody has reported a high-watermark yet. Distinguish "no
            # follower in this deployment" (primary ack is all the
            # durability there is — pre-HA semantics, window collapses)
            # from "follower attached but its first report hasn't landed"
            # (clearing now would silently void the no-loss guarantee).
            if any(not st["writable"] for st in self._probe().values()):
                return                     # a replica exists: keep waiting
            self._pending.clear()
            return

        def covered(entry: _Pending) -> bool:
            for p, need in entry.needs.items():
                if not any(len(m.get(entry.topic, [])) > p
                           and m[entry.topic][p] >= need
                           for m in hwms.values()):
                    return False
            return True

        self._pending = [e for e in self._pending if not covered(e)]

    def _maybe_confirm(self) -> None:
        if self._pending and \
                time.monotonic() - self._last_confirm \
                >= self._confirm_interval:
            self._confirm()

    def flush(self, timeout: float | None = 5.0) -> bool:
        """Block until every produced batch is follower-covered (or the
        deployment has no followers). Returns ``False`` on timeout with
        batches still unconfirmed — the caller's data is *safe on the
        primary* but a primary loss right now would rely on the resend
        window in this process."""
        deadline = (time.monotonic() + timeout) if timeout is not None \
            else None
        while True:
            with self._lock:
                if not self._pending:
                    return True
                self._confirm()
                if not self._pending:
                    return True
            if deadline is not None and time.monotonic() > deadline:
                return False
            time.sleep(0.01)

    @property
    def pending_batches(self) -> int:
        with self._lock:
            return len(self._pending)

    # -- Broker surface: producers ----------------------------------------
    def produce(self, topic: str, value: Any, key: bytes | None = None,
                partition: int | None = None, timestamp: float = 0.0) -> int:
        with self._lock:
            offset = self._call("produce", topic, value, key=key,
                                partition=partition, timestamp=timestamp)
            self._track(topic, [(key, value)], partition, [offset],
                        ("produce", (topic, value),
                         {"key": key, "partition": partition,
                          "timestamp": timestamp}))
            self._maybe_confirm()
        return offset

    def produce_many(self, topic: str, pairs, partition: int | None = None,
                     timestamp: float = 0.0) -> list[int]:
        pairs = list(pairs)
        with self._lock:
            offsets = self._call("produce_many", topic, pairs,
                                 partition=partition, timestamp=timestamp)
            self._track(topic, pairs, partition, offsets,
                        ("produce_many", (topic, pairs),
                         {"partition": partition, "timestamp": timestamp}))
            self._maybe_confirm()
        return offsets

    # -- Broker surface: passthrough --------------------------------------
    def create_topic(self, topic: str, partitions: int = 1,
                     codec: str | None = None) -> None:
        self._call("create_topic", topic, partitions, codec=codec)

    def topic_codec(self, topic: str) -> str | None:
        return self._call("topic_codec", topic)

    def topics(self) -> list[str]:
        return self._call("topics")

    def num_partitions(self, topic: str) -> int:
        return self._call("num_partitions", topic)

    def read(self, rng: OffsetRange) -> list[Record]:
        return self._call("read", rng)

    def end_offset(self, topic: str, partition: int = 0) -> int:
        return self._call("end_offset", topic, partition)

    def end_offsets(self, topic: str) -> list[int]:
        return self._call("end_offsets", topic)

    def commit(self, topic: str, partition: int, offset: int,
               group: str = "", consumer: str | None = None,
               generation: int | None = None) -> None:
        self._call("commit", topic, partition, offset, group=group,
                   consumer=consumer, generation=generation)

    def committed(self, topic: str, group: str = "") -> list[int]:
        return self._call("committed", topic, group=group)

    def commit_groups(self, topic: str) -> list[str]:
        return self._call("commit_groups", topic)

    def lag(self, topic: str, group: str = "") -> int:
        return self._call("lag", topic, group=group)

    def join_group(self, group: str, consumer: str, topics,
                   session_timeout: float = 5.0) -> dict:
        return self._call("join_group", group, consumer, list(topics),
                          session_timeout=session_timeout)

    def heartbeat(self, group: str, consumer: str, generation: int) -> dict:
        return self._call("heartbeat", group, consumer, generation)

    def sync_group(self, group: str, consumer: str, generation: int) -> dict:
        return self._call("sync_group", group, consumer, generation)

    def leave_group(self, group: str, consumer: str) -> None:
        self._call("leave_group", group, consumer)

    def describe_group(self, group: str) -> dict:
        return self._call("describe_group", group)

    def ping(self) -> bool:
        return self._call("ping")

    def stats(self) -> dict:
        return self._call("stats")

    def replica_hwm(self, replica_id: str | None = None,
                    hwms: dict | None = None) -> dict:
        return self._call("replica_hwm", replica_id=replica_id, hwms=hwms)

    def broker_epoch(self) -> dict:
        return {"epoch": self.epoch, "writable": True}

    # -- lifecycle ---------------------------------------------------------
    def close(self) -> None:
        with self._lock:
            for client in self._clients.values():
                client.close()

    def __enter__(self) -> "FailoverBroker":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()
