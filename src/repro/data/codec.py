"""Per-topic payload codecs: compress detector frames at the source→broker
boundary, decode at subscribe.

DELTA streams KSTAR shots to NERSC over a WAN and leans on reduction at the
source because the link, not the cluster, is the bottleneck (SNIPPETS.md §1);
the Spark-MPI follow-up likewise minimizes data movement between the
streaming and HPC sides. This module is that role in our stack: a topic
created with ``codec="int8"`` (or ``IngestConfig(codec=...)``) has its record
*values* encoded by :class:`~repro.data.ingest.IngestRunner` before they ever
reach the broker, and decoded by ``StreamingContext``/``TopicSource`` when
consumed. The broker itself never looks inside a value, so
``DurablePartitionLog`` segments and ``ReplicaFollower`` byte-identity
replication carry codec'd payloads verbatim — compression composes with
durability and HA for free.

Encoded values are *self-describing*: a dict whose ``"__codec__"`` key names
the codec, so :func:`maybe_decode` needs no topic configuration (an O(1)
isinstance + key check on the consume hot path) and a consumer reading a
mixed log of raw and codec'd records decodes each correctly. An encoded
value naming a codec this process does not know is refused with
:class:`UnknownCodecError`, never silently passed through.

Codecs:

- ``raw`` — identity; the default for every topic not configured otherwise
  (control topics — ``__commits``, dead-letter queues — stay raw because
  they never pass through the ingest encode boundary at all).
- ``int8`` — *lossy* symmetric per-tensor quantization, the NumPy mirror of
  ``repro.optim.compression.quantize_int8``: float arrays anywhere in the
  value shrink 4x (float32) with per-element error ≤ ``amax/127``. The int8
  payload arrays still ride the transport's out-of-band buffer path
  (``'A'``/``'S'`` frames), so zero-copy framing is preserved.
- ``zlib`` — lossless byte-level compression of the whole pickled value.
  Decode routes through the transport's *restricted* unpickler: bytes that
  came off the wire stay inside the same trust boundary as the wire itself
  (see ``repro.data.transport.register_safe``).
"""
from __future__ import annotations

import pickle
import zlib as _zlib
from typing import Any, Callable

import numpy as np

from repro.data.transport import _ERR_TYPES, _restricted_load

# The self-description key on encoded values. A *raw-topic* user value that
# happens to be a dict carrying this key is wrapped by the raw codec on
# encode (and unwrapped on decode) so it can never be mistaken for an
# encoded payload.
SENTINEL = "__codec__"

# Marker key for a quantized array node inside an int8-encoded value.
_Q8 = "__q8__"


class UnknownCodecError(ValueError):
    """An encoded value (or a ``create_topic``/``IngestConfig``) names a
    codec this process has no decoder for — refused, never passed through
    as-is or guessed at."""


# a remote create_topic with a bad codec name must raise the same type the
# in-process broker does (the parity matrix pins this), so the transport
# needs to reconstruct it from the error frame
_ERR_TYPES["UnknownCodecError"] = UnknownCodecError


class Codec:
    """One payload codec: ``encode`` runs producer-side at the ingest flush
    boundary, ``decode`` consumer-side at subscribe. Both take and return a
    record *value* (any restricted-pickle-safe object)."""

    name: str = "?"

    def encode(self, value: Any) -> Any:
        raise NotImplementedError

    def decode(self, wrapped: Any) -> Any:
        raise NotImplementedError


class RawCodec(Codec):
    """Identity, except for escaping user dicts that collide with the
    sentinel key (so raw values round-trip byte-exactly through consumers
    that :func:`maybe_decode` everything)."""

    name = "raw"

    def encode(self, value: Any) -> Any:
        if isinstance(value, dict) and SENTINEL in value:
            return {SENTINEL: self.name, "v": value}
        return value

    def decode(self, wrapped: Any) -> Any:
        return wrapped["v"]


def _quantize(arr: np.ndarray) -> dict:
    """NumPy mirror of ``repro.optim.compression.quantize_int8`` (pinned
    against it by a parity test): symmetric per-tensor int8."""
    x32 = np.asarray(arr, dtype=np.float32)
    amax = float(np.max(np.abs(x32))) if x32.size else 0.0
    scale = max(amax / 127.0, 1e-12)
    q = np.clip(np.round(x32 / scale), -127, 127).astype(np.int8)
    return {_Q8: 1, "q": q, "s": scale, "d": str(arr.dtype)}


def _dequantize(node: dict) -> np.ndarray:
    out = node["q"].astype(np.float32) * node["s"]
    return out.astype(node["d"], copy=False)


class Int8Codec(Codec):
    """Lossy: every floating-point ndarray in the value is replaced by its
    int8 quantization (4x smaller for float32, 8x for float64); everything
    else passes through untouched. Error per element is bounded by the
    tensor's ``amax/127`` — fine for detector frames feeding iterative
    solvers, wrong for control data, which is why codecs are per-topic."""

    name = "int8"

    def _walk_enc(self, v: Any) -> Any:
        if isinstance(v, np.ndarray) and v.dtype.kind == "f":
            return _quantize(v)
        if isinstance(v, dict):
            return {k: self._walk_enc(x) for k, x in v.items()}
        if isinstance(v, (list, tuple)):
            return type(v)(self._walk_enc(x) for x in v)
        return v

    def _walk_dec(self, v: Any) -> Any:
        if isinstance(v, dict):
            if _Q8 in v:
                return _dequantize(v)
            return {k: self._walk_dec(x) for k, x in v.items()}
        if isinstance(v, (list, tuple)):
            return type(v)(self._walk_dec(x) for x in v)
        return v

    def encode(self, value: Any) -> Any:
        return {SENTINEL: self.name, "v": self._walk_enc(value)}

    def decode(self, wrapped: Any) -> Any:
        return self._walk_dec(wrapped["v"])


class ZlibCodec(Codec):
    """Lossless byte-level compression of the whole pickled value. Decode
    goes through the transport's restricted unpickler — the compressed blob
    crossed the wire, so it gets exactly the wire's trust model (values with
    custom classes need ``transport.register_safe`` on the consumer, same as
    they would to cross the socket raw)."""

    name = "zlib"

    def __init__(self, level: int = 1) -> None:
        self.level = level             # speed over ratio: this is a hot path

    def encode(self, value: Any) -> Any:
        blob = pickle.dumps(value, protocol=pickle.HIGHEST_PROTOCOL)
        return {SENTINEL: self.name, "z": _zlib.compress(blob, self.level)}

    def decode(self, wrapped: Any) -> Any:
        return _restricted_load(_zlib.decompress(wrapped["z"]))


_CODECS: dict[str, Codec] = {}


def register_codec(codec: Codec) -> Codec:
    """Add a codec to the registry (both sides: producers that encode with
    it and consumers that will meet its name in ``__codec__``)."""
    _CODECS[codec.name] = codec
    return codec


register_codec(RawCodec())
register_codec(Int8Codec())
register_codec(ZlibCodec())


def codec_names() -> list[str]:
    return sorted(_CODECS)


def get_codec(name: str) -> Codec:
    try:
        return _CODECS[name]
    except KeyError:
        raise UnknownCodecError(
            f"unknown codec {name!r} (known: {codec_names()}; "
            "see repro.data.codec.register_codec)") from None


def maybe_decode(value: Any) -> Any:
    """Decode ``value`` if it is a codec-wrapped payload, else return it
    unchanged. O(1) for unwrapped values — safe on every consume path."""
    if isinstance(value, dict) and SENTINEL in value:
        return get_codec(value[SENTINEL]).decode(value)
    return value


def compose_decoder(decoder: Callable[[Any], Any] | None
                    ) -> Callable[[Any], Any]:
    """Codec decode first, then the user's value decoder (if any) — what
    ``StreamingContext`` applies to every consumed record value."""
    if decoder is None:
        return maybe_decode
    return lambda v: decoder(maybe_decode(v))


class CodecBroker:
    """Transparent encode/decode adapter around any broker duck type:
    ``produce``/``produce_many`` encode values, ``read`` decodes them —
    every other call passes through. With a lossless codec this is
    observationally identical to the wrapped broker, which is exactly what
    the ``codec`` row of the broker contract-parity matrix pins."""

    def __init__(self, broker: Any, codec: str = "zlib") -> None:
        self._broker = broker
        self._codec = get_codec(codec)

    def __getattr__(self, name: str) -> Any:
        return getattr(self._broker, name)

    def produce(self, topic: str, value: Any, **kwargs: Any) -> int:
        return self._broker.produce(topic, self._codec.encode(value),
                                    **kwargs)

    def _encode_pair(self, pair: Any) -> Any:
        try:
            k, v = pair
        except (TypeError, ValueError):
            return pair                # malformed: the broker's validation
        return (k, self._codec.encode(v))  # raises, preserving its error type

    def produce_many(self, topic: str, pairs, **kwargs: Any) -> list[int]:
        enc = [self._encode_pair(p) for p in pairs]
        return self._broker.produce_many(topic, enc, **kwargs)

    def read(self, rng) -> list:
        from repro.core.broker import Record
        return [Record(r.key, maybe_decode(r.value), r.offset, r.timestamp)
                for r in self._broker.read(rng)]

    def close(self) -> None:
        close = getattr(self._broker, "close", None)
        if close is not None:
            close()
