"""HTTP observability endpoint: the registry + trace log served live.

Stdlib-only (``http.server``), one daemon thread, bound to an ephemeral port
by default — small enough to run inside a test and real enough for a
Prometheus scrape config or a dashboard poll loop (the role MongoDB plays
for DELTA's visualization consumer and InfluxDB/Grafana for CFAA).

Routes:

=====================  =====================================================
``GET /metrics``       Prometheus text exposition of the whole registry
``GET /metrics.json``  full registry: values, histogram buckets, and each
                       metric's ring-buffer ``(t, value)`` series
``GET /traces?last=N`` the most recent N batch-epoch trace spans (default
                       32): per-stage timings tagged with checkpoint epoch
``GET /health``        ``ok`` / ``degraded`` verdict: per-topic consumer lag
                       judged against :class:`~repro.core.fault.LagPolicy`
                       watermarks (HTTP 200 / 503, so a load balancer or
                       systemd watchdog can consume it without parsing)
=====================  =====================================================

Each scrape of ``/metrics`` or ``/metrics.json`` calls
:meth:`~repro.data.metrics.MetricsRegistry.sample` first, so the ring-buffer
series advance at scrape frequency — the Prometheus pull model, with the
last ``ring_size`` points kept in-process for consumers that cannot run a
TSDB.

Start one via :meth:`repro.core.dstream.StreamingContext.serve_observability`
/ ``NearRealTimePipeline.serve_observability`` (wires the context's
registry, trace log, and lag-based health in one call), or standalone::

    server = ObservabilityServer(registry=get_registry()).start()
    print(server.url)          # e.g. http://127.0.0.1:43215
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Callable
from urllib.parse import parse_qs, urlparse

from repro.data.metrics import MetricsRegistry, TraceLog, get_registry
from repro.utils import get_logger

log = get_logger(__name__)


def lag_health(lag_of: Callable[[], "dict[str, int]"],
               lag_policy: Any = None) -> Callable[[], dict]:
    """Build a ``/health`` callback from a live per-topic lag snapshot and
    (optionally) a :class:`~repro.core.fault.LagPolicy` whose
    ``scale_up_lag`` watermark defines *degraded*. Without a policy the
    endpoint reports lags but never degrades (no watermark to judge by)."""
    up = getattr(lag_policy, "scale_up_lag", None)
    down = getattr(lag_policy, "scale_down_lag", None)

    def health() -> dict:
        try:
            lags = dict(lag_of())
        except Exception as e:         # a torn-down context must not 500
            return {"status": "degraded", "error": repr(e), "topics": {}}
        degraded = [t for t, lag in lags.items()
                    if up is not None and lag >= up]
        return {
            "status": "degraded" if degraded else "ok",
            "topics": {t: {"lag": lag,
                           "scale_up_lag": up, "scale_down_lag": down,
                           "ok": t not in degraded}
                       for t, lag in lags.items()},
        }

    return health


class _Handler(BaseHTTPRequestHandler):
    # set per-server via functools-free subclassing in ObservabilityServer
    registry: MetricsRegistry
    traces: TraceLog | None
    health_fn: Callable[[], dict] | None

    protocol_version = "HTTP/1.1"

    def log_message(self, fmt: str, *args: Any) -> None:
        log.debug("obs: " + fmt, *args)

    def _send(self, status: int, body: bytes, content_type: str) -> None:
        self.send_response(status)
        self.send_header("Content-Type", content_type)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _send_json(self, obj: Any, status: int = 200) -> None:
        self._send(status, json.dumps(obj, default=_jsonable).encode(),
                   "application/json")

    def do_GET(self) -> None:          # noqa: N802 - BaseHTTPRequestHandler
        try:
            url = urlparse(self.path)
            if url.path == "/metrics":
                self.registry.sample()
                self._send(200, self.registry.prometheus_text().encode(),
                           "text/plain; version=0.0.4")
            elif url.path == "/metrics.json":
                self.registry.sample()
                self._send_json(self.registry.snapshot())
            elif url.path == "/traces":
                qs = parse_qs(url.query)
                try:
                    last = int(qs.get("last", ["32"])[0])
                except ValueError:
                    self._send_json({"error": "last must be an integer"},
                                    status=400)
                    return
                spans = (self.traces.last(last)
                         if self.traces is not None else [])
                self._send_json({"spans": [s.as_dict() for s in spans],
                                 "recorded": getattr(self.traces,
                                                     "recorded", 0)})
            elif url.path == "/health":
                verdict = (self.health_fn() if self.health_fn is not None
                           else {"status": "ok", "topics": {}})
                self._send_json(
                    verdict,
                    status=200 if verdict.get("status") == "ok" else 503)
            else:
                self._send_json({"error": f"no route {url.path}",
                                 "routes": ["/metrics", "/metrics.json",
                                            "/traces", "/health"]},
                                status=404)
        except BrokenPipeError:        # client went away mid-response
            pass
        except Exception as e:         # never kill the serving thread
            log.warning("obs endpoint error on %s: %r", self.path, e)
            try:
                self._send_json({"error": repr(e)}, status=500)
            except OSError:
                pass


def _jsonable(obj: Any) -> Any:
    as_dict = getattr(obj, "as_dict", None)
    if as_dict is not None:
        return as_dict()
    return repr(obj)


class ObservabilityServer:
    """Serve a registry (+ optional trace log and health callback) over HTTP.

    ``address`` is ``(host, port)``; port 0 binds an ephemeral port — read
    it back from :attr:`address` / :attr:`url` after :meth:`start`.
    """

    def __init__(self, registry: MetricsRegistry | None = None,
                 traces: TraceLog | None = None,
                 health_fn: Callable[[], dict] | None = None,
                 address: tuple[str, int] = ("127.0.0.1", 0)) -> None:
        self.registry = registry if registry is not None else get_registry()
        self.traces = traces
        self.health_fn = health_fn
        self._requested = address
        self._httpd: ThreadingHTTPServer | None = None
        self._thread: threading.Thread | None = None
        self.address: tuple[str, int] | None = None

    @property
    def url(self) -> str:
        if self.address is None:
            raise RuntimeError("server not started")
        return f"http://{self.address[0]}:{self.address[1]}"

    def start(self) -> "ObservabilityServer":
        if self._httpd is not None:
            return self
        # staticmethod: a plain-function health_fn stored on the class would
        # otherwise bind as a method and receive the handler as an argument
        handler = type("_BoundHandler", (_Handler,), {
            "registry": self.registry, "traces": self.traces,
            "health_fn": (staticmethod(self.health_fn)
                          if self.health_fn is not None else None)})
        self._httpd = ThreadingHTTPServer(self._requested, handler)
        self._httpd.daemon_threads = True
        self.address = self._httpd.server_address[:2]
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        daemon=True, name="obs-server")
        self._thread.start()
        log.info("observability endpoint on %s", self.url)
        return self

    def stop(self) -> None:
        if self._httpd is None:
            return
        self._httpd.shutdown()
        self._httpd.server_close()
        self._httpd = None
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None

    def __enter__(self) -> "ObservabilityServer":
        return self.start()

    def __exit__(self, *exc: Any) -> None:
        self.stop()


def serve_observability(registry: MetricsRegistry | None = None,
                        traces: TraceLog | None = None,
                        health_fn: Callable[[], dict] | None = None,
                        address: tuple[str, int] = ("127.0.0.1", 0)
                        ) -> ObservabilityServer:
    """Start an :class:`ObservabilityServer`; returns it with
    :attr:`~ObservabilityServer.address` bound."""
    return ObservabilityServer(registry, traces, health_fn, address).start()
