"""Opt-in lock-order tracing: a runtime complement to the static
`lock-discipline` rule (docs/static_analysis.md).

The data plane documents one global lock-order invariant — coordinator →
broker, never the reverse (`groups.py`) — but nothing enforced it: a new
call path that nests the locks the other way deadlocks only under the
right interleaving, which chaos suites hit once a month and users hit in
production. :class:`TracingLock` closes that gap:

- API-compatible with ``threading.Lock`` / ``threading.RLock`` (acquire/
  release/context-manager/locked), so components can be constructed with
  traced locks transparently;
- every acquisition records a *lock-order edge* (holder → acquiree) into
  a process-wide :class:`LockRegistry`, keyed by lock *name* (one node
  per lock role, e.g. ``Broker._lock``, not per instance) — a cycle in
  that graph is a potential deadlock even if this run never interleaved
  into it;
- while tracing is enabled, fully-blocking calls (``queue.Queue.get``
  and ``socket.recv``/``recv_into`` with timeout ``None``) made while a
  traced lock is held are recorded as *hazards*: a peer that never
  answers turns the lock into a deadlock.

Production components take their locks from :func:`new_lock` /
:func:`new_rlock` — plain ``threading`` primitives unless a registry is
:func:`enable`\\ d, so the hot path costs nothing when tracing is off.
``tests/conftest.py`` enables tracing for the delivery/groups/replication
chaos suites and asserts the recorded graph is acyclic.
"""
from __future__ import annotations

import os
import queue
import socket
import threading
import traceback
from dataclasses import dataclass, field
from typing import Any

__all__ = ["TracingLock", "LockRegistry", "LockOrderReport", "enable",
           "disable", "active", "new_lock", "new_rlock", "tracing"]


@dataclass(frozen=True)
class Hazard:
    held: tuple[str, ...]     # traced locks held by the calling thread
    call: str                 # e.g. "queue.Queue.get(timeout=None)"
    site: str                 # "file:line" of the caller


@dataclass
class LockOrderReport:
    locks: set[str]
    edges: dict[tuple[str, str], str]   # (held, acquired) -> first site
    cycles: list[list[str]]
    hazards: list[Hazard]

    def describe(self) -> str:
        lines = [f"{len(self.locks)} lock(s), {len(self.edges)} order "
                 f"edge(s), {len(self.cycles)} cycle(s), "
                 f"{len(self.hazards)} hazard(s)"]
        for cyc in self.cycles:
            lines.append("  cycle: " + " -> ".join(cyc + cyc[:1]))
        for (a, b), site in sorted(self.edges.items()):
            lines.append(f"  edge: {a} -> {b}   [{site}]")
        for hz in self.hazards:
            lines.append(f"  hazard: {hz.call} while holding "
                         f"{', '.join(hz.held)}   [{hz.site}]")
        return "\n".join(lines)


def _call_site() -> str:
    # the most recent frame outside this module: the code doing the locking
    for frame in reversed(traceback.extract_stack(limit=12)):
        if os.path.basename(frame.filename) != "locktrace.py":
            return f"{frame.filename}:{frame.lineno}"
    return "?"


class LockRegistry:
    """Process-wide acquisition graph. Thread-safe; the per-acquire cost
    is a thread-local list append plus one set lookup for known edges."""

    def __init__(self) -> None:
        self._mu = threading.Lock()
        self._tls = threading.local()
        self._names: set[str] = set()
        self._edges: dict[tuple[str, str], str] = {}
        self._hazards: list[Hazard] = []

    # -- called by TracingLock (hot path) ----------------------------------
    def _stack(self) -> list:
        try:
            return self._tls.stack
        except AttributeError:
            stack = self._tls.stack = []
            return stack

    def _acquired(self, lock: "TracingLock") -> None:
        stack = self._stack()
        reentrant = any(l is lock for l in stack)
        if stack and not reentrant:
            edge = (stack[-1].name, lock.name)
            if edge not in self._edges:
                with self._mu:
                    self._edges.setdefault(edge, _call_site())
        stack.append(lock)

    def _released(self, lock: "TracingLock") -> None:
        stack = self._stack()
        for i in range(len(stack) - 1, -1, -1):
            if stack[i] is lock:
                del stack[i]
                return

    def _register(self, name: str) -> None:
        with self._mu:
            self._names.add(name)

    def _blocking_call(self, call: str) -> None:
        held = tuple(l.name for l in self._stack())
        if held:
            with self._mu:
                self._hazards.append(Hazard(held, call, _call_site()))

    # -- reporting ---------------------------------------------------------
    def cycles(self) -> list[list[str]]:
        """Strongly connected components of size > 1 (plus self-edges):
        each is a set of locks with no consistent global order."""
        with self._mu:
            edges = list(self._edges)
        adj: dict[str, list[str]] = {}
        for a, b in edges:
            adj.setdefault(a, []).append(b)
            adj.setdefault(b, [])
        index: dict[str, int] = {}
        low: dict[str, int] = {}
        on_stack: set[str] = set()
        stack: list[str] = []
        out: list[list[str]] = []
        counter = [0]

        def strongconnect(v: str) -> None:
            index[v] = low[v] = counter[0]
            counter[0] += 1
            stack.append(v)
            on_stack.add(v)
            for w in adj[v]:
                if w not in index:
                    strongconnect(w)
                    low[v] = min(low[v], low[w])
                elif w in on_stack:
                    low[v] = min(low[v], index[w])
            if low[v] == index[v]:
                scc = []
                while True:
                    w = stack.pop()
                    on_stack.discard(w)
                    scc.append(w)
                    if w == v:
                        break
                if len(scc) > 1 or (v, v) in edges:
                    out.append(sorted(scc))

        for v in adj:
            if v not in index:
                strongconnect(v)
        return out

    def report(self) -> LockOrderReport:
        with self._mu:
            locks = set(self._names)
            edges = dict(self._edges)
            hazards = list(self._hazards)
        return LockOrderReport(locks, edges, self.cycles(), hazards)


class TracingLock:
    """Drop-in ``threading.Lock``/``RLock`` that reports into a registry.

    Reentrant acquires of an RLock-flavored instance are recorded on the
    per-thread stack (so releases pair up) but never produce an order
    edge — holding a lock you already hold orders nothing.
    """

    __slots__ = ("name", "reentrant", "_reg", "_inner")

    def __init__(self, name: str, registry: LockRegistry,
                 reentrant: bool = False) -> None:
        self.name = name
        self.reentrant = reentrant
        self._reg = registry
        self._inner = threading.RLock() if reentrant else threading.Lock()
        registry._register(name)

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        got = self._inner.acquire(blocking, timeout)
        if got:
            self._reg._acquired(self)
        return got

    def release(self) -> None:
        self._reg._released(self)
        self._inner.release()

    def __enter__(self) -> bool:
        return self.acquire()

    def __exit__(self, *exc: Any) -> None:
        self.release()

    def locked(self) -> bool:
        probe = getattr(self._inner, "locked", None)
        if probe is not None:
            return probe()
        # RLock before 3.13 has no locked(). A non-blocking probe alone
        # lies when *this* thread is the owner (it just re-enters), so
        # check ownership first; only then does probe-failure mean "held
        # by someone else".
        if self._inner._is_owned():
            return True
        if self._inner.acquire(blocking=False):
            self._inner.release()
            return False
        return True

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        kind = "RLock" if self.reentrant else "Lock"
        return f"<TracingLock {self.name} ({kind})>"


# -- process-wide switchboard ----------------------------------------------

_active: LockRegistry | None = None
_patches: list[tuple[Any, str, Any]] = []
_switch_mu = threading.Lock()


def active() -> LockRegistry | None:
    return _active


def new_lock(name: str) -> Any:
    """Construction seam: a plain ``threading.Lock`` normally, a traced
    lock while a registry is enabled."""
    reg = _active
    if reg is None:
        return threading.Lock()
    return TracingLock(name, reg)


def new_rlock(name: str) -> Any:
    reg = _active
    if reg is None:
        return threading.RLock()
    return TracingLock(name, reg, reentrant=True)


def _patch(obj: Any, attr: str, wrapper: Any) -> None:
    _patches.append((obj, attr, getattr(obj, attr)))
    setattr(obj, attr, wrapper)


def _install_blocking_probes(reg: LockRegistry) -> None:
    orig_get = queue.Queue.get

    def traced_get(self, block=True, timeout=None):
        if block and timeout is None:
            reg._blocking_call("queue.Queue.get(timeout=None)")
        return orig_get(self, block, timeout)

    _patch(queue.Queue, "get", traced_get)

    for meth in ("recv", "recv_into"):
        orig = getattr(socket.socket, meth)

        def traced_recv(self, *args, _orig=orig, _meth=meth, **kwargs):
            try:
                forever = self.gettimeout() is None
            except OSError:
                forever = False
            if forever:
                reg._blocking_call(f"socket.{_meth}(timeout=None)")
            return _orig(self, *args, **kwargs)

        _patch(socket.socket, meth, traced_recv)


def enable() -> LockRegistry:
    """Start tracing: subsequent :func:`new_lock`/:func:`new_rlock` calls
    hand out traced locks, and blocking-call probes go live."""
    global _active
    with _switch_mu:
        if _active is not None:
            raise RuntimeError("lock tracing already enabled")
        _active = reg = LockRegistry()
        _install_blocking_probes(reg)
        return reg


def disable() -> LockRegistry:
    """Stop tracing and return the registry (already-constructed traced
    locks keep recording into it — they just stop mattering once their
    components wind down)."""
    global _active
    with _switch_mu:
        if _active is None:
            raise RuntimeError("lock tracing is not enabled")
        reg, _active = _active, None
        while _patches:
            obj, attr, orig = _patches.pop()
            setattr(obj, attr, orig)
        return reg


class tracing:
    """``with locktrace.tracing() as reg: ...`` — scoped enable/disable."""

    def __enter__(self) -> LockRegistry:
        self._reg = enable()
        return self._reg

    def __exit__(self, *exc: Any) -> None:
        disable()
