"""Multi-host broker transport: partition logs served over sockets.

The paper's pipelines put the detector and the compute cluster on different
machines, joined by Kafka; its future-work item is to "augment the Kafka
Receiver with interfaces to other data sources, such as ZeroMQ". PR 1's
broker is purely in-process, so ingest and reconstruction had to share one
interpreter. This module crosses that boundary the way Alchemist crosses the
Spark↔MPI one — a socket-based data service:

- :class:`BrokerServer` owns a local :class:`~repro.core.broker.Broker` and
  serves its surface (``create_topic``/``produce``/``produce_many``/``read``/
  ``end_offset``/``commit``/…) over TCP or a Unix domain socket, one handler
  thread per client connection.
- :class:`RemoteBroker` is a client implementing the same duck type as
  :class:`~repro.core.broker.Broker`, so ``IngestRunner``,
  ``StreamingContext`` and ``TopicSource`` work across processes/hosts
  unchanged. It reconnects after a server restart and bounds its retries.

Wire format (``docs/transport.md`` has the full story): every message is one
*frame* — a fixed header ``magic(2B) | length(u32) | crc32(u32)`` followed by
``length`` payload bytes. A frame whose magic, length or checksum does not
hold is *rejected*, not guessed at: a torn or corrupt write kills that
connection and the client re-establishes and retries. Retries give
at-least-once delivery (a ``produce``/``produce_many`` whose ack was lost may
be re-sent — the *whole batch*, in the batched case); the data layer's
idempotent-by-key sinks restore exactly-once downstream, the same contract
the in-process path already has.

The frame payload itself carries a one-byte *message kind*:

- ``P`` — the message is a restricted-pickle blob (containers, scalars,
  broker record types; see :func:`register_safe`).
- ``A`` — an *array frame*: the message skeleton is still restricted pickle,
  but every contiguous ndarray's bytes travel as raw out-of-band buffers
  after the skeleton (pickle protocol 5 buffer references: the skeleton holds
  only dtype/shape/contiguity, the payload region holds the bytes). Arrays
  skip pickling entirely on encode — the buffers are sent straight from the
  array memory — and on decode they are reconstructed as views over the
  received frame buffer: zero copy on the detector/projection hot path.
- ``S`` — a *shared-memory frame*: the same skeleton + out-of-band buffer
  split as ``A``, but the buffer bytes live in a server-owned
  ``multiprocessing.shared_memory`` segment and only ``(offset, length)``
  descriptors cross the socket. Same-host only, negotiated per connection
  by a ``hello`` capability exchange (hostname + kernel boot id must match
  on both sides); requests fall back to ``A`` frames automatically when the
  negotiation fails, the :data:`USE_SHM_FRAMES` kill switch is off, or the
  server declines a segment lease. Segments are pooled per connection,
  ref-counted against the arrays decoded out of them, and unlinked by the
  *server* the moment the connection drops — a SIGKILLed producer strands
  nothing in ``/dev/shm``.

Delivery/ordering semantics match the in-process broker: per-partition total
order (one handler thread executes one client's requests in order; the log
append itself is locked), no order across partitions or across clients.
"""
from __future__ import annotations

import io
import itertools
import os
import pickle
import socket
import struct
import threading
import time
import weakref
import zlib
from multiprocessing import resource_tracker, shared_memory
from typing import Any, Callable

import numpy as np

from repro.core.broker import (  # noqa: F401
    Broker, BrokerFencedError, NotPrimaryError, OffsetRange, Record)
from repro.utils import get_logger

log = get_logger(__name__)

# -- framing -----------------------------------------------------------------

MAGIC = b"\xabK"                       # 2 bytes: frame sync marker
_HEADER = struct.Struct(">2sII")       # magic | payload length | crc32
MAX_FRAME_BYTES = 256 * 1024 * 1024    # reject absurd lengths before alloc

# Message kinds: first payload byte. P = restricted pickle; A = array frame
# (pickled skeleton + raw out-of-band ndarray buffers, layout below);
# S = shared-memory frame (buffers live in a shm segment, only descriptors
# cross the socket).
KIND_PICKLE = b"P"
KIND_ARRAY = b"A"
KIND_SHM = b"S"
# Array frame body, after the kind byte:
#   u32 skeleton_len | u32 nbufs | nbufs x u64 buf_len | skeleton | buf0 ...
_ARRAY_HEADER = struct.Struct(">II")
# Shared-memory frame body, after the kind byte:
#   u32 skeleton_len | u32 nbufs | u16 name_len | name |
#   nbufs x (u64 offset | u64 length) | skeleton
_SHM_HEADER = struct.Struct(">IIH")
_SHM_DESC = struct.Struct(">QQ")

# Flip to False to force every ndarray through the pickle path (the PR 2
# behavior) — benchmarks use this to price the array fast path.
USE_ARRAY_FRAMES = True

# Kill switch for the shared-memory fast path: False refuses it on both
# sides of the hello negotiation, so every frame degrades to 'A'/'P'.
USE_SHM_FRAMES = True

# Per-connection cap on pooled shm segment bytes; past it shm_alloc declines
# and the client falls back to 'A' frames (a safety valve, not an error).
SHM_POOL_MAX_BYTES = 256 * 1024 * 1024
_SHM_SEGMENT_MIN = 1 << 20             # round leases up so segments recycle
_SHM_PREFIX = "reproshm"               # /dev/shm names: leak tests grep this

# Address = ("host", port) for TCP, or "path.sock" for a Unix domain socket.
Address = "tuple[str, int] | str"


class TransportError(RuntimeError):
    """Client gave up: retries exhausted or the server returned a non-broker
    error."""


class FrameError(TransportError):
    """The byte stream is not a well-formed frame (bad magic, bad checksum,
    torn write, undecodable message). The connection carrying it must be
    dropped."""


_HAVE_SENDMSG = hasattr(socket.socket, "sendmsg")
_IOV_BATCH = 512                       # stay safely under IOV_MAX (1024)


def _sendmsg_all(sock: socket.socket, parts) -> None:
    """``sendall`` for a list of buffers via scatter-gather ``sendmsg`` (one
    syscall per ~512 buffers, resuming partial sends mid-buffer) — the parts
    are never concatenated, so nothing here is O(frame) beyond the kernel
    copy itself. Falls back to serial ``sendall`` without ``sendmsg``."""
    views = [(p if isinstance(p, memoryview) else memoryview(p)).cast("B")
             for p in parts]
    if not _HAVE_SENDMSG:               # pragma: no cover - non-POSIX
        for v in views:
            sock.sendall(v)
        return
    while views:
        sent = sock.sendmsg(views[:_IOV_BATCH])
        while views and sent >= views[0].nbytes:
            sent -= views[0].nbytes
            views.pop(0)
        if sent:                        # partial buffer: resume mid-view
            views[0] = views[0][sent:]


def send_frame(sock: socket.socket, payload) -> None:
    """Write one length-prefixed, checksummed frame of raw ``payload`` bytes."""
    if len(payload) > MAX_FRAME_BYTES:
        # fail fast on the sending side: the receiver would reject it anyway,
        # and a retry loop can never make an oversized payload fit
        raise FrameError(
            f"frame length {len(payload)} exceeds {MAX_FRAME_BYTES}")
    header = _HEADER.pack(MAGIC, len(payload), zlib.crc32(payload))
    # header and payload as two iovecs of one sendmsg — never `header +
    # payload`, which copied the whole payload (up to 256 MiB) to prepend
    # 10 bytes
    _sendmsg_all(sock, [header, payload])


def _recv_exact(sock: socket.socket, n: int, *, at_boundary: bool
                ) -> bytearray | None:
    """Read exactly ``n`` bytes into a fresh *writable* buffer. Clean EOF *at
    a frame boundary* returns ``None`` (peer closed between frames); EOF
    anywhere else is a torn frame. The buffer is writable so that arrays
    decoded zero-copy over it stay mutable downstream."""
    buf = bytearray(n)
    view = memoryview(buf)
    got = 0
    while got < n:
        r = sock.recv_into(view[got:], n - got)
        if r == 0:
            if at_boundary and got == 0:
                return None
            raise FrameError(
                f"torn frame: connection closed after {got}/{n} bytes")
        got += r
    return buf


def recv_frame(sock: socket.socket) -> bytearray | None:
    """Read one frame's payload; ``None`` on clean EOF. Raises
    :class:`FrameError` on torn writes, bad magic, oversized lengths, or
    checksum mismatch."""
    raw = _recv_exact(sock, _HEADER.size, at_boundary=True)
    if raw is None:
        return None
    magic, length, crc = _HEADER.unpack(raw)
    if magic != MAGIC:
        raise FrameError(f"bad magic {magic!r} (not a broker frame)")
    if length > MAX_FRAME_BYTES:
        raise FrameError(f"frame length {length} exceeds {MAX_FRAME_BYTES}")
    payload = _recv_exact(sock, length, at_boundary=False)
    if zlib.crc32(payload) != crc:
        raise FrameError("checksum mismatch (corrupt frame)")
    return payload


# Payloads arrive from the network, and pickle.loads on untrusted bytes is
# arbitrary code execution — the op allow-list below would never get a say.
# Unpickling therefore resolves globals only from this closed set: container
# builtins, the numpy array-reconstruction machinery, and the broker's own
# record types. Anything else (os.system, subprocess, custom classes) is
# refused before instantiation. Extend deliberately via register_safe().
_SAFE_GLOBALS: set[tuple[str, str]] = (
    {("builtins", n) for n in (
        "list", "dict", "tuple", "set", "frozenset", "bytes", "bytearray",
        "str", "int", "float", "complex", "bool", "slice", "range",
    )}
    | {(mod, name)
       for mod in ("numpy.core.multiarray", "numpy._core.multiarray")
       for name in ("_reconstruct", "scalar")}
    | {(mod, "_frombuffer")
       for mod in ("numpy.core.numeric", "numpy._core.numeric")}
    | {("numpy", "ndarray"), ("numpy", "dtype")}
    | {("repro.core.broker", "Record"), ("repro.core.broker", "OffsetRange")}
)


def register_safe(module: str, name: str) -> None:
    """Allow one more global through the transport's restricted unpickler
    (for pipelines whose record values are custom classes). Register on both
    sides of the socket."""
    _SAFE_GLOBALS.add((module, name))


class _RestrictedUnpickler(pickle.Unpickler):
    def find_class(self, module: str, name: str) -> Any:
        if (module, name) in _SAFE_GLOBALS:
            return super().find_class(module, name)
        raise FrameError(
            f"refusing to unpickle {module}.{name} from the wire "
            "(not in the transport allow-list; see register_safe)")


def _restricted_load(data, buffers=None) -> Any:
    return _RestrictedUnpickler(io.BytesIO(data), buffers=buffers).load()


# -- message layer: kind byte + optional raw array region --------------------

def _nbytes(part) -> int:
    return part.nbytes if isinstance(part, memoryview) else len(part)


def encode_message(obj: Any) -> list:
    """Encode one message into frame-payload *parts* (bytes/memoryviews whose
    concatenation is the payload). With :data:`USE_ARRAY_FRAMES`, contiguous
    ndarrays anywhere in ``obj`` are emitted as raw out-of-band buffers — the
    returned memoryviews alias the arrays, nothing is copied."""
    if not USE_ARRAY_FRAMES:
        return [KIND_PICKLE
                + pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)]
    bufs: list[memoryview] = []

    def keep_out_of_band(pb: pickle.PickleBuffer):
        try:
            m = pb.raw()               # flat byte view; raises if
        except BufferError:            # non-contiguous -> stay in-band
            return True
        bufs.append(m)
        return False

    skeleton = pickle.dumps(obj, protocol=5, buffer_callback=keep_out_of_band)
    if not bufs:
        return [KIND_PICKLE + skeleton]
    head = KIND_ARRAY + _ARRAY_HEADER.pack(len(skeleton), len(bufs)) \
        + struct.pack(f">{len(bufs)}Q", *(m.nbytes for m in bufs))
    return [head, skeleton, *bufs]


def decode_message(payload) -> Any:
    """Decode one frame payload (either message kind). Raises
    :class:`FrameError` for anything malformed — unknown kind, region lengths
    that do not add up, undecodable pickle — never returns garbage. Arrays in
    ``A`` messages are reconstructed as zero-copy views over ``payload``
    (pass a writable buffer, e.g. from :func:`recv_frame`, to keep them
    mutable). The flip side of zero copy: every such array keeps the *whole*
    frame buffer alive — consumers that cherry-pick one array out of a large
    multi-record frame and retain it long-term should ``np.copy()`` it."""
    view = memoryview(payload)
    if view.nbytes == 0:
        raise FrameError("empty message payload")
    kind, body = bytes(view[:1]), view[1:]
    try:
        if kind == KIND_PICKLE:
            return _restricted_load(body)
        if kind == KIND_ARRAY:
            if body.nbytes < _ARRAY_HEADER.size:
                raise FrameError("array message too short for its header")
            skeleton_len, nbufs = _ARRAY_HEADER.unpack_from(body, 0)
            lens_end = _ARRAY_HEADER.size + 8 * nbufs
            if lens_end > body.nbytes:
                raise FrameError("array message too short for buffer lengths")
            lens = struct.unpack_from(f">{nbufs}Q", body, _ARRAY_HEADER.size)
            if lens_end + skeleton_len + sum(lens) != body.nbytes:
                raise FrameError("array message region lengths do not add up")
            skeleton = body[lens_end:lens_end + skeleton_len]
            bufs, pos = [], lens_end + skeleton_len
            for n in lens:
                bufs.append(body[pos:pos + n])
                pos += n
            return _restricted_load(skeleton, bufs)
        raise FrameError(f"unknown message kind {kind!r}")
    except FrameError:
        raise
    except Exception as e:             # torn pickle, struct error, ...
        raise FrameError(f"undecodable {kind!r} message: {e}") from e


# -- shared-memory frames ('S'): same-host zero-copy bulk path ---------------

_host_token_cache: str | None = None


def _host_token() -> str:
    """This machine's identity for the same-host shm negotiation: hostname
    plus the kernel boot id, so two hosts sharing a hostname never falsely
    negotiate shared memory. (Containers sharing a kernel but not /dev/shm
    normally differ in hostname; :data:`USE_SHM_FRAMES` covers the rest.)"""
    global _host_token_cache
    if _host_token_cache is None:
        try:
            with open("/proc/sys/kernel/random/boot_id") as f:
                boot = f.read().strip()
        except OSError:                # pragma: no cover - non-Linux
            boot = "-"
        _host_token_cache = f"{socket.gethostname()}:{boot}"
    return _host_token_cache


def build_shm_payload(skeleton, bufs, name: str, seg: memoryview) -> bytes:
    """Copy out-of-band buffers into the shared-memory view ``seg`` (packed
    back to back from offset 0) and return the small ``S`` frame payload
    describing them. The caller leased ``seg`` via the ``shm_alloc`` op, so
    it is at least ``sum(nbytes)`` long."""
    name_b = name.encode("ascii")
    descs, pos = [], 0
    for b in bufs:
        m = (b if isinstance(b, memoryview) else memoryview(b)).cast("B")
        n = m.nbytes
        seg[pos:pos + n] = m
        descs.append(_SHM_DESC.pack(pos, n))
        pos += n
    return b"".join((KIND_SHM,
                     _SHM_HEADER.pack(len(skeleton), len(bufs), len(name_b)),
                     name_b, *descs, skeleton))


def decode_shm_payload(payload, resolve) -> tuple[Any, str]:
    """Decode one ``S`` frame payload. ``resolve(name)`` maps a segment name
    to its memoryview (``None`` for a segment this connection does not own —
    refused, like every other malformed descriptor, with
    :class:`FrameError`). Returns ``(message, segment_name)``; arrays are
    zero-copy views over the shared segment, so the segment must stay mapped
    for as long as they live (:class:`_ShmPool` ref-counts exactly that)."""
    view = memoryview(payload)
    body = view[1:]
    try:
        if body.nbytes < _SHM_HEADER.size:
            raise FrameError("shm message too short for its header")
        skeleton_len, nbufs, name_len = _SHM_HEADER.unpack_from(body, 0)
        pos = _SHM_HEADER.size
        descs_end = pos + name_len + _SHM_DESC.size * nbufs
        if descs_end + skeleton_len != body.nbytes:
            raise FrameError("shm message region lengths do not add up")
        name = bytes(body[pos:pos + name_len]).decode("ascii", "replace")
        pos += name_len
        seg = resolve(name)
        if seg is None:
            raise FrameError(f"shm message names unknown segment {name!r}")
        bufs = []
        for _ in range(nbufs):
            off, length = _SHM_DESC.unpack_from(body, pos)
            pos += _SHM_DESC.size
            if off + length > seg.nbytes:
                raise FrameError(
                    f"shm descriptor [{off}, {off + length}) outside its "
                    f"{seg.nbytes}-byte segment")
            bufs.append(seg[off:off + length])
        return _restricted_load(body[descs_end:], bufs), name
    except FrameError:
        raise
    except Exception as e:             # torn pickle, struct error, ...
        raise FrameError(f"undecodable {KIND_SHM!r} message: {e}") from e


_shm_seq = itertools.count()


class _ShmSegment:
    """One server-owned shared-memory segment plus its bookkeeping: a lease
    flag (handed to the client, no ``S`` frame seen yet), a refcount of live
    arrays decoded out of it, and the mapped address range the refcounter
    matches arrays against."""

    __slots__ = ("shm", "size", "addr", "refs", "leased", "unlinked")

    def __init__(self, size: int) -> None:
        name = f"{_SHM_PREFIX}_{os.getpid()}_{next(_shm_seq)}"
        self.shm = shared_memory.SharedMemory(name=name, create=True,
                                              size=size)
        self.size = self.shm.size
        probe = np.frombuffer(self.shm.buf, dtype=np.uint8)
        self.addr = probe.__array_interface__["data"][0]
        del probe                      # drop the buffer export before close
        self.refs = 0
        self.leased = False
        self.unlinked = False

    @property
    def name(self) -> str:
        return self.shm.name


def _abandon_shm(shm: shared_memory.SharedMemory) -> None:
    """A view over the mapping is still exported (e.g. interpreter shutdown
    runs ``weakref.finalize`` callbacks while the arrays are technically
    alive), so ``close()`` raises BufferError — and letting
    ``SharedMemory.__del__`` retry would spray "Exception ignored" noise.
    Abandon the mapping instead: drop our references, close the fd, and let
    the mmap die with its last view. The name is already unlinked, so the
    memory is reclaimed with the process either way."""
    shm._buf = None
    shm._mmap = None
    if shm._fd >= 0:                   # pragma: no branch
        os.close(shm._fd)
        shm._fd = -1


def _close_shm(shm: shared_memory.SharedMemory) -> None:
    try:
        shm.close()
    except BufferError:
        _abandon_shm(shm)


_tracker_patch_lock = threading.Lock()


def _attach_untracked(name: str) -> shared_memory.SharedMemory:
    """Attach a server-owned segment without registering it with this
    process's resource_tracker. Python 3.10 registers *attached* segments
    too (3.13 grew ``track=False``); if this process shares its tracker
    with the server — a ``multiprocessing`` child does — any register or
    unregister we issue unbalances the server's own create/unlink pair and
    the shared tracker dies with a KeyError traceback at unlink time. So
    suppress the registration at the source: swallow register calls for
    exactly this name while attaching (the name is unique to one lease, so
    nothing else can race into the filter)."""
    with _tracker_patch_lock:
        orig = resource_tracker.register

        def _skip(rname: str, rtype: str) -> None:
            if rtype == "shared_memory" and rname.lstrip("/") == name:
                return
            orig(rname, rtype)

        resource_tracker.register = _skip
        try:
            return shared_memory.SharedMemory(name=name)
        finally:
            resource_tracker.register = orig


def _close_segment(seg: _ShmSegment) -> None:
    _close_shm(seg.shm)


def _walk_arrays(obj, out: list) -> list:
    if isinstance(obj, np.ndarray):
        out.append(obj)
    elif isinstance(obj, (list, tuple, set, frozenset)):
        for x in obj:
            _walk_arrays(x, out)
    elif isinstance(obj, dict):
        for k, v in obj.items():
            _walk_arrays(k, out)
            _walk_arrays(v, out)
    return out


class _ShmPool:
    """Per-connection pool of server-owned shared-memory segments.

    A client leases a segment (``shm_alloc``), copies its array buffers in
    and sends an ``S`` frame naming it; the arrays decoded out of the frame
    are zero-copy views over the mapping, so the pool pins the segment with
    one refcount per such array (``weakref.finalize``) and only recycles it
    for a later lease once every view died. Ownership is strictly server
    side: when the connection drops — including a SIGKILLed producer — every
    segment is unlinked immediately (``release_all``), closing the mappings
    as their last views die, so nothing is ever stranded in ``/dev/shm``.
    """

    def __init__(self, max_bytes: int | None = None) -> None:
        self.max_bytes = (SHM_POOL_MAX_BYTES if max_bytes is None
                          else max_bytes)
        self._segments: dict[str, _ShmSegment] = {}
        self._lock = threading.Lock()

    def alloc(self, size) -> str | None:
        """Lease a segment of at least ``size`` bytes; ``None`` declines
        (over the pool cap, or shm unavailable) and the client falls back
        to an ``A`` frame."""
        size = int(size)
        if size <= 0 or size > self.max_bytes:
            return None
        with self._lock:
            free = [s for s in self._segments.values()
                    if not s.leased and not s.unlinked and s.refs == 0
                    and s.size >= size]
            if free:
                seg = min(free, key=lambda s: s.size)
            else:
                total = sum(s.size for s in self._segments.values())
                want = max(_SHM_SEGMENT_MIN, 1 << (size - 1).bit_length())
                if total + want > self.max_bytes:
                    return None
                try:
                    seg = _ShmSegment(want)
                except OSError:        # /dev/shm full or unavailable
                    return None
                self._segments[seg.name] = seg
            seg.leased = True
            return seg.name

    def resolve(self, name: str) -> memoryview | None:
        with self._lock:
            seg = self._segments.get(name)
            if seg is None or seg.unlinked:
                return None
            return memoryview(seg.shm.buf)

    def track(self, name: str, obj: Any) -> None:
        """End the lease opened by :meth:`alloc` and pin the segment for as
        long as any ndarray decoded out of it stays alive."""
        with self._lock:
            seg = self._segments.get(name)
            if seg is None:
                return
            seg.leased = False
            for arr in _walk_arrays(obj, []):
                addr = arr.__array_interface__["data"][0]
                if seg.addr <= addr < seg.addr + seg.size:
                    seg.refs += 1
                    weakref.finalize(arr, self._decref, name)

    def _decref(self, name: str) -> None:
        with self._lock:
            seg = self._segments.get(name)
            if seg is None:
                return
            seg.refs -= 1
            if seg.refs == 0 and seg.unlinked:
                self._segments.pop(name, None)
                _close_segment(seg)

    def segment_count(self) -> int:
        with self._lock:
            return len(self._segments)

    def release_all(self) -> None:
        """Connection dropped: unlink every segment *now* (nothing remains
        in ``/dev/shm``), close each mapping once its last view dies."""
        with self._lock:
            for seg in list(self._segments.values()):
                if not seg.unlinked:
                    seg.unlinked = True
                    try:
                        seg.shm.unlink()
                    except OSError:    # pragma: no cover - already gone
                        pass
                if seg.refs == 0:
                    self._segments.pop(seg.name, None)
                    _close_segment(seg)


def _message_checksum(parts) -> tuple[int, int]:
    total, crc = 0, 0
    for p in parts:
        total += _nbytes(p)
        crc = zlib.crc32(p, crc)
    return total, crc


def _send_parts(sock: socket.socket, parts, total: int, crc: int) -> None:
    """One frame from pre-encoded parts. The header and the two small lead
    parts coalesce into one buffer; everything else — the payload of a
    single-part frame, every array buffer — goes straight from its own
    memory via scatter-gather ``sendmsg``, no O(frame) concat anywhere."""
    header = _HEADER.pack(MAGIC, total, crc)
    if len(parts) == 1:
        _sendmsg_all(sock, [header, parts[0]])
        return
    _sendmsg_all(sock, [header + parts[0] + parts[1], *parts[2:]])


def send_message(sock: socket.socket, obj: Any) -> int:
    """Encode ``obj`` (array-aware) and send it as one frame. Returns the
    frame's payload size in bytes (what the byte counters account)."""
    parts = encode_message(obj)
    total, crc = _message_checksum(parts)
    if total > MAX_FRAME_BYTES:
        raise FrameError(f"message of {total} bytes exceeds the "
                         f"{MAX_FRAME_BYTES}-byte frame limit")
    _send_parts(sock, parts, total, crc)
    return total


def recv_message(sock: socket.socket) -> Any:
    """Receive and decode one message; ``None`` on clean EOF (broker
    messages are always tuples, so ``None`` is unambiguous)."""
    payload = recv_frame(sock)
    if payload is None:
        return None
    return decode_message(payload)


def _make_socket(address: Any) -> socket.socket:
    family = socket.AF_UNIX if isinstance(address, str) else socket.AF_INET
    return socket.socket(family, socket.SOCK_STREAM)


# -- server ------------------------------------------------------------------

# The server executes exactly these broker methods; anything else is an error
# frame, never an attribute lookup on the broker (no remote getattr).
# "ping", "stats", "hello" and "shm_alloc" are served by the transport
# itself, not the broker.
_OPS = frozenset({
    "create_topic", "topics", "num_partitions", "produce", "produce_many",
    "read", "end_offset", "end_offsets", "commit", "committed",
    "commit_groups", "lag", "ping", "stats", "hello", "shm_alloc",
    "topic_codec",
    # consumer-group protocol (repro.data.groups), hosted by the broker
    "join_group", "heartbeat", "sync_group", "leave_group", "describe_group",
    # replication/HA protocol (repro.data.replication): followers pull raw
    # record frames and report high-watermarks; clients fence/promote
    "fetch_frames", "replica_sync", "replica_hwm", "broker_epoch",
    "promote", "fence",
})


class BrokerServer:
    """Serve a local :class:`Broker` to remote clients over a socket.

    ``address`` is ``(host, port)`` for TCP (port 0 picks an ephemeral port;
    read the bound one back from ``.address``) or a filesystem path for a
    Unix domain socket. One thread accepts, one thread per connection
    handles request/response frames — a client's requests execute in order,
    which is what keeps per-partition ordering identical to in-process use.

    Requests are ``(op, args, kwargs)``; responses ``("ok", value)`` or
    ``("err", exc_type_name, message)``. Malformed frames are counted in
    ``frames_rejected`` and drop the offending connection only.
    """

    def __init__(self, broker: Broker, address: Any = ("127.0.0.1", 0),
                 accept_poll: float = 0.1) -> None:
        self.broker = broker
        self._requested = address
        self._accept_poll = accept_poll
        self._listener: socket.socket | None = None
        self._accept_thread: threading.Thread | None = None
        self._conns: list[socket.socket] = []
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self.address: Any = None       # bound address, set by start()
        self.requests_served = 0
        self.frames_rejected = 0
        self.shm_frames = 0            # 'S' frames decoded (all connections)
        self._shm_pools: list[_ShmPool] = []
        # registry instruments (constructor-time import: see Broker.__init__)
        from repro.data.metrics import get_registry
        reg = get_registry()
        self._m_requests = reg.counter(
            "transport_requests_total",
            "broker requests served over the socket transport")
        self._m_rejected = reg.counter(
            "transport_frames_rejected_total",
            "malformed/torn frames rejected (connection dropped)")
        self._m_bytes_in = reg.counter(
            "transport_bytes_received_total",
            "request frame payload bytes received")
        self._m_bytes_out = reg.counter(
            "transport_bytes_sent_total",
            "response frame payload bytes sent")
        self._m_shm_frames = reg.counter(
            "transport_shm_frames_total",
            "'S' frames decoded over server-owned shared-memory segments")
        reg.gauge("transport_connections", "live client connections",
                  callback=lambda: len(self._conns))
        reg.gauge("transport_shm_segments",
                  "pooled shared-memory segments across live connections",
                  callback=lambda: sum(p.segment_count()
                                       for p in list(self._shm_pools)))

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> "BrokerServer":
        listener = _make_socket(self._requested)
        if not isinstance(self._requested, str):
            listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        listener.bind(self._requested)
        listener.listen(32)
        listener.settimeout(self._accept_poll)
        self._listener = listener
        self.address = (self._requested if isinstance(self._requested, str)
                        else listener.getsockname())
        self._stop.clear()
        self._accept_thread = threading.Thread(
            target=self._accept_loop, daemon=True, name="broker-server")
        self._accept_thread.start()
        log.info("broker server listening on %s", self.address)
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._accept_thread is not None:
            self._accept_thread.join(timeout=10)
            self._accept_thread = None
        if self._listener is not None:
            self._listener.close()
            self._listener = None
        with self._lock:
            conns, self._conns = self._conns, []
        for conn in conns:
            try:
                conn.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            conn.close()

    def __enter__(self) -> "BrokerServer":
        return self.start() if self._listener is None else self

    def __exit__(self, *exc: Any) -> None:
        self.stop()

    # -- loops -------------------------------------------------------------
    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._listener.accept()
            except socket.timeout:
                continue
            except OSError:
                return                     # listener closed under us
            with self._lock:
                self._conns.append(conn)
            threading.Thread(target=self._serve_conn, args=(conn,),
                             daemon=True, name="broker-conn").start()

    def _serve_conn(self, conn: socket.socket) -> None:
        # Per-connection shm state: ``enabled`` is flipped by a successful
        # hello negotiation; the pool owns every segment this client leases.
        state = {"shm": False, "pool": _ShmPool()}
        with self._lock:
            self._shm_pools.append(state["pool"])
        try:
            while not self._stop.is_set():
                try:
                    payload = recv_frame(conn)
                except FrameError as e:
                    # Torn/corrupt input: reject the frame AND the stream —
                    # after a bad header there is no resync point.
                    with self._lock:
                        self.frames_rejected += 1
                    self._m_rejected.inc()
                    log.warning("rejecting connection: %s", e)
                    return
                if payload is None:
                    return                 # client closed cleanly
                self._m_bytes_in.inc(len(payload))
                try:
                    sent = send_message(conn, self._dispatch(payload, state))
                except FrameError:
                    # response too large for one frame: tell the client
                    # instead of dying silently (e.g. a read() of a huge
                    # offset range; the client should narrow it)
                    sent = send_message(conn, (
                        "err", "FrameError",
                        f"response exceeds the {MAX_FRAME_BYTES}-byte "
                        f"frame limit; narrow the request"))
                self._m_bytes_out.inc(sent)
        except OSError:
            pass                           # peer vanished mid-response
        finally:
            # unlink the connection's shm segments *before* anything else:
            # this is the no-stranded-/dev/shm guarantee for SIGKILLed and
            # vanished producers alike
            state["pool"].release_all()
            conn.close()
            with self._lock:
                if conn in self._conns:
                    self._conns.remove(conn)
                if state["pool"] in self._shm_pools:
                    self._shm_pools.remove(state["pool"])

    def _decode_request(self, payload, state) -> Any:
        if bytes(memoryview(payload)[:1]) == KIND_SHM:
            if not state["shm"]:
                raise FrameError(
                    "shm frame on a connection that did not negotiate it")
            msg, name = decode_shm_payload(payload, state["pool"].resolve)
            # the lease ends here; the segment stays pinned while any array
            # decoded out of it is alive
            state["pool"].track(name, msg)
            with self._lock:
                self.shm_frames += 1
            self._m_shm_frames.inc()
            return msg
        return decode_message(payload)

    def _hello(self, state, caps: dict) -> dict:
        """Capability negotiation: shm frames are offered only when both
        sides want them *and* the client proved it shares this host (same
        hostname + kernel boot id, so /dev/shm is the same filesystem)."""
        same_host = caps.get("host") == _host_token()
        state["shm"] = bool(USE_SHM_FRAMES and same_host
                            and caps.get("shm"))
        return {"shm": state["shm"], "host": _host_token(),
                "shm_max_bytes": state["pool"].max_bytes}

    def _dispatch(self, payload, state) -> tuple:
        try:
            op, args, kwargs = self._decode_request(payload, state)
            if op not in _OPS:
                raise ValueError(f"unknown op {op!r}")
            with self._lock:
                self.requests_served += 1
            self._m_requests.inc()
            if op == "ping":
                return ("ok", "pong")
            if op == "stats":
                return ("ok", self.stats())
            if op == "hello":
                return ("ok", self._hello(state, *args, **kwargs))
            if op == "shm_alloc":
                if not state["shm"]:
                    return ("ok", None)    # decline: client uses 'A' frames
                return ("ok", state["pool"].alloc(*args, **kwargs))
            return ("ok", getattr(self.broker, op)(*args, **kwargs))
        except Exception as e:             # broker errors travel as frames
            return ("err", type(e).__name__, str(e))

    def stats(self) -> dict:
        """The server's own transport counters — served over the wire as the
        ``stats`` op, so remote producers can see ``requests_served`` /
        ``frames_rejected`` instead of only local attribute reads."""
        with self._lock:
            return {"requests_served": self.requests_served,
                    "frames_rejected": self.frames_rejected,
                    "connections": len(self._conns),
                    "shm_frames": self.shm_frames,
                    "shm_segments": sum(p.segment_count()
                                        for p in self._shm_pools)}


def serve_broker(broker: Broker, address: Any = ("127.0.0.1", 0)
                 ) -> BrokerServer:
    """Start a :class:`BrokerServer`; returns it with ``.address`` bound."""
    return BrokerServer(broker, address).start()


# -- client ------------------------------------------------------------------

_ERR_TYPES: dict[str, Callable[[str], Exception]] = {
    "KeyError": KeyError, "ValueError": ValueError, "TypeError": TypeError,
    # HA fencing errors must survive the wire typed: FailoverBroker reacts
    # to them (fail over / re-point), unlike a generic TransportError
    "BrokerFencedError": BrokerFencedError,
    "NotPrimaryError": NotPrimaryError,
}


class RemoteBroker:
    """Client-side :class:`Broker` duck type backed by a :class:`BrokerServer`.

    Every broker call is one request/response frame exchange under a lock
    (callers on many threads serialize, preserving per-client order). On a
    connection failure — server restart, torn frame, refused connect — the
    client closes, waits ``retry_delay * 2**attempt`` and reconnects, up to
    ``max_retries`` times, then raises :class:`TransportError`. A retried
    ``produce``/``produce_many`` whose ack was lost may duplicate the record
    (or the whole batch): delivery is at-least-once, and exactly-once is
    restored by idempotent sinks (``docs/transport.md``).

    ``shm`` controls the same-host shared-memory fast path: ``None`` follows
    the module :data:`USE_SHM_FRAMES` kill switch, ``False`` opts this client
    out (benchmarks price the two paths against each other this way). When
    negotiated, array-bearing requests lease a server-owned segment per
    request, copy the buffers in, and send a small ``S`` descriptor frame
    instead of the bulk bytes; anything that fails along the way falls back
    to a plain ``A`` frame.
    """

    def __init__(self, address: Any, connect_timeout: float = 5.0,
                 max_retries: int = 5, retry_delay: float = 0.05,
                 shm: bool | None = None) -> None:
        self.address = address
        self.connect_timeout = connect_timeout
        self.max_retries = max_retries
        self.retry_delay = retry_delay
        self._shm_want = shm
        self._shm_ok = False
        self._attached: dict[str, shared_memory.SharedMemory] = {}
        self.shm_frames_sent = 0
        self._sock: socket.socket | None = None
        self._lock = threading.RLock()
        self.reconnects = 0
        # constructor-time import: repro.data.metrics must not be imported at
        # module scope here (repro.data.__init__ -> transport cycle)
        from repro.data.metrics import get_registry
        self._m_reconnects = get_registry().counter(
            "transport_reconnects_total",
            help="client reconnects after a dropped broker connection")

    # -- connection --------------------------------------------------------
    def _connect(self) -> None:
        sock = _make_socket(self.address)
        sock.settimeout(self.connect_timeout)
        try:
            sock.connect(self.address)
        except BaseException:
            sock.close()
            raise
        sock.settimeout(None)
        if isinstance(self.address, tuple):
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._sock = sock
        self._shm_ok = False
        want = USE_SHM_FRAMES if self._shm_want is None else self._shm_want
        if want:
            resp = self._roundtrip(
                ("hello", ({"host": _host_token(), "shm": True},), {}))
            if resp[0] == "ok":        # an "err" (old server) just means no shm
                self._shm_ok = bool(resp[1].get("shm"))

    def _roundtrip(self, msg) -> tuple:
        """One raw request/response exchange on the live socket — used
        inside :meth:`_connect`/:meth:`_request` where the usual retry
        machinery is already wrapped around the caller."""
        send_message(self._sock, msg)
        payload = recv_frame(self._sock)
        if payload is None:
            raise FrameError("server closed the connection")
        return decode_message(payload)

    def _detach_segments(self) -> None:
        for shm in self._attached.values():
            _close_shm(shm)
        self._attached.clear()

    def _attach_segment(self, name: str) -> shared_memory.SharedMemory:
        shm = self._attached.get(name)
        if shm is None:
            shm = _attach_untracked(name)
            self._attached[name] = shm
        return shm

    def _send_shm(self, parts) -> bool:
        """Try to send the encoded request as an ``S`` frame: lease a
        server-owned segment, copy the out-of-band buffers in, send the
        descriptor frame. ``False`` means the server declined the lease —
        the caller falls back to a plain ``A`` frame. Socket-level failures
        raise and land in the caller's retry loop."""
        bufs = parts[2:]
        need = sum(_nbytes(b) for b in bufs)
        if need == 0:
            return False
        resp = self._roundtrip(("shm_alloc", (need,), {}))
        if resp[0] != "ok" or not resp[1]:
            return False
        shm = self._attach_segment(resp[1])
        payload = build_shm_payload(parts[1], bufs, resp[1],
                                    memoryview(shm.buf))
        send_frame(self._sock, payload)
        self.shm_frames_sent += 1
        return True

    def _close(self) -> None:
        self._detach_segments()
        self._shm_ok = False
        if self._sock is not None:
            self._sock.close()
            self._sock = None

    def close(self) -> None:
        with self._lock:
            self._close()

    def __enter__(self) -> "RemoteBroker":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()

    # -- request/response --------------------------------------------------
    def _request(self, op: str, *args: Any, **kwargs: Any) -> Any:
        parts = encode_message((op, args, kwargs))
        total = sum(_nbytes(p) for p in parts)
        if total > MAX_FRAME_BYTES:
            # permanent protocol violation, not a connectivity problem:
            # no number of retries makes an oversized frame fit
            raise FrameError(
                f"{op} request of {total} bytes exceeds the "
                f"{MAX_FRAME_BYTES}-byte frame limit")
        # the frame CRC is an O(payload) pass over the bulk buffers — computed
        # lazily, only if the bytes actually go through the socket (the shm
        # path never frames them, and its small descriptor frame has its own)
        crc: int | None = None
        last: Exception | None = None
        with self._lock:
            for attempt in range(self.max_retries + 1):
                try:
                    if self._sock is None:
                        self._connect()
                        if attempt:
                            self.reconnects += 1
                            self._m_reconnects.inc()
                    # len(parts) >= 3 ⇔ the request carries out-of-band
                    # array buffers — the only frames worth a shm round trip
                    if not (self._shm_ok and len(parts) >= 3
                            and self._send_shm(parts)):
                        if crc is None:
                            crc = _message_checksum(parts)[1]
                        _send_parts(self._sock, parts, total, crc)
                    payload = recv_frame(self._sock)
                    if payload is None:
                        raise FrameError("server closed the connection")
                    resp = decode_message(payload)
                except (OSError, FrameError) as e:
                    last = e
                    self._close()
                    if attempt < self.max_retries:
                        time.sleep(self.retry_delay * (2 ** attempt))
                    continue
                if resp[0] == "ok":
                    return resp[1]
                _, exc_name, message = resp
                raise _ERR_TYPES.get(exc_name, TransportError)(message)
        raise TransportError(
            f"broker at {self.address!r} unreachable after "
            f"{self.max_retries + 1} attempts: {last}") from last

    # -- Broker surface ----------------------------------------------------
    def ping(self) -> bool:
        return self._request("ping") == "pong"

    def stats(self) -> dict:
        """Server-side transport counters (``requests_served``,
        ``frames_rejected``, ``connections``) fetched over the wire."""
        return self._request("stats")

    def create_topic(self, topic: str, partitions: int = 1,
                     codec: str | None = None) -> None:
        if codec is None:              # wire-compatible with older servers
            self._request("create_topic", topic, partitions)
        else:
            self._request("create_topic", topic, partitions, codec=codec)

    def topic_codec(self, topic: str) -> str | None:
        return self._request("topic_codec", topic)

    def topics(self) -> list[str]:
        return self._request("topics")

    def num_partitions(self, topic: str) -> int:
        return self._request("num_partitions", topic)

    def produce(self, topic: str, value: Any, key: bytes | None = None,
                partition: int | None = None, timestamp: float = 0.0) -> int:
        """Append one record; returns its partition-local offset.

        One request/response round trip per record — the per-record cost
        `bench_ingest` prices as ``ingest/remote_transport``. Hot paths
        should batch with :meth:`produce_many` instead (one frame per batch).
        Delivery is at-least-once: a retry whose ack was lost appends the
        record twice; idempotent-by-key sinks dedupe downstream.
        """
        return self._request("produce", topic, value, key=key,
                             partition=partition, timestamp=timestamp)

    def produce_many(self, topic: str, pairs, partition: int | None = None,
                     timestamp: float = 0.0) -> list[int]:
        """Append a batch of ``(key, value)`` pairs in one round trip;
        returns their offsets in input order.

        This is the transport fast path: the whole batch crosses the socket
        as one frame (an array frame when values hold ndarrays — detector
        frames skip pickle entirely), amortizing framing and latency across
        the batch. Semantics:

        - **Validation is all-or-nothing**: an unknown topic, bad partition
          or malformed pair fails the whole batch server-side with nothing
          appended.
        - **Delivery is at-least-once per batch**: if the ack is lost and the
          request retried, the *entire batch* may append twice. The sinks'
          idempotency-by-key still restores exactly-once downstream, exactly
          as for single ``produce`` retries.
        - Per-partition order within the batch follows pair order.
        """
        return self._request("produce_many", topic, list(pairs),
                             partition=partition, timestamp=timestamp)

    def read(self, rng: OffsetRange) -> list[Record]:
        return self._request("read", rng)

    def end_offset(self, topic: str, partition: int = 0) -> int:
        return self._request("end_offset", topic, partition)

    def end_offsets(self, topic: str) -> list[int]:
        return self._request("end_offsets", topic)

    def commit(self, topic: str, partition: int, offset: int,
               group: str = "", consumer: str | None = None,
               generation: int | None = None) -> None:
        self._request("commit", topic, partition, offset, group=group,
                      consumer=consumer, generation=generation)

    def committed(self, topic: str, group: str = "") -> list[int]:
        return self._request("committed", topic, group=group)

    def commit_groups(self, topic: str) -> list[str]:
        return self._request("commit_groups", topic)

    def lag(self, topic: str, group: str = "") -> int:
        return self._request("lag", topic, group=group)

    # -- consumer groups (repro.data.groups; errors arrive as GroupError /
    # StaleGenerationError — groups.py registers them in _ERR_TYPES) -------
    def join_group(self, group: str, consumer: str, topics,
                   session_timeout: float = 5.0) -> dict:
        return self._request("join_group", group, consumer, list(topics),
                             session_timeout=session_timeout)

    def heartbeat(self, group: str, consumer: str, generation: int) -> dict:
        return self._request("heartbeat", group, consumer, generation)

    def sync_group(self, group: str, consumer: str, generation: int) -> dict:
        return self._request("sync_group", group, consumer, generation)

    def leave_group(self, group: str, consumer: str) -> None:
        self._request("leave_group", group, consumer)

    def describe_group(self, group: str) -> dict:
        return self._request("describe_group", group)

    # -- replication / HA (repro.data.replication) -------------------------
    def fetch_frames(self, topic: str, partition: int, start: int,
                     max_bytes: int = 4 * 1024 * 1024) -> tuple:
        """Pull committed raw record frames for replication: returns
        ``(blob, lengths, next_offset, end_offset)`` — one contiguous blob
        of the durable log's on-disk CRC-framed bytes, shipped verbatim,
        plus each frame's size within it (docs/replication.md)."""
        return self._request("fetch_frames", topic, partition, start,
                             max_bytes=max_bytes)

    def replica_sync(self, replica_id: str, cursors: dict,
                     max_bytes: int = 4 * 1024 * 1024) -> dict:
        """One replication round in one round trip: report ``cursors`` as
        this replica's high-watermarks and pull every partition's tail past
        them (:meth:`repro.core.broker.Broker.replica_sync`)."""
        return self._request("replica_sync", replica_id, cursors,
                             max_bytes=max_bytes)

    def replica_hwm(self, replica_id: str | None = None,
                    hwms: dict | None = None) -> dict:
        """Report this replica's per-partition replicated high-watermarks
        (when ``replica_id``/``hwms`` given) and fetch the full map."""
        return self._request("replica_hwm", replica_id=replica_id, hwms=hwms)

    def broker_epoch(self) -> dict:
        return self._request("broker_epoch")

    def promote(self, epoch: int) -> dict:
        return self._request("promote", epoch)

    def fence(self, epoch: int) -> dict:
        return self._request("fence", epoch)


def parse_address(spec: str) -> Any:
    """CLI helper: ``"host:port"`` → TCP tuple, anything else → Unix path."""
    host, sep, port = spec.rpartition(":")
    if sep and port.isdigit():
        return (host or "127.0.0.1", int(port))
    return spec
