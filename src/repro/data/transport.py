"""Multi-host broker transport: partition logs served over sockets.

The paper's pipelines put the detector and the compute cluster on different
machines, joined by Kafka; its future-work item is to "augment the Kafka
Receiver with interfaces to other data sources, such as ZeroMQ". PR 1's
broker is purely in-process, so ingest and reconstruction had to share one
interpreter. This module crosses that boundary the way Alchemist crosses the
Spark↔MPI one — a socket-based data service:

- :class:`BrokerServer` owns a local :class:`~repro.core.broker.Broker` and
  serves its surface (``create_topic``/``produce``/``read``/``end_offset``/
  ``commit``/…) over TCP or a Unix domain socket, one handler thread per
  client connection.
- :class:`RemoteBroker` is a client implementing the same duck type as
  :class:`~repro.core.broker.Broker`, so ``IngestRunner``,
  ``StreamingContext`` and ``TopicSource`` work across processes/hosts
  unchanged. It reconnects after a server restart and bounds its retries.

Wire format (``docs/transport.md`` has the full story): every message is one
*frame* — a fixed header ``magic(2B) | length(u32) | crc32(u32)`` followed by
``length`` payload bytes (a pickled message). A frame whose magic, length or
checksum does not hold is *rejected*, not guessed at: a torn or corrupt write
kills that connection and the client re-establishes and retries. Retries give
at-least-once delivery (a ``produce`` whose ack was lost may be re-sent);
the data layer's idempotent-by-key sinks restore exactly-once downstream,
the same contract the in-process path already has.

Delivery/ordering semantics match the in-process broker: per-partition total
order (one handler thread executes one client's requests in order; the log
append itself is locked), no order across partitions or across clients.
"""
from __future__ import annotations

import io
import pickle
import socket
import struct
import threading
import time
import zlib
from typing import Any, Callable

from repro.core.broker import Broker, OffsetRange, Record  # noqa: F401
from repro.utils import get_logger

log = get_logger(__name__)

# -- framing -----------------------------------------------------------------

MAGIC = b"\xabK"                       # 2 bytes: frame sync marker
_HEADER = struct.Struct(">2sII")       # magic | payload length | crc32
MAX_FRAME_BYTES = 256 * 1024 * 1024    # reject absurd lengths before alloc

# Address = ("host", port) for TCP, or "path.sock" for a Unix domain socket.
Address = "tuple[str, int] | str"


class TransportError(RuntimeError):
    """Client gave up: retries exhausted or the server returned a non-broker
    error."""


class FrameError(TransportError):
    """The byte stream is not a well-formed frame (bad magic, bad checksum,
    torn write). The connection carrying it must be dropped."""


def send_frame(sock: socket.socket, payload: bytes) -> None:
    """Write one length-prefixed, checksummed frame."""
    if len(payload) > MAX_FRAME_BYTES:
        # fail fast on the sending side: the receiver would reject it anyway,
        # and a retry loop can never make an oversized payload fit
        raise FrameError(
            f"frame length {len(payload)} exceeds {MAX_FRAME_BYTES}")
    header = _HEADER.pack(MAGIC, len(payload), zlib.crc32(payload))
    sock.sendall(header + payload)


def _recv_exact(sock: socket.socket, n: int, *, at_boundary: bool) -> bytes | None:
    """Read exactly ``n`` bytes. Clean EOF *at a frame boundary* returns
    ``None`` (peer closed between frames); EOF anywhere else is a torn frame.
    """
    chunks: list[bytes] = []
    got = 0
    while got < n:
        chunk = sock.recv(min(n - got, 1 << 20))
        if not chunk:
            if at_boundary and got == 0:
                return None
            raise FrameError(
                f"torn frame: connection closed after {got}/{n} bytes")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def recv_frame(sock: socket.socket) -> bytes | None:
    """Read one frame; ``None`` on clean EOF. Raises :class:`FrameError` on
    torn writes, bad magic, oversized lengths, or checksum mismatch."""
    raw = _recv_exact(sock, _HEADER.size, at_boundary=True)
    if raw is None:
        return None
    magic, length, crc = _HEADER.unpack(raw)
    if magic != MAGIC:
        raise FrameError(f"bad magic {magic!r} (not a broker frame)")
    if length > MAX_FRAME_BYTES:
        raise FrameError(f"frame length {length} exceeds {MAX_FRAME_BYTES}")
    payload = _recv_exact(sock, length, at_boundary=False)
    if zlib.crc32(payload) != crc:
        raise FrameError("checksum mismatch (corrupt frame)")
    return payload


def _encode(obj: Any) -> bytes:
    return pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)


# Payloads arrive from the network, and pickle.loads on untrusted bytes is
# arbitrary code execution — the op allow-list below would never get a say.
# Unpickling therefore resolves globals only from this closed set: container
# builtins, the numpy array-reconstruction machinery, and the broker's own
# record types. Anything else (os.system, subprocess, custom classes) is
# refused before instantiation. Extend deliberately via register_safe().
_SAFE_GLOBALS: set[tuple[str, str]] = (
    {("builtins", n) for n in (
        "list", "dict", "tuple", "set", "frozenset", "bytes", "bytearray",
        "str", "int", "float", "complex", "bool", "slice", "range",
    )}
    | {(mod, name)
       for mod in ("numpy.core.multiarray", "numpy._core.multiarray")
       for name in ("_reconstruct", "scalar")}
    | {(mod, "_frombuffer")
       for mod in ("numpy.core.numeric", "numpy._core.numeric")}
    | {("numpy", "ndarray"), ("numpy", "dtype")}
    | {("repro.core.broker", "Record"), ("repro.core.broker", "OffsetRange")}
)


def register_safe(module: str, name: str) -> None:
    """Allow one more global through the transport's restricted unpickler
    (for pipelines whose record values are custom classes). Register on both
    sides of the socket."""
    _SAFE_GLOBALS.add((module, name))


class _RestrictedUnpickler(pickle.Unpickler):
    def find_class(self, module: str, name: str) -> Any:
        if (module, name) in _SAFE_GLOBALS:
            return super().find_class(module, name)
        raise FrameError(
            f"refusing to unpickle {module}.{name} from the wire "
            "(not in the transport allow-list; see register_safe)")


def _decode(payload: bytes) -> Any:
    return _RestrictedUnpickler(io.BytesIO(payload)).load()


def _make_socket(address: Any) -> socket.socket:
    family = socket.AF_UNIX if isinstance(address, str) else socket.AF_INET
    return socket.socket(family, socket.SOCK_STREAM)


# -- server ------------------------------------------------------------------

# The server executes exactly these broker methods; anything else is an error
# frame, never an attribute lookup on the broker (no remote getattr).
_OPS = frozenset({
    "create_topic", "topics", "num_partitions", "produce", "read",
    "end_offset", "end_offsets", "commit", "committed", "lag", "ping",
})


class BrokerServer:
    """Serve a local :class:`Broker` to remote clients over a socket.

    ``address`` is ``(host, port)`` for TCP (port 0 picks an ephemeral port;
    read the bound one back from ``.address``) or a filesystem path for a
    Unix domain socket. One thread accepts, one thread per connection
    handles request/response frames — a client's requests execute in order,
    which is what keeps per-partition ordering identical to in-process use.

    Requests are ``(op, args, kwargs)``; responses ``("ok", value)`` or
    ``("err", exc_type_name, message)``. Malformed frames are counted in
    ``frames_rejected`` and drop the offending connection only.
    """

    def __init__(self, broker: Broker, address: Any = ("127.0.0.1", 0),
                 accept_poll: float = 0.1) -> None:
        self.broker = broker
        self._requested = address
        self._accept_poll = accept_poll
        self._listener: socket.socket | None = None
        self._accept_thread: threading.Thread | None = None
        self._conns: list[socket.socket] = []
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self.address: Any = None       # bound address, set by start()
        self.requests_served = 0
        self.frames_rejected = 0

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> "BrokerServer":
        listener = _make_socket(self._requested)
        if not isinstance(self._requested, str):
            listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        listener.bind(self._requested)
        listener.listen(32)
        listener.settimeout(self._accept_poll)
        self._listener = listener
        self.address = (self._requested if isinstance(self._requested, str)
                        else listener.getsockname())
        self._stop.clear()
        self._accept_thread = threading.Thread(
            target=self._accept_loop, daemon=True, name="broker-server")
        self._accept_thread.start()
        log.info("broker server listening on %s", self.address)
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._accept_thread is not None:
            self._accept_thread.join(timeout=10)
            self._accept_thread = None
        if self._listener is not None:
            self._listener.close()
            self._listener = None
        with self._lock:
            conns, self._conns = self._conns, []
        for conn in conns:
            try:
                conn.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            conn.close()

    def __enter__(self) -> "BrokerServer":
        return self.start() if self._listener is None else self

    def __exit__(self, *exc: Any) -> None:
        self.stop()

    # -- loops -------------------------------------------------------------
    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._listener.accept()
            except socket.timeout:
                continue
            except OSError:
                return                     # listener closed under us
            with self._lock:
                self._conns.append(conn)
            threading.Thread(target=self._serve_conn, args=(conn,),
                             daemon=True, name="broker-conn").start()

    def _serve_conn(self, conn: socket.socket) -> None:
        try:
            while not self._stop.is_set():
                try:
                    payload = recv_frame(conn)
                except FrameError as e:
                    # Torn/corrupt input: reject the frame AND the stream —
                    # after a bad header there is no resync point.
                    with self._lock:
                        self.frames_rejected += 1
                    log.warning("rejecting connection: %s", e)
                    return
                if payload is None:
                    return                 # client closed cleanly
                send_frame(conn, _encode(self._dispatch(payload)))
        except OSError:
            pass                           # peer vanished mid-response
        finally:
            conn.close()
            with self._lock:
                if conn in self._conns:
                    self._conns.remove(conn)

    def _dispatch(self, payload: bytes) -> tuple:
        try:
            op, args, kwargs = _decode(payload)
            if op not in _OPS:
                raise ValueError(f"unknown op {op!r}")
            with self._lock:
                self.requests_served += 1
            if op == "ping":
                return ("ok", "pong")
            return ("ok", getattr(self.broker, op)(*args, **kwargs))
        except Exception as e:             # broker errors travel as frames
            return ("err", type(e).__name__, str(e))


def serve_broker(broker: Broker, address: Any = ("127.0.0.1", 0)
                 ) -> BrokerServer:
    """Start a :class:`BrokerServer`; returns it with ``.address`` bound."""
    return BrokerServer(broker, address).start()


# -- client ------------------------------------------------------------------

_ERR_TYPES: dict[str, Callable[[str], Exception]] = {
    "KeyError": KeyError, "ValueError": ValueError, "TypeError": TypeError,
}


class RemoteBroker:
    """Client-side :class:`Broker` duck type backed by a :class:`BrokerServer`.

    Every broker call is one request/response frame exchange under a lock
    (callers on many threads serialize, preserving per-client order). On a
    connection failure — server restart, torn frame, refused connect — the
    client closes, waits ``retry_delay * 2**attempt`` and reconnects, up to
    ``max_retries`` times, then raises :class:`TransportError`. A retried
    ``produce`` whose ack was lost may duplicate the record: delivery is
    at-least-once, and exactly-once is restored by idempotent sinks
    (``docs/transport.md``).
    """

    def __init__(self, address: Any, connect_timeout: float = 5.0,
                 max_retries: int = 5, retry_delay: float = 0.05) -> None:
        self.address = address
        self.connect_timeout = connect_timeout
        self.max_retries = max_retries
        self.retry_delay = retry_delay
        self._sock: socket.socket | None = None
        self._lock = threading.RLock()
        self.reconnects = 0

    # -- connection --------------------------------------------------------
    def _connect(self) -> None:
        sock = _make_socket(self.address)
        sock.settimeout(self.connect_timeout)
        try:
            sock.connect(self.address)
        except BaseException:
            sock.close()
            raise
        sock.settimeout(None)
        if isinstance(self.address, tuple):
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._sock = sock

    def _close(self) -> None:
        if self._sock is not None:
            self._sock.close()
            self._sock = None

    def close(self) -> None:
        with self._lock:
            self._close()

    def __enter__(self) -> "RemoteBroker":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()

    # -- request/response --------------------------------------------------
    def _request(self, op: str, *args: Any, **kwargs: Any) -> Any:
        request = _encode((op, args, kwargs))
        if len(request) > MAX_FRAME_BYTES:
            # permanent protocol violation, not a connectivity problem:
            # no number of retries makes an oversized frame fit
            raise FrameError(
                f"{op} request of {len(request)} bytes exceeds the "
                f"{MAX_FRAME_BYTES}-byte frame limit")
        last: Exception | None = None
        with self._lock:
            for attempt in range(self.max_retries + 1):
                try:
                    if self._sock is None:
                        self._connect()
                        if attempt:
                            self.reconnects += 1
                    send_frame(self._sock, request)
                    payload = recv_frame(self._sock)
                    if payload is None:
                        raise FrameError("server closed the connection")
                    resp = _decode(payload)
                except (OSError, FrameError) as e:
                    last = e
                    self._close()
                    if attempt < self.max_retries:
                        time.sleep(self.retry_delay * (2 ** attempt))
                    continue
                if resp[0] == "ok":
                    return resp[1]
                _, exc_name, message = resp
                raise _ERR_TYPES.get(exc_name, TransportError)(message)
        raise TransportError(
            f"broker at {self.address!r} unreachable after "
            f"{self.max_retries + 1} attempts: {last}") from last

    # -- Broker surface ----------------------------------------------------
    def ping(self) -> bool:
        return self._request("ping") == "pong"

    def create_topic(self, topic: str, partitions: int = 1) -> None:
        self._request("create_topic", topic, partitions)

    def topics(self) -> list[str]:
        return self._request("topics")

    def num_partitions(self, topic: str) -> int:
        return self._request("num_partitions", topic)

    def produce(self, topic: str, value: Any, key: bytes | None = None,
                partition: int | None = None, timestamp: float = 0.0) -> int:
        return self._request("produce", topic, value, key=key,
                             partition=partition, timestamp=timestamp)

    def read(self, rng: OffsetRange) -> list[Record]:
        return self._request("read", rng)

    def end_offset(self, topic: str, partition: int = 0) -> int:
        return self._request("end_offset", topic, partition)

    def end_offsets(self, topic: str) -> list[int]:
        return self._request("end_offsets", topic)

    def commit(self, topic: str, partition: int, offset: int) -> None:
        self._request("commit", topic, partition, offset)

    def committed(self, topic: str) -> list[int]:
        return self._request("committed", topic)

    def lag(self, topic: str) -> int:
        return self._request("lag", topic)


def parse_address(spec: str) -> Any:
    """CLI helper: ``"host:port"`` → TCP tuple, anything else → Unix path."""
    host, sep, port = spec.rpartition(":")
    if sep and port.isdigit():
        return (host or "127.0.0.1", int(port))
    return spec
