"""Parallel sink delivery: one worker lane per sink, with failure policies.

``fan_out`` writes every sink serially in the batch thread, so the slowest
sink sets the latency of the whole output stage — a 100x-slow artifact store
stalls the metrics path, and one raising sink aborts delivery for all of
them. This module is the DELTA generator/collector split applied to the
*output* side of the paper's Fig. 7: each sink gets its own worker thread
and bounded queue (a delivery *lane*), so the batch thread only pays an
enqueue, and failure is isolated to the lane it happened in.

Per-lane behavior is a :class:`SinkPolicy`:

=================  ==========================================================
policy             on terminal write failure (after ``retries`` attempts)
=================  ==========================================================
``skip_batch``     drop this batch for this sink, keep the lane running
``dead_letter``    produce the batch's items to a dead-letter topic on the
                   broker (key preserved; value wraps sink/batch/error), so
                   a dead-letter consumer can replay them later
``fail_pipeline``  flag the runtime; the next ``submit``/``check``/``close``
                   raises :class:`DeliveryFailed` and aborts the pipeline
=================  ==========================================================

Orthogonal knobs: ``retries`` (re-attempts before the terminal action, with
``retry_backoff`` between), ``timeout`` (per-batch write deadline, enforced
by running the sink on a lane-private executor thread — a hung sink wedges
only its own lane), and queue-full behavior (``on_full="block"`` applies
backpressure to the batch thread; ``"drop"`` sheds the oldest pressure by
refusing the new batch and counting it).

Delivery is asynchronous: a submitted batch is only guaranteed written after
``drain()`` or ``close(drain=True)``. Two contract consequences, priced in
deliberately:

* **Crash window.** The streaming layer commits offsets when the batch
  *processes*, before lanes write. A process that dies (or exits without
  ``close``) loses up to ``queue_depth`` queued batches per lane for that
  sink — wider than the serial path's single in-flight batch. Lanes trade
  the replay guarantee for isolation; sinks that cannot afford the window
  should stay serial (policy-less) or keep ``queue_depth`` small.
* **Timeout ambiguity.** A write abandoned at its deadline may still finish
  inside the sink; the retry (or the dead-letter record) then duplicates a
  batch that actually landed. That is at-least-once delivery under
  timeouts — the repo's idempotent-by-key sinks absorb the duplicates,
  exactly as they absorb replayed offsets; only non-idempotent sinks see
  double writes, and only when they blow their own deadline.

The serial ``fan_out`` path stays the degenerate case — a sink registered
without a policy is written inline by the batch thread exactly as before.

Wiring: :meth:`repro.core.dstream.StreamingContext.add_sink` and
:meth:`repro.core.pipeline.NearRealTimePipeline.add_sink` take an optional
``policy=``; with one, the sink is moved onto a lane of the context's
:class:`DeliveryRuntime`. Per-lane depth/latency/failure counters are in
:meth:`DeliveryRuntime.report`, alongside the batch-level numbers
:class:`~repro.data.sinks.MetricsSink` already aggregates.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

from repro.utils import get_logger

log = get_logger(__name__)

FAILURE_ACTIONS = ("skip_batch", "dead_letter", "fail_pipeline")
QUEUE_FULL = ("block", "drop")

_CLOSE = object()                     # lane shutdown sentinel


class DeliveryFailed(RuntimeError):
    """A lane with ``on_failure="fail_pipeline"`` exhausted its retries."""

    def __init__(self, lane: str, error: BaseException) -> None:
        super().__init__(f"sink lane {lane!r} failed pipeline: {error!r}")
        self.lane = lane
        self.error = error


class SinkTimeoutError(RuntimeError):
    """A sink write exceeded its policy timeout (or the sink is still stuck
    in a previous timed-out write — a *wedged* lane)."""


@dataclass(frozen=True)
class SinkPolicy:
    """Per-sink delivery policy. Build via the named constructors
    (:meth:`retry`, :meth:`skip_batch`, :meth:`dead_letter`,
    :meth:`fail_pipeline`) or directly."""

    retries: int = 0               # re-attempts before the failure action
    on_failure: str = "skip_batch"
    dead_letter_topic: str | None = None
    timeout: float | None = None   # per-batch write deadline, seconds
    queue_depth: int = 64          # bounded lane queue (batches)
    on_full: str = "block"         # block | drop when the queue is full
    retry_backoff: float = 0.0     # sleep between retry attempts

    def __post_init__(self) -> None:
        if self.on_failure not in FAILURE_ACTIONS:
            raise ValueError(
                f"on_failure {self.on_failure!r} not in {FAILURE_ACTIONS}")
        if self.on_failure == "dead_letter" and not self.dead_letter_topic:
            raise ValueError("dead_letter policy needs dead_letter_topic")
        if self.on_full not in QUEUE_FULL:
            raise ValueError(f"on_full {self.on_full!r} not in {QUEUE_FULL}")
        if self.retries < 0:
            raise ValueError("retries must be >= 0")
        if self.queue_depth < 1:
            raise ValueError("queue_depth must be >= 1")

    # -- named constructors (the policy names the docs/tests use) ----------
    @classmethod
    def retry(cls, n: int, then: str = "skip_batch", **kw: Any) -> "SinkPolicy":
        """Retry ``n`` times, then apply ``then`` (default: skip the batch)."""
        return cls(retries=n, on_failure=then, **kw)

    @classmethod
    def skip_batch(cls, **kw: Any) -> "SinkPolicy":
        return cls(on_failure="skip_batch", **kw)

    @classmethod
    def dead_letter(cls, topic: str, **kw: Any) -> "SinkPolicy":
        return cls(on_failure="dead_letter", dead_letter_topic=topic, **kw)

    @classmethod
    def fail_pipeline(cls, **kw: Any) -> "SinkPolicy":
        return cls(on_failure="fail_pipeline", **kw)


@dataclass
class LaneMetrics:
    """Per-lane counters surfaced by :meth:`DeliveryRuntime.report`."""
    name: str = ""
    enqueued: int = 0
    delivered: int = 0             # batches written successfully
    failed: int = 0                # batches that exhausted retries
    retries: int = 0               # individual re-attempts
    dropped_full: int = 0          # batches refused by on_full="drop"
    dead_lettered: int = 0         # batches routed to the dead-letter topic
    discarded: int = 0             # batches thrown away by close(drain=False)
    max_depth: int = 0             # high-water queue depth
    leaked_thread: bool = False    # a wedged sink outlived close()
    last_error: str | None = None
    latencies: list[float] = field(default_factory=list)   # submit -> done
    write_s: list[float] = field(default_factory=list)     # write call alone

    def as_dict(self) -> dict[str, Any]:
        out = {"name": self.name, "enqueued": self.enqueued,
               "delivered": self.delivered, "failed": self.failed,
               "retries": self.retries, "dropped_full": self.dropped_full,
               "dead_lettered": self.dead_lettered,
               "discarded": self.discarded, "max_depth": self.max_depth,
               "last_error": self.last_error}
        if self.latencies:
            out["mean_latency_s"] = sum(self.latencies) / len(self.latencies)
            out["max_latency_s"] = max(self.latencies)
        if self.write_s:
            out["mean_write_s"] = sum(self.write_s) / len(self.write_s)
        if self.leaked_thread:
            out["leaked_thread"] = True
        return out


class _TimedExecutor:
    """Lane-private thread that runs sink writes under a deadline.

    The lane worker hands each call over and waits ``timeout`` for its done
    event. A call that blows the deadline is abandoned (its event belongs to
    that call alone, so a late completion cannot be mistaken for a newer
    call's); while the sink is still stuck, subsequent calls fail fast as
    *wedged*. The thread is daemonic — a sink that never returns cannot keep
    the process alive, only its own lane broken.
    """

    def __init__(self, write: Callable[[Any], None], name: str) -> None:
        self._write = write
        self._calls: queue.Queue = queue.Queue()
        self._last: dict | None = None
        self.thread = threading.Thread(target=self._loop, daemon=True,
                                       name=f"{name}-exec")
        self.thread.start()

    def _loop(self) -> None:
        while True:
            item = self._calls.get()
            if item is _CLOSE:
                return
            call, payload = item
            try:
                self._write(payload)
            except BaseException as e:   # noqa: BLE001 - handed to the lane
                call["error"] = e
            call["done"].set()

    def submit(self, payload: Any, timeout: float) -> None:
        if self._last is not None and not self._last["done"].wait(timeout):
            raise SinkTimeoutError(
                "sink still executing a previous timed-out batch (wedged)")
        call = {"done": threading.Event(), "error": None}
        self._last = call
        self._calls.put((call, payload))
        if not call["done"].wait(timeout):
            raise SinkTimeoutError(f"sink write exceeded {timeout}s")
        if call["error"] is not None:
            raise call["error"]

    def close(self) -> bool:
        """Returns True if the executor thread exited (False = wedged)."""
        self._calls.put(_CLOSE)
        self.thread.join(timeout=0.5)
        return not self.thread.is_alive()


class SinkLane:
    """One sink's worker thread + bounded queue.

    ``write(payload)`` performs the sink write; ``items_of(payload)`` maps a
    payload back to keyed items for dead-lettering (may return ``[]``).
    """

    def __init__(self, name: str, write: Callable[[Any], None],
                 policy: SinkPolicy, runtime: "DeliveryRuntime",
                 items_of: Callable[[Any], list] | None = None,
                 index_of: Callable[[Any], int] | None = None,
                 sink_close: Callable[[], None] | None = None) -> None:
        self.name = name
        self.policy = policy
        self.metrics = LaneMetrics(name=name)
        self._write = write
        self._items_of = items_of or (lambda payload: [])
        self._index_of = index_of or (lambda payload: -1)
        self._sink_close = sink_close
        self._runtime = runtime
        self._queue: queue.Queue = queue.Queue(maxsize=policy.queue_depth)
        self._discard = False
        # constructor-time import (repro.data.__init__ import cycle); lane
        # names label the instruments, so every lane shows up in /metrics
        from repro.data.metrics import get_registry
        reg = get_registry()
        labels = {"lane": name}
        self._m_enqueued = reg.counter(
            "delivery_enqueued_total", help="batches accepted by the lane",
            labels=labels)
        self._m_delivered = reg.counter(
            "delivery_delivered_total", help="batches written successfully",
            labels=labels)
        self._m_failed = reg.counter(
            "delivery_failed_total", help="batches that exhausted retries",
            labels=labels)
        self._m_retries = reg.counter(
            "delivery_retries_total", help="individual write re-attempts",
            labels=labels)
        self._m_dropped = reg.counter(
            "delivery_dropped_full_total",
            help='batches refused by on_full="drop"', labels=labels)
        self._m_dead = reg.counter(
            "delivery_dead_lettered_total",
            help="batches routed to the dead-letter topic", labels=labels)
        self._m_write = reg.histogram(
            "delivery_write_seconds", help="sink write call duration",
            labels=labels)
        self._m_latency = reg.histogram(
            "delivery_latency_seconds", help="submit-to-written latency",
            labels=labels)
        reg.gauge("delivery_queue_depth", help="batches queued on the lane",
                  labels=labels, callback=self._queue.qsize)
        self._executor = (_TimedExecutor(write, name)
                          if policy.timeout is not None else None)
        self.thread = threading.Thread(target=self._run, daemon=True,
                                       name=f"sink-lane-{name}")
        self.thread.start()

    @property
    def depth(self) -> int:
        return self._queue.qsize()

    # -- producer side (batch thread) --------------------------------------
    def submit(self, payload: Any) -> bool:
        """Enqueue one batch; returns False if dropped (on_full="drop")."""
        item = (time.perf_counter(), payload)
        if self.policy.on_full == "drop":
            try:
                self._queue.put_nowait(item)
            except queue.Full:
                self.metrics.dropped_full += 1
                self._m_dropped.inc()
                return False
        else:
            # block in short slices, re-checking for a fail_pipeline verdict
            # from ANOTHER lane: a blocked enqueue must not outlive an
            # aborted pipeline
            while True:
                try:
                    self._queue.put(item, timeout=0.05)
                    break
                except queue.Full:
                    self._runtime.check()
        self.metrics.enqueued += 1
        self._m_enqueued.inc()
        self.metrics.max_depth = max(self.metrics.max_depth, self.depth)
        return True

    # -- worker side --------------------------------------------------------
    def _run(self) -> None:
        while True:
            item = self._queue.get()
            try:
                if item is _CLOSE:
                    return
                if self._discard:
                    self.metrics.discarded += 1
                    continue
                self._deliver(*item)
            finally:
                self._queue.task_done()

    def _write_once(self, payload: Any) -> None:
        t0 = time.perf_counter()
        try:
            if self._executor is not None:
                self._executor.submit(payload, self.policy.timeout)
            else:
                self._write(payload)
        finally:
            dt = time.perf_counter() - t0
            self.metrics.write_s.append(dt)
            self._m_write.observe(dt)

    def _deliver(self, enqueued_at: float, payload: Any) -> None:
        error: BaseException | None = None
        for attempt in range(self.policy.retries + 1):
            if attempt:
                self.metrics.retries += 1
                self._m_retries.inc()
                if self.policy.retry_backoff:
                    time.sleep(self.policy.retry_backoff)
            try:
                self._write_once(payload)
                self.metrics.delivered += 1
                self._m_delivered.inc()
                lat = time.perf_counter() - enqueued_at
                self.metrics.latencies.append(lat)
                self._m_latency.observe(lat)
                return
            except BaseException as e:   # noqa: BLE001 - policy decides
                error = e
        self.metrics.failed += 1
        self._m_failed.inc()
        self.metrics.last_error = repr(error)
        log.warning("sink lane %s: batch failed after %d attempt(s): %r",
                    self.name, self.policy.retries + 1, error)
        if self.policy.on_failure == "dead_letter":
            try:
                self._runtime._dead_letter(
                    self.name, self.policy.dead_letter_topic,
                    self._index_of(payload), self._items_of(payload), error)
                self.metrics.dead_lettered += 1
                self._m_dead.inc()
            except Exception as e:       # broker gone: isolate, don't crash
                log.error("sink lane %s: dead-letter write failed: %r",
                          self.name, e)
        elif self.policy.on_failure == "fail_pipeline":
            self._runtime._flag_failure(self.name, error)

    # -- shutdown -----------------------------------------------------------
    def close(self, drain: bool = True, timeout: float | None = None) -> None:
        if not self.thread.is_alive():
            return
        if not drain:
            self._discard = True
        # bounded enqueue of the sentinel: a wedged sink may never free
        # queue space, and close() must honor its timeout even then
        deadline = (time.monotonic() + timeout) if timeout is not None else None
        while True:
            try:
                self._queue.put_nowait(_CLOSE)
                break
            except queue.Full:
                # `is not None`: close(timeout=0) means "try once, abandon
                # immediately" — a falsy deadline must not disable the bound
                if deadline is not None and time.monotonic() > deadline:
                    self.metrics.leaked_thread = True
                    log.warning("sink lane %s: queue still full after %ss; "
                                "abandoning worker", self.name, timeout)
                    return
                time.sleep(0.002)
        self.thread.join(timeout=(max(0.0, deadline - time.monotonic())
                                  if deadline is not None else None))
        if self.thread.is_alive():
            self.metrics.leaked_thread = True
            log.warning("sink lane %s: worker did not exit in %ss",
                        self.name, timeout)
        if self._executor is not None and not self._executor.close():
            self.metrics.leaked_thread = True
        if self._sink_close is not None:
            try:
                self._sink_close()
            except Exception as e:
                log.warning("sink lane %s: close() raised %r", self.name, e)


class DeliveryRuntime:
    """Fans each batch out to per-sink lanes; owns failure isolation.

    ``submit(info)`` enqueues the batch on every lane and returns
    immediately (modulo ``on_full="block"`` backpressure). Keyed lanes
    receive the batch result normalized to ``(key, value)`` items (computed
    once per batch); batch lanes receive the :class:`BatchInfo` itself.
    """

    def __init__(self, broker: Any = None) -> None:
        self.broker = broker
        self._lanes: list[tuple[str, SinkLane]] = []   # (kind, lane)
        self._failure: DeliveryFailed | None = None
        from repro.data.locktrace import new_lock  # lock seam (chaos suites)
        self._failure_lock = new_lock("DeliveryRuntime._failure_lock")
        self._dl_lock = new_lock("DeliveryRuntime._dl_lock")

    @property
    def lanes(self) -> list[SinkLane]:
        return [lane for _, lane in self._lanes]

    def _require_broker(self, policy: SinkPolicy) -> None:
        if policy.on_failure == "dead_letter" and self.broker is None:
            raise ValueError(
                "dead_letter policy needs a broker on the DeliveryRuntime")

    def _lane_name(self, obj: Any, name: str | None) -> str:
        base = name or type(obj).__name__
        taken = {lane.name for _, lane in self._lanes}
        if base not in taken:
            return base
        i = 2
        while f"{base}-{i}" in taken:
            i += 1
        return f"{base}-{i}"

    def add_sink(self, sink: Any, policy: SinkPolicy,
                 name: str | None = None) -> SinkLane:
        """Keyed sink (``write_batch``): lane payload is ``(index, items)``."""
        self._require_broker(policy)
        lane = SinkLane(
            self._lane_name(sink, name),
            write=lambda payload: sink.write_batch(payload[1]),
            policy=policy, runtime=self,
            items_of=lambda payload: payload[1],
            index_of=lambda payload: payload[0],
            sink_close=getattr(sink, "close", None))
        self._lanes.append(("keyed", lane))
        return lane

    def add_batch_sink(self, fn: Callable[[Any], None], policy: SinkPolicy,
                       name: str | None = None,
                       sink_close: Callable[[], None] | None = None
                       ) -> SinkLane:
        """Batch-level sink (``fn(BatchInfo)``): lane payload is the info."""
        self._require_broker(policy)
        lane = SinkLane(
            self._lane_name(fn, name), write=fn, policy=policy, runtime=self,
            index_of=lambda info: getattr(info, "index", -1),
            sink_close=sink_close)
        self._lanes.append(("batch", lane))
        return lane

    # -- per-batch ----------------------------------------------------------
    def submit(self, info: Any, items: Sequence | None = None) -> None:
        """Fan one batch out to every lane. Raises :class:`DeliveryFailed`
        first if a fail_pipeline lane already gave up (so a blocked enqueue
        can never outlive an aborted pipeline)."""
        self.check()
        keyed = None
        for kind, lane in self._lanes:
            if kind == "keyed":
                if keyed is None:
                    if items is not None:
                        keyed = list(items)
                    else:
                        from repro.data.sinks import describe_result_items
                        keyed = describe_result_items(
                            getattr(info, "result", info),
                            getattr(info, "index", 0))
                lane.submit((getattr(info, "index", 0), keyed))
            else:
                lane.submit(info)

    def check(self) -> None:
        """Raise if a fail_pipeline lane has failed."""
        if self._failure is not None:
            raise self._failure

    def _flag_failure(self, lane: str, error: BaseException) -> None:
        with self._failure_lock:
            if self._failure is None:
                self._failure = DeliveryFailed(lane, error)

    def _dead_letter(self, lane: str, topic: str, index: int,
                     items: Sequence, error: BaseException | None) -> None:
        """Route a failed batch to the dead-letter topic: one record per
        item, key preserved, value wrapping enough to replay or debug."""
        with self._dl_lock:
            if topic not in self.broker.topics():
                try:
                    self.broker.create_topic(topic, 1)
                except ValueError:
                    pass               # another lane won the create race
        records = list(items) or [(f"{lane}-batch-{index:06d}", None)]
        for key, value in records:
            self.broker.produce(
                topic,
                {"sink": lane, "batch": index, "error": repr(error),
                 "value": value},
                key=key.encode() if isinstance(key, str) else key)

    # -- lifecycle ----------------------------------------------------------
    def drain(self, timeout: float | None = None) -> bool:
        """Block until every lane's queue is empty and its last write
        returned. Returns False on timeout."""
        deadline = (time.monotonic() + timeout) if timeout is not None else None
        for _, lane in self._lanes:
            while lane.depth > 0 or lane._queue.unfinished_tasks:
                if deadline is not None and time.monotonic() > deadline:
                    return False
                time.sleep(0.001)
        return True

    def close(self, drain: bool = True, timeout: float | None = 30.0) -> None:
        """Stop every lane (draining queued batches unless ``drain=False``),
        close the underlying sinks, and surface a pending fail_pipeline
        failure. Idempotent."""
        for _, lane in self._lanes:
            lane.close(drain=drain, timeout=timeout)
        self.check()

    def report(self) -> dict[str, dict[str, Any]]:
        """Per-lane depth/latency/failure counters, keyed by lane name —
        the sink-side siblings of ``MetricsSink.report()``."""
        out = {}
        for _, lane in self._lanes:
            d = lane.metrics.as_dict()
            d["depth"] = lane.depth
            out[lane.name] = d
        return out
