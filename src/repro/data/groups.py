"""Kafka-style consumer groups: coordinated partition ownership + failover.

Until this module exactly one :class:`~repro.core.dstream.StreamingContext`
owned every partition of a topic — the hard ceiling on consumer throughput
and a single point of failure (ROADMAP's top open item; the CFAA exemplar's
Kafka-group → streaming-consumers → dashboard topology is the target). This
module adds the group protocol on top of the broker's committed-offset
machinery:

- :class:`GroupCoordinator` — broker-hosted group state (``Broker
  .coordinator`` creates one lazily; the ``join_group`` / ``heartbeat`` /
  ``sync_group`` / ``leave_group`` broker methods delegate to it and are
  served over the socket transport). Membership is leased: a member that
  stops heartbeating past its ``session_timeout`` is evicted on the next
  coordinator call — liveness is driven by the *survivors'* heartbeats, no
  background thread. Every membership change recomputes the assignment and
  bumps the group *generation*; commits carrying a stale generation (or a
  partition the member does not own) are fenced with
  :class:`StaleGenerationError`, so a zombie consumer cannot corrupt the
  group's progress signal.
- :func:`sticky_assign` — the partition assignor: balanced within one
  partition, every partition owned exactly once, and *sticky* — when
  membership is unchanged the assignment is unchanged, and survivors keep
  their partitions across a rebalance (only the dead member's partitions
  move, which is what makes window-state handoff cheap).
- :class:`GroupMember` — the client half: join + sync, periodic heartbeats
  (``maintain()``, called by the streaming context at the top of each
  micro-batch), rejoin on eviction or generation change, with an
  ``on_rebalance`` callback for the owner to acquire/release partitions.
- :class:`GroupConsumer` — a group-mode streaming consumer with
  **per-partition window-state handoff**: each owned partition gets its own
  :class:`~repro.data.window.Windower` + :class:`~repro.data.state
  .DurableStateStore` + offset checkpoint under a shared filesystem root,
  so when a partition migrates (crash, leave, scale-out) the new owner
  restores the open window from the dead owner's last committed
  ``(offset, state ref)`` pair and *replays* it instead of losing it —
  the PR-5 both-or-neither argument, per partition instead of per process.

Convergence note: ``join_group`` bumps the generation only when the computed
assignment actually changes. A member re-joining after it noticed a new
generation therefore does *not* trigger another rebalance — the protocol
settles in one round instead of ping-ponging generations forever.

Fencing vs. handoff: the broker-side group commit is *advisory* (lag signal
+ zombie fencing); the per-partition checkpoint under the shared root is
*authoritative* for where a new owner resumes. A SIGKILLed owner's partition
replays from its last atomic (offset, ref) pair; outputs re-fired during the
replay carry the same window indices, so idempotent-by-key sinks absorb the
duplicates — exactly-once downstream, the same contract the single-consumer
pipeline has (see ``docs/consumer_groups.md`` for the crash-window table).
"""
from __future__ import annotations

import json
import os
import threading
import time
import uuid
from dataclasses import dataclass, field
from typing import Any, Callable, Mapping, Sequence

from repro.data import transport as _transport
from repro.utils import get_logger

log = get_logger(__name__)

DEFAULT_SESSION_TIMEOUT = 5.0


class GroupError(ValueError):
    """Consumer-group protocol violation: unknown group/member, evicted
    member, malformed join. Members react by re-joining."""


class StaleGenerationError(GroupError):
    """A commit or sync carried a superseded generation (or a partition the
    member no longer owns): the caller was rebalanced away and must rejoin
    before touching group state again — Kafka's generation fencing."""


class _FencedBatch(Exception):
    """Internal to :class:`GroupConsumer`: a range's fence failed mid-batch.
    The whole batch aborts so the streaming context does not advance its
    local cursor past records the windower never saw (for a partition the
    member *keeps* after the resync, an advanced cursor would silently drop
    those records from the window stream). ``step()`` swallows it."""


# Group errors cross the socket as ("err", type_name, message) frames; map
# the names back to the real types so RemoteBroker raises what Broker raises.
_transport._ERR_TYPES.setdefault("GroupError", GroupError)
_transport._ERR_TYPES.setdefault("StaleGenerationError", StaleGenerationError)


# -- assignor ----------------------------------------------------------------

def sticky_assign(num_partitions: int, consumers: Sequence[str],
                  prior: Mapping[str, Sequence[int]] | None = None
                  ) -> dict[str, list[int]]:
    """Assign ``num_partitions`` partitions across ``consumers``.

    Guarantees (the property suite in ``tests/test_groups.py`` pins them):

    - every partition in ``[0, num_partitions)`` is assigned exactly once;
    - load is balanced within one partition (max - min owned <= 1);
    - *sticky*: a consumer keeps its ``prior`` partitions wherever the
      balance targets allow, and an unchanged membership with a balanced
      prior reproduces the prior exactly.

    Deterministic: ties break on sorted consumer name, released/unowned
    partitions are filled lowest-index-first to the least-loaded consumer.
    """
    if num_partitions < 0:
        raise ValueError("num_partitions must be >= 0")
    members = sorted(set(consumers))
    if not members:
        return {}
    prior = prior or {}
    base, extra = divmod(num_partitions, len(members))
    owned: dict[str, list[int]] = {}
    seen: set[int] = set()
    for c in members:                    # keep prior claims, first-come by
        kept = []                        # sorted name, dropping out-of-range
        for p in sorted(set(prior.get(c, ()))):
            if 0 <= p < num_partitions and p not in seen:
                seen.add(p)
                kept.append(p)
        owned[c] = kept
    cap = base + (1 if extra else 0)
    for c in members:                    # nobody keeps more than the cap
        while len(owned[c]) > cap:
            seen.discard(owned[c].pop())
    if extra:                            # and only `extra` members sit at cap
        over = [c for c in members if len(owned[c]) > base]
        for c in over[extra:]:
            while len(owned[c]) > base:
                seen.discard(owned[c].pop())
    for p in range(num_partitions):      # fill the released/unclaimed rest
        if p not in seen:
            c = min(members, key=lambda m: (len(owned[m]), m))
            owned[c].append(p)
    return {c: sorted(ps) for c, ps in owned.items()}


# -- coordinator (broker side) -----------------------------------------------

@dataclass
class _Member:
    topics: tuple
    session_timeout: float
    deadline: float                      # clock reading past which = dead


@dataclass
class _Group:
    name: str
    generation: int = 0
    members: dict = field(default_factory=dict)       # consumer -> _Member
    assignments: dict = field(default_factory=dict)   # consumer -> {t: [p]}
    m_rebalances: Any = None
    m_evicted: Any = None


class GroupCoordinator:
    """Broker-hosted group membership, liveness and assignment.

    Thread-free by design: member expiry is evaluated lazily at the top of
    every coordinator call against the injected ``clock`` (``time.monotonic``
    by default; tests inject a fake clock and install the coordinator via
    ``broker._coordinator`` before the first group op). All methods are
    thread-safe; lock order is coordinator -> broker, never the reverse.
    """

    def __init__(self, broker: Any = None,
                 clock: Callable[[], float] | None = None) -> None:
        self.broker = broker
        self._clock = clock or time.monotonic
        # lock seam: traced under the chaos suites' lock-order harness.
        # Invariant the harness pins: coordinator -> broker, never reverse.
        from repro.data.locktrace import new_rlock
        self._lock = new_rlock("GroupCoordinator._lock")
        self._groups: dict[str, _Group] = {}
        self._lag_gauges: set[tuple[str, str]] = set()
        # constructor-time import: repro.data.metrics must not be imported at
        # module scope here (repro.data.__init__ import cycle)
        from repro.data.metrics import get_registry
        self._registry = get_registry()

    # -- group bookkeeping -------------------------------------------------
    def _group(self, name: str) -> _Group:
        g = self._groups.get(name)
        if g is None:
            g = self._groups[name] = _Group(name=name)
            reg = self._registry
            reg.gauge("group_members", "live members per consumer group",
                      labels={"group": name},
                      callback=lambda n=name: len(self._groups[n].members))
            reg.gauge("group_generation", "current group generation",
                      labels={"group": name},
                      callback=lambda n=name: self._groups[n].generation)
            g.m_rebalances = reg.counter(
                "group_rebalances_total",
                "generation bumps (assignment recomputed and changed)",
                labels={"group": name})
            g.m_evicted = reg.counter(
                "group_members_evicted_total",
                "members removed by heartbeat expiry", labels={"group": name})
        return g

    def _register_lag_gauge(self, group: str, topic: str) -> None:
        if self.broker is None or (group, topic) in self._lag_gauges:
            return
        self._lag_gauges.add((group, topic))
        self._registry.gauge(
            "group_lag", "produced-but-uncommitted records per group",
            labels={"group": group, "topic": topic},
            callback=lambda g=group, t=topic: self._safe_lag(t, g))

    def _safe_lag(self, topic: str, group: str) -> int:
        try:
            return self.broker.lag(topic, group=group)
        except Exception:                # topic gone / remote hiccup: a
            return 0                     # scrape must never raise

    def _num_partitions(self, topic: str) -> int | None:
        if self.broker is None:
            return None
        try:
            return self.broker.num_partitions(topic)
        except KeyError:
            return None

    def _rebalance(self, g: _Group) -> bool:
        """Recompute the full assignment; bump the generation only if it
        changed (re-joins by existing members converge instead of
        ping-ponging generations)."""
        topics = sorted({t for m in g.members.values() for t in m.topics})
        new: dict[str, dict[str, list[int]]] = {c: {} for c in g.members}
        for t in topics:
            subscribed = sorted(c for c, m in g.members.items()
                                if t in m.topics)
            n = self._num_partitions(t)
            if n is None:
                log.warning("group %r subscribes unknown topic %r; it gets "
                            "no partitions until it exists at a rebalance",
                            g.name, t)
                continue
            prior = {c: g.assignments.get(c, {}).get(t, [])
                     for c in subscribed}
            for c, parts in sticky_assign(n, subscribed, prior).items():
                if parts:
                    new[c][t] = parts
        if new == g.assignments:
            return False
        g.assignments = new
        g.generation += 1
        g.m_rebalances.inc()
        log.info("group %r generation %d: %s", g.name, g.generation,
                 {c: a for c, a in new.items()})
        # durable generation floor: on a broker with a commit topic this
        # event replicates to followers, so a promoted primary's coordinator
        # resumes generations *above* every pre-failover one — a zombie
        # consumer's stale-generation commit stays fenced across failover
        record = getattr(self.broker, "_record_group_event", None)
        if record is not None:
            record(("gen", g.name, g.generation))
        return True

    def seed_generation(self, group: str, generation: int) -> None:
        """Raise ``group``'s generation floor (promotion/restart path: the
        replayed commit log names the highest generation the old primary
        ever handed out; resuming below it would let zombie commits through
        the generation fence)."""
        with self._lock:
            g = self._group(group)
            g.generation = max(g.generation, int(generation))

    def _expire(self, g: _Group, now: float) -> None:
        dead = [c for c, m in g.members.items() if m.deadline <= now]
        for c in dead:
            del g.members[c]
            g.m_evicted.inc()
            log.warning("group %r: evicting %r (heartbeat expired)",
                        g.name, c)
        if dead:
            self._rebalance(g)

    def _live_member(self, group: str, consumer: str,
                     now: float) -> tuple[_Group, _Member]:
        g = self._groups.get(group)
        if g is None:
            raise GroupError(f"unknown group {group!r}")
        self._expire(g, now)
        m = g.members.get(consumer)
        if m is None:
            raise GroupError(
                f"consumer {consumer!r} is not a live member of group "
                f"{group!r} (evicted or never joined); rejoin")
        return g, m

    # -- protocol ----------------------------------------------------------
    def join_group(self, group: str, consumer: str, topics: Sequence[str],
                   session_timeout: float = DEFAULT_SESSION_TIMEOUT) -> dict:
        """Add/refresh a member; returns ``{"generation", "members"}``. The
        caller must follow with :meth:`sync_group` at that generation to
        learn its partitions (two-phase, like Kafka's JoinGroup/SyncGroup)."""
        if not consumer or not isinstance(consumer, str):
            raise GroupError("consumer id must be a non-empty string")
        if not (isinstance(session_timeout, (int, float))
                and session_timeout > 0):
            raise GroupError("session_timeout must be > 0")
        with self._lock:
            now = self._clock()
            g = self._group(group)
            self._expire(g, now)
            g.members[consumer] = _Member(
                topics=tuple(topics), session_timeout=float(session_timeout),
                deadline=now + float(session_timeout))
            self._rebalance(g)
            for t in topics:
                self._register_lag_gauge(group, t)
            return {"generation": g.generation, "members": sorted(g.members)}

    def heartbeat(self, group: str, consumer: str, generation: int) -> dict:
        """Renew the member's lease. ``rebalance`` in the response tells the
        member its generation is stale and it must rejoin + resync."""
        with self._lock:
            g, m = self._live_member(group, consumer, self._clock())
            m.deadline = self._clock() + m.session_timeout
            return {"generation": g.generation,
                    "rebalance": generation != g.generation}

    def sync_group(self, group: str, consumer: str,
                   generation: int) -> dict[str, list[int]]:
        """Fetch the member's assignment at ``generation``; fenced if the
        group moved on (the member rejoins and syncs at the new one)."""
        with self._lock:
            g, m = self._live_member(group, consumer, self._clock())
            if generation != g.generation:
                raise StaleGenerationError(
                    f"group {group!r} is at generation {g.generation}; "
                    f"{consumer!r} synced at {generation} — rejoin")
            m.deadline = self._clock() + m.session_timeout
            return {t: list(ps)
                    for t, ps in g.assignments.get(consumer, {}).items()}

    def leave_group(self, group: str, consumer: str) -> None:
        with self._lock:
            g = self._groups.get(group)
            if g is None:
                return
            self._expire(g, self._clock())
            if g.members.pop(consumer, None) is not None:
                log.info("group %r: %r left", group, consumer)
                self._rebalance(g)

    def check_commit(self, group: str, consumer: str | None, generation: int,
                     topic: str | None = None,
                     partition: int | None = None) -> None:
        """Fence a group offset commit: only a live member at the current
        generation that owns ``(topic, partition)`` may advance it. Raises
        :class:`StaleGenerationError` otherwise (``Broker.commit`` calls
        this for every generation-carrying commit)."""
        with self._lock:
            now = self._clock()
            g = self._groups.get(group)
            if g is None:
                raise GroupError(f"unknown group {group!r}")
            self._expire(g, now)
            if consumer not in g.members:
                raise StaleGenerationError(
                    f"commit fenced: {consumer!r} is not a live member of "
                    f"group {group!r}")
            if generation != g.generation:
                raise StaleGenerationError(
                    f"commit fenced: generation {generation} superseded by "
                    f"{g.generation} in group {group!r}")
            if topic is not None and partition is not None:
                parts = g.assignments.get(consumer, {}).get(topic, [])
                if partition not in parts:
                    raise StaleGenerationError(
                        f"commit fenced: {topic!r}[{partition}] is not "
                        f"assigned to {consumer!r} in group {group!r}")

    def describe(self, group: str) -> dict:
        """Group snapshot for tests/observability (also a broker op:
        ``describe_group`` over the transport)."""
        with self._lock:
            g = self._groups.get(group)
            if g is None:
                return {"group": group, "generation": 0, "members": {},
                        "assignments": {}}
            self._expire(g, self._clock())
            return {"group": group, "generation": g.generation,
                    "members": {c: {"topics": list(m.topics),
                                    "session_timeout": m.session_timeout}
                                for c, m in g.members.items()},
                    "assignments": {c: {t: list(ps) for t, ps in a.items()}
                                    for c, a in g.assignments.items()}}


# -- member (client side) ----------------------------------------------------

class GroupMember:
    """The client half of the protocol, driven by the owner's batch loop.

    ``maintain()`` is cheap when nothing is due (one clock read); on the
    heartbeat interval it renews the lease, and on eviction / generation
    change it re-joins and re-syncs, firing ``on_rebalance(old, new)`` with
    the before/after assignment whenever the owned partitions changed.
    """

    def __init__(self, broker: Any, group: str, consumer_id: str | None = None,
                 topics: Sequence[str] = (), *,
                 heartbeat_interval: float = 1.0,
                 session_timeout: float = DEFAULT_SESSION_TIMEOUT,
                 clock: Callable[[], float] | None = None,
                 on_rebalance: Callable[[dict, dict], None] | None = None
                 ) -> None:
        self.broker = broker
        self.group = group
        self.consumer_id = consumer_id or f"consumer-{uuid.uuid4().hex[:8]}"
        self.topics = list(topics)
        self.heartbeat_interval = heartbeat_interval
        self.session_timeout = session_timeout
        self.on_rebalance = on_rebalance
        self._clock = clock or time.monotonic
        self.generation = -1
        self.assignment: dict[str, list[int]] = {}
        self.rebalances = 0              # assignment changes seen
        self._last_hb = float("-inf")
        self._resync = False

    def join(self) -> bool:
        """Join + sync; returns True when the owned partitions changed
        (after firing ``on_rebalance``). Retries the sync when a concurrent
        join bumps the generation between our join and sync."""
        gen = self.broker.join_group(
            self.group, self.consumer_id, list(self.topics),
            session_timeout=self.session_timeout)["generation"]
        for _ in range(8):
            try:
                assignment = self.broker.sync_group(
                    self.group, self.consumer_id, gen)
                break
            except StaleGenerationError:
                gen = self.broker.join_group(
                    self.group, self.consumer_id, list(self.topics),
                    session_timeout=self.session_timeout)["generation"]
        else:
            raise GroupError(
                f"group {self.group!r} did not settle after 8 join/sync "
                "rounds (membership churning faster than we can sync)")
        self._last_hb = self._clock()
        self._resync = False
        self.generation = gen
        changed = assignment != self.assignment
        if changed:
            old, self.assignment = self.assignment, assignment
            self.rebalances += 1
            log.info("member %r generation %d owns %s", self.consumer_id,
                     gen, assignment)
            if self.on_rebalance is not None:
                self.on_rebalance(old, assignment)
        return changed

    def maintain(self, force: bool = False) -> bool:
        """Heartbeat/rejoin as due; returns True when ownership changed."""
        now = self._clock()
        if self._resync:
            return self.join()
        if not force and now - self._last_hb < self.heartbeat_interval:
            return False
        try:
            resp = self.broker.heartbeat(self.group, self.consumer_id,
                                         self.generation)
        except GroupError:               # evicted while away: start over
            return self.join()
        self._last_hb = now
        if resp["rebalance"]:
            return self.join()
        return False

    def request_resync(self) -> None:
        """Force a rejoin on the next :meth:`maintain` (called when a group
        commit came back fenced — the group moved on under us)."""
        self._resync = True

    def partitions(self, topic: str) -> list[int]:
        return list(self.assignment.get(topic, []))

    def leave(self) -> None:
        """Leave gracefully (immediate rebalance). Best-effort: if the
        broker is unreachable the coordinator evicts us by expiry anyway."""
        try:
            self.broker.leave_group(self.group, self.consumer_id)
        except Exception as e:           # noqa: BLE001 - teardown path
            log.warning("leave_group(%r, %r) failed (%s); coordinator will "
                        "evict on expiry", self.group, self.consumer_id, e)
        self.assignment = {}
        self.generation = -1


# -- group consumer: per-partition window-state handoff ----------------------

@dataclass
class _PartState:
    windower: Any
    store: Any
    offset: int                          # records consumed (authoritative)
    epoch: int                           # per-partition commit epoch
    path: str


class GroupConsumer:
    """A group-mode windowed consumer whose open windows survive handoff.

    Each owned partition keeps, under ``root/<topic>-p<N>/``, its own
    :class:`~repro.data.state.DurableStateStore` plus a ``ckpt.json`` naming
    the last committed ``(offset, state ref, epoch, generation)`` — written
    tmp + fsync + ``os.replace``, so the pair is atomic exactly like the
    PR-5 process checkpoint, but *per partition*: the unit of migration.
    On rebalance the member releases lost partitions and acquires gained
    ones by restoring the previous owner's pair, replaying the open window
    from the committed offset. Window outputs are re-fired with the same
    window indices on replay, so idempotent-by-key sinks keep end-to-end
    exactly-once across the handoff.

    ``window_fn(partition, records, window_info)`` is the user callback; it
    must be idempotent by ``(partition, window_info.index)`` — same
    discipline as every keyed sink in this repo.

    A *graceful* handoff (leave/scale-out) has no gap; a *crash* handoff
    replays at most the records between the dead owner's last per-partition
    commit and its death. The broker-side group commit runs *first* in each
    range — before the windower push and the state-log append — so a member
    the group has moved away from a partition is fenced *before* it can
    write into the new owner's state directory; a fenced range aborts the
    whole batch (the context must not advance past records the windower
    never saw — it may keep this very partition after the resync). For
    resume offsets the per-partition checkpoint stays authoritative (the
    broker commit is a lag signal + fence, never the replay source) — see
    the crash-window table in ``docs/consumer_groups.md``.
    """

    def __init__(self, broker: Any, group: str, topic: str, root: str, *,
                 window: Any, window_fn: Callable[[int, list, Any], Any],
                 consumer_id: str | None = None,
                 batch_interval: float = 0.02,
                 max_records_per_partition: int | None = None,
                 heartbeat_interval: float = 1.0,
                 session_timeout: float = DEFAULT_SESSION_TIMEOUT,
                 per_batch_sleep: float = 0.0,
                 store_factory: Callable[[str], Any] | None = None) -> None:
        # constructor-time imports: dstream/window are package siblings the
        # data __init__ may still be mid-import when this module loads
        from repro.core.dstream import StreamingContext
        from repro.core.rdd import Context

        self.broker = broker
        self.group = group
        self.topic = topic
        self.root = str(root)
        self.spec = window
        self.window_fn = window_fn
        self.per_batch_sleep = per_batch_sleep
        self._store_factory = store_factory or _durable_store
        self._parts: dict[int, _PartState] = {}
        os.makedirs(self.root, exist_ok=True)
        self.sc = StreamingContext(
            Context(), broker, batch_interval=batch_interval,
            max_records_per_partition=max_records_per_partition)
        self.sc.subscribe([topic])
        self.sc.foreach_batch(self._on_batch)
        self.sc.join_group(
            group, consumer_id=consumer_id,
            heartbeat_interval=heartbeat_interval,
            session_timeout=session_timeout,
            start_offset=self._start_offset,
            on_rebalance=self._on_rebalance)

    @property
    def member(self):
        """The live :class:`GroupMember` (``None`` once closed/abandoned).
        A property over the context's member because the initial rebalance
        callback runs *inside* the join, before ``__init__`` could bind it."""
        return self.sc.group_member

    # -- per-partition checkpoints -----------------------------------------
    def _part_dir(self, p: int) -> str:
        return os.path.join(self.root, f"{self.topic}-p{p}")

    def _read_ckpt(self, p: int) -> dict:
        try:
            with open(os.path.join(self._part_dir(p), "ckpt.json")) as f:
                return json.load(f)
        except (OSError, ValueError):
            return {}

    def _write_ckpt(self, p: int, st: _PartState, ref: int) -> None:
        path = os.path.join(st.path, "ckpt.json")
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"offset": st.offset, "ref": ref, "epoch": st.epoch,
                       "generation": self.member.generation,
                       "owner": self.member.consumer_id}, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)

    # -- acquire / release -------------------------------------------------
    def _acquire(self, p: int) -> _PartState:
        d = self._part_dir(p)
        os.makedirs(d, exist_ok=True)
        store = self._store_factory(os.path.join(d, "state"))
        ck = self._read_ckpt(p)
        windower = _make_windower(self.spec, self._emitter(p))
        state = store.restore(ck.get("ref"))
        if state is not None:
            windower.restore_state(state)
        st = _PartState(windower=windower, store=store,
                        offset=int(ck.get("offset", 0)),
                        epoch=int(ck.get("epoch", 0)), path=d)
        self._parts[p] = st
        log.info("%s acquired %s[%d] at offset %d (%d open-window records)",
                 self.member.consumer_id, self.topic, p, st.offset,
                 len(windower.state().buf))
        return st

    def _release(self, p: int) -> None:
        st = self._parts.pop(p, None)
        if st is not None:
            st.store.close()

    def _emitter(self, p: int) -> Callable:
        return lambda records, winfo: self.window_fn(p, records, winfo)

    def _on_rebalance(self, old: dict, new: dict) -> None:
        owned = set(new.get(self.topic, []))
        for p in sorted(set(self._parts) - owned):
            self._release(p)
        for p in sorted(owned):
            st = self._parts.get(p)
            if st is not None:
                # kept across the rebalance — but if we were evicted and the
                # partition ran under another owner meanwhile, our in-memory
                # state is stale: the on-disk pair is authoritative
                if int(self._read_ckpt(p).get("offset", 0)) != st.offset:
                    self._release(p)
                    self._acquire(p)
            else:
                self._acquire(p)

    def _start_offset(self, topic: str, partition: int) -> int | None:
        if topic != self.topic:
            return None
        st = self._parts.get(partition)
        if st is not None:
            return st.offset
        return int(self._read_ckpt(partition).get("offset", 0))

    # -- the batch function ------------------------------------------------
    def _on_batch(self, rdd: Any, info: Any) -> list:
        out = []
        member = self.member
        for rng in info.ranges:
            if rng.topic != self.topic:
                continue
            st = self._parts.get(rng.partition)
            if st is None:               # assignment raced the batch: late
                st = self._acquire(rng.partition)
            if rng.until <= st.offset:
                continue                 # replay of an already-committed range
            # Fence BEFORE touching the partition's shared durable state:
            # the generation-checked group commit rejects a member the group
            # rebalanced away from this partition, so a stale owner discards
            # its batch here instead of clobbering the new owner's state log
            # (two writers on one log: the zombie's compaction would
            # os.replace the file out from under the rightful owner).
            # A fenced range aborts the WHOLE batch (not just this range):
            # the context commits every range of a completed batch into its
            # local cursor, so skipping one quietly would advance past
            # records that never reached the windower — lost for good if the
            # resync hands this same partition back to us. Ranges already
            # processed above replay next batch and dedupe on st.offset.
            try:
                self.broker.commit(rng.topic, rng.partition, rng.until,
                                   group=self.group,
                                   consumer=member.consumer_id,
                                   generation=member.generation)
            except GroupError as e:
                member.request_resync()
                raise _FencedBatch(str(e)) from e
            records = [r.value for r in self.broker.read(rng)]
            skip = max(0, st.offset - rng.start)
            out.extend(st.windower.push(records[skip:], info))
            st.epoch += 1
            ref = st.store.commit(st.epoch, st.windower.state())
            st.offset = rng.until
            self._write_ckpt(rng.partition, st, ref)
        if self.per_batch_sleep:
            time.sleep(self.per_batch_sleep)
        return out

    # -- drive -------------------------------------------------------------
    def step(self):
        try:
            return self.sc.run_one_batch()
        except _FencedBatch as e:
            log.info("%s: batch fenced (%s); will resync",
                     getattr(self.member, "consumer_id", "<closed>"), e)
            return None

    def run_until(self, done: Callable[[], bool], idle_sleep: float = 0.005,
                  timeout: float | None = None) -> bool:
        """Run batches until ``done()``; False on timeout."""
        # `is not None`, not truthiness: timeout=0 means "deadline already
        # passed" (check once, give up immediately), never "wait forever"
        deadline = (time.monotonic() + timeout) if timeout is not None \
            else None
        while not done():
            if deadline is not None and time.monotonic() > deadline:
                return False
            if self.step() is None:
                time.sleep(idle_sleep)
        return True

    @property
    def partitions(self) -> list[int]:
        return sorted(self._parts)

    def abandon(self) -> None:
        """Simulate a crash for tests: drop all state without leaving — the
        coordinator must evict this member by heartbeat expiry."""
        for p in list(self._parts):
            self._release(p)
        self.sc.group_member = None      # close() must not leave gracefully
        self.sc.close(drain=False)

    def close(self) -> None:
        """Graceful exit: leave the group (immediate rebalance, no expiry
        wait), then release every partition's store — their last committed
        pairs stay on disk for the next owner."""
        self.sc.close()                  # leaves the group
        for p in list(self._parts):
            self._release(p)


def _durable_store(path: str):
    from repro.data.state import DurableStateStore
    return DurableStateStore(path)


def _make_windower(spec: Any, fn: Callable):
    from repro.data.window import Windower
    return Windower(spec, fn)
