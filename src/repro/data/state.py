"""Durable window state: ``Windower`` accumulation that survives a restart.

The paper's §III pipelines accumulate 512-frame acquisitions across
micro-batches before reconstruction. :mod:`repro.data.window` absorbs that
buffering into the platform — but until this module, the open window was the
one piece of consumer state that did *not* survive a restart: offsets were
checkpointed after every micro-batch while ``Windower._buf`` lived only in
memory, so a crash mid-window permanently lost every record already consumed
into the open window (the records were committed past, the buffer gone —
a silent break of the at-least-once contract the checkpoint layer provides).

This module closes that hole with a :class:`WindowStateStore` behind the
windower:

- :class:`InMemoryStateStore` — the degenerate path: same protocol, no I/O,
  no threads; a process death loses the open window exactly as before.
- :class:`DurableStateStore` — the open window spilled to disk using the
  durable log's CRC-frame machinery (``u32 len | u32 crc | payload`` frames,
  recovery scan truncating torn tails): a **snapshot** frame holds the full
  state, **delta** frames append only what one commit changed (records
  pushed at the tail, records evicted off the front, counters). Every
  ``snapshot_every`` deltas the log is compacted — rewritten through a temp
  file + ``os.replace`` as the last *committed* snapshot plus the new one,
  so the file stays bounded without ever holding fewer epochs than a crash
  could need.

Atomicity with the offset checkpoint is the point. Stores do not decide
what is committed — the :class:`~repro.core.dstream.StreamingContext` does:
each batch it first calls :meth:`WindowStateStore.commit` (durable write,
returns a *ref* = the epoch persisted), then publishes
``(offsets, epoch, window refs)`` in its checkpoint's single ``os.replace``.
A crash between the two leaves the old checkpoint pointing at the old ref;
:meth:`WindowStateStore.restore` replays state **up to the ref** and
truncates the uncommitted tail, so the interrupted batch — offsets *and*
window pushes — replays together: both-or-neither, by construction.

Time-kind caveat: ``Windower`` buckets records relative to its first batch's
clock reading (``_t0``). Restoring ``_t0`` across processes is only
meaningful when the stream clock is comparable across restarts (wall clock,
or an injected domain clock) — the default ``time.monotonic`` is not. Count
windows (the paper's "every 512 frames") restore exactly under any clock.
"""
from __future__ import annotations

import os
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Protocol, runtime_checkable

from repro.data.durable_log import (FSYNC_POLICIES, _REC_HEADER, frame_bytes,
                                    scan_frames)
from repro.data.transport import decode_message, encode_message
from repro.utils import get_logger

log = get_logger(__name__)

_SNAP, _DELTA = "snap", "delta"
_STATE_FILE = "state.log"


@dataclass
class WindowState:
    """A :class:`~repro.data.window.Windower`'s restartable state: the open
    window buffer — ``(value, ts, batch)`` triples — plus the counters that
    place it in the stream."""
    buf: list[tuple[Any, float, int]] = field(default_factory=list)
    evicted: int = 0                 # records dropped off the front
    t0: float | None = None          # stream epoch (time kind)
    windows_fired: int = 0

    @property
    def total_seen(self) -> int:
        """Records ever pushed = evicted + still buffered (monotonic)."""
        return self.evicted + len(self.buf)

    def copy(self) -> "WindowState":
        return WindowState(list(self.buf), self.evicted, self.t0,
                           self.windows_fired)


@runtime_checkable
class WindowStateStore(Protocol):
    """Persistence behind a windower. ``commit(epoch, state)`` durably
    records ``state`` and returns the *ref* to put in the offset checkpoint
    (the epoch persisted; an unchanged state may return the previous ref).
    ``restore(ref)`` returns the state committed at ``ref`` — discarding
    anything newer, which a crash left uncommitted — or ``None`` for an
    unknown/empty ref (fresh start)."""

    def commit(self, epoch: int, state: WindowState) -> int: ...

    def restore(self, ref: int | None) -> WindowState | None: ...

    def close(self) -> None: ...


class InMemoryStateStore:
    """Degenerate :class:`WindowStateStore`: holds the last committed state
    in memory. Same protocol, zero I/O, thread-free — the pre-existing
    behavior (a process death loses the open window), but round-trippable
    in-process for tests and as the baseline the durable store's overhead
    is measured against (``ingest/window_restore``)."""

    def __init__(self) -> None:
        self._ref: int | None = None
        self._state: WindowState | None = None
        self.commits = 0

    def commit(self, epoch: int, state: WindowState) -> int:
        self._state = state.copy()
        self._ref = epoch
        self.commits += 1
        return epoch

    def restore(self, ref: int | None) -> WindowState | None:
        if ref is None or ref != self._ref or self._state is None:
            return None
        return self._state.copy()

    def close(self) -> None:
        pass


def _encode_entry(kind: str, epoch: int, body: Any) -> bytes:
    return frame_bytes(b"".join(encode_message((kind, epoch, body))))


class DurableStateStore:
    """File-backed :class:`WindowStateStore` under ``path`` (a directory).

    One append-only ``state.log`` of CRC frames (the durable log's segment
    record format). Frame payloads are transport messages — ndarray window
    contents ride the zero-copy array encoding, and reads go through the
    restricted unpickler. Two entry kinds, epochs strictly increasing:

    - ``snap``  — full :class:`WindowState`,
    - ``delta`` — one commit's change against the previous: ``(dropped,
      tail, windows_fired, t0)``, replayed as ``buf = buf[dropped:] + tail``
      (evictions are always a prefix drop: the buffer is ts-ordered).

    On open, a recovery scan truncates any torn/corrupt tail (a crash
    mid-write costs at most the frame being written). :meth:`restore`
    additionally truncates frames *beyond the committed ref* — state the
    offset checkpoint never published. Compaction (every ``snapshot_every``
    deltas, and whenever a delta cannot express the change) rewrites the log
    as ``[snap(last committed ref), snap(new epoch)]`` via temp file +
    fsync + ``os.replace``: crash-safe on both sides of the caller's
    checkpoint write, and the file stays O(window), not O(stream).

    ``fsync`` policy is the durable log's: ``"always"`` / ``"interval"``
    (default) / ``"never"``. Like the durable log, a *process* crash loses
    nothing under any policy (writes are unbuffered); a *power loss* can
    lose frames the policy had not yet fsynced — and since the offset
    checkpoint always fsyncs, that is the one case where offsets can land
    ahead of window state. ``restore`` detects it (the checkpoint's ref has
    no frame) and warns; ``fsync="always"`` closes it. A state larger than
    the transport frame cap (~256 MiB serialized) is refused at commit with
    ``ValueError`` — the recovery scan would destroy it as corruption on
    the next open.
    """

    def __init__(self, path: str, snapshot_every: int = 16,
                 fsync: str = "interval", fsync_interval: float = 0.05
                 ) -> None:
        if fsync not in FSYNC_POLICIES:
            raise ValueError(f"fsync {fsync!r} not in {FSYNC_POLICIES}")
        if snapshot_every < 1:
            raise ValueError("snapshot_every must be >= 1")
        self.path = str(path)
        self.snapshot_every = snapshot_every
        self.fsync = fsync
        self.fsync_interval = fsync_interval
        from repro.data.locktrace import new_lock  # lock seam (chaos suites)
        self._lock = new_lock("DurableStateStore._lock")
        self._last_fsync = 0.0
        self._writer: Any = None
        # last committed (ref, state): the delta base, and what compaction
        # must keep restorable while the new epoch's checkpoint is in flight
        self._prev: tuple[int, WindowState] | None = None
        self._deltas_since_snap = 0
        self.snapshots = 0               # compactions (snapshot rewrites)
        self.deltas = 0                  # delta frames written
        self.recovered_frames = 0        # valid frames found on open
        self.truncated_bytes = 0         # torn/corrupt tail cut on open
        # constructor-time import (repro.data.__init__ import cycle);
        # unlabeled on purpose: the store path is a tmpdir in tests and
        # would explode label cardinality, so stores aggregate per process
        from repro.data.metrics import get_registry
        reg = get_registry()
        self._m_commits = reg.counter(
            "state_commits_total", help="window-state commits persisted")
        self._m_deltas = reg.counter(
            "state_delta_frames_total", help="delta frames appended")
        self._m_snapshots = reg.counter(
            "state_snapshots_total", help="snapshot compaction rewrites")
        self._m_restores = reg.counter(
            "state_restores_total", help="restore() replays")
        self._m_commit_s = reg.histogram(
            "state_commit_seconds", help="durable commit latency")
        self._m_restore_s = reg.histogram(
            "state_restore_seconds", help="restore replay latency")
        reg.gauge("state_log_bytes", help="window-state log size on disk",
                  callback=lambda: os.path.getsize(self._file))
        os.makedirs(self.path, exist_ok=True)
        self._file = os.path.join(self.path, _STATE_FILE)
        if os.path.exists(self._file):
            frames, valid_end = scan_frames(self._file)
            size = os.path.getsize(self._file)
            if valid_end < size:
                self.truncated_bytes = size - valid_end
                with open(self._file, "ab") as f:
                    f.truncate(valid_end)
                log.warning("window state %s: truncated %d torn/corrupt "
                            "tail bytes", self._file, self.truncated_bytes)
            self.recovered_frames = len(frames)
        self._open_writer()

    # -- file plumbing -----------------------------------------------------
    def _open_writer(self) -> None:
        if self._writer is not None:
            self._writer.close()
        # unbuffered: a killed process loses at most the frame being written
        self._writer = open(self._file, "ab", buffering=0)

    def _maybe_fsync(self) -> None:
        if self.fsync == "never":
            return
        now = time.monotonic()
        if self.fsync == "always" or \
                now - self._last_fsync >= self.fsync_interval:
            os.fsync(self._writer.fileno())
            self._last_fsync = now

    def _entries(self):
        """Decode every valid frame: ``[(end_pos, kind, epoch, body), ...]``.
        ``end_pos`` is the byte just past the frame — the truncation point
        that keeps everything up to and including it."""
        frames, _ = scan_frames(self._file)
        out = []
        with open(self._file, "rb") as f:
            for pos, length in frames:
                f.seek(pos + _REC_HEADER.size)
                payload = bytearray(length)
                f.readinto(payload)
                kind, epoch, body = decode_message(payload)
                out.append((pos + _REC_HEADER.size + length, kind, epoch,
                            body))
        return out

    # -- protocol ----------------------------------------------------------
    def commit(self, epoch: int, state: WindowState) -> int:
        t0 = time.perf_counter()
        with self._lock:
            delta = self._delta_against_prev(epoch, state)
            if delta == ():              # unchanged: keep the previous ref
                return self._prev[0]
            if delta is not None and \
                    self._deltas_since_snap < self.snapshot_every:
                self._writer.write(_encode_entry(_DELTA, epoch, delta))
                self._maybe_fsync()
                self._deltas_since_snap += 1
                self.deltas += 1
                self._m_deltas.inc()
            else:
                self._compact(epoch, state)
            self._prev = (epoch, state.copy())
            self._m_commits.inc()
            self._m_commit_s.observe(time.perf_counter() - t0)
            return epoch

    def restore(self, ref: int | None) -> WindowState | None:
        """Fold the log up to ``ref`` and truncate everything newer (written
        but never published by the offset checkpoint — the crash window this
        store exists to close). ``ref=None`` (no/fresh checkpoint) resets the
        log entirely."""
        # not t0: the replay loop below unpacks window-state t0 over it
        t_start = time.perf_counter()
        with self._lock:
            state: WindowState | None = None
            last: tuple[int, int] | None = None      # (end_pos, epoch)
            deltas_since = 0
            entries = self._entries()
            if ref is not None and not any(e == ref for _, _, e, _ in entries):
                # the checkpoint only ever names an epoch this store wrote,
                # so a missing ref frame means the frame never reached disk
                # (power loss outran the fsync policy) or the wrong state
                # directory — surface it instead of degrading silently
                log.warning(
                    "window state %s has no frame for checkpoint ref %s "
                    "(newest on disk: %s): restoring the newest earlier "
                    "state; records consumed after it may be lost from the "
                    "open window. fsync='always' closes this power-loss "
                    "window.", self._file, ref,
                    max((e for _, _, e, _ in entries), default=None))
            for end, kind, epoch, body in entries:
                if ref is None or epoch > ref:
                    break
                if kind == _SNAP:
                    buf, evicted, t0, wf = body
                    state = WindowState(list(buf), evicted, t0, wf)
                    deltas_since = 0
                elif kind == _DELTA and state is not None:
                    dropped, tail, wf, t0 = body
                    state.buf = state.buf[dropped:] + list(tail)
                    state.evicted += dropped
                    state.windows_fired, state.t0 = wf, t0
                    deltas_since += 1
                else:                    # delta with no base snapshot
                    log.warning("window state %s: delta at epoch %d has no "
                                "base snapshot; ignored", self._file, epoch)
                last = (end, epoch)
            good = last is not None and state is not None
            keep = last[0] if good else 0
            if keep < os.path.getsize(self._file):
                with open(self._file, "ab") as f:
                    f.truncate(keep)
                self._open_writer()
            self._deltas_since_snap = deltas_since if good else 0
            self._prev = (last[1], state.copy()) if good else None
            self._m_restores.inc()
            self._m_restore_s.observe(time.perf_counter() - t_start)
            return state.copy() if good else None

    def close(self) -> None:
        with self._lock:
            if self._writer is not None:
                if self.fsync != "never":
                    os.fsync(self._writer.fileno())
                self._writer.close()
                self._writer = None

    def __enter__(self) -> "DurableStateStore":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()

    # -- delta / compaction ------------------------------------------------
    def _delta_against_prev(self, epoch: int, state: WindowState):
        """The change one commit made, or ``None`` when a delta cannot
        express it (first commit, or counters moved backwards — a caller-side
        rollback/restore we must not extrapolate across), or ``()`` when
        nothing changed at all."""
        if self._prev is None:
            return None
        pref, prev = self._prev
        appended = state.total_seen - prev.total_seen
        dropped = state.evicted - prev.evicted
        if (appended < 0 or dropped < 0 or epoch <= pref
                or state.windows_fired < prev.windows_fired
                or len(prev.buf) - dropped + appended != len(state.buf)):
            return None
        if appended == 0 and dropped == 0 \
                and state.windows_fired == prev.windows_fired \
                and state.t0 == prev.t0:
            return ()
        tail = state.buf[len(state.buf) - appended:] if appended else []
        return (dropped, tail, state.windows_fired, state.t0)

    def _compact(self, epoch: int, state: WindowState) -> None:
        """Rewrite the log as at most two snapshots: the last *committed*
        epoch (the checkpoint may still point at it if the caller crashes
        before publishing ``epoch``) and the new one. Temp file + fsync +
        ``os.replace``: readers of either epoch always find a valid log."""
        tmp = self._file + ".tmp"
        with open(tmp, "wb") as f:
            if self._prev is not None:
                pref, prev = self._prev
                f.write(_encode_entry(_SNAP, pref,
                                      (prev.buf, prev.evicted, prev.t0,
                                       prev.windows_fired)))
            f.write(_encode_entry(_SNAP, epoch,
                                  (state.buf, state.evicted, state.t0,
                                   state.windows_fired)))
            f.flush()
            if self.fsync != "never":
                os.fsync(f.fileno())
        os.replace(tmp, self._file)
        self._open_writer()
        self._deltas_since_snap = 0
        self.snapshots += 1
        self._m_snapshots.inc()
