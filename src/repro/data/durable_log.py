"""Durable, file-backed :class:`~repro.core.broker.PartitionLog`.

PR 2's transport lets a broker restart without losing *consumer progress*
(``StreamProgress`` offsets live with the consumer) — but the records
themselves lived in :class:`~repro.core.broker.InMemoryPartitionLog` and died
with the process. This module is the Kafka half of that durability story: an
append-only log of length-prefixed, CRC-checked record frames in **segment
files** on disk, with an in-memory offset index rebuilt by a **recovery
scan** every time the log opens.

Layout of one partition directory::

    p0000/
      00000000.seg     record frames, appended in offset order
      00000001.seg     ... next segment after ``segment_bytes`` rolls over

Each record frame is ``u32 length | u32 crc32 | payload`` where the payload
is the transport's message encoding of ``(key, value, timestamp)`` — the same
kind-byte + optional raw-array-region format that crosses the socket
(``docs/transport.md``), so detector frames hit the disk as raw dtype/shape +
bytes, not pickle blow-ups, and the same restricted unpickler guards reads.

Recovery contract (what the crash tests in ``tests/test_durable_log.py``
pin down): on open, every segment is scanned front to back and each frame's
CRC re-verified. The scan stops at the first frame that does not hold — a
torn tail from a killed producer, a truncated file, a flipped bit — and the
log **truncates to the last valid frame boundary** (later segments are set
aside as ``*.orphan``, never silently re-entered). What survives is always a
dense, garbage-free prefix of what was appended: exactly Kafka's
log-recovery behavior for unflushed segments.

``fsync`` policy trades durability for append latency:

- ``"always"``   — fsync after every append/append_many (power-loss safe),
- ``"interval"`` — fsync at most every ``fsync_interval`` seconds (default;
  bounded power-loss window, process crashes lose nothing),
- ``"never"``    — leave flushing to the OS (process crashes still lose
  nothing: writes are unbuffered, only power loss is exposed).

Directory durability is part of the same contract: creating a new segment
file (a roll) and renaming one aside (``*.orphan`` during recovery) are
*directory* mutations, and a power loss after the data fsync but before the
directory entry reaches disk could resurrect an orphaned segment or lose a
freshly rolled one. Under ``fsync="always"``/``"interval"`` the partition
directory fd is therefore fsynced after every segment create/rename;
``"never"`` skips it, consistent with that policy's power-loss exposure.

The CRC frame format doubles as the **replication wire format**: a follower
(:class:`~repro.data.replication.ReplicaFollower`) pulls committed frames
with :meth:`DurablePartitionLog.read_frames` — raw header+payload bytes,
verbatim — re-verifies each CRC on its side of the socket and appends them
byte-identical with :meth:`DurablePartitionLog.append_frames`. Offsets stay
dense and equal on both logs by construction.

:class:`DurableLogFactory` adapts this to ``Broker(log_factory=...)``: the
broker passes ``(topic, partition)`` to factories that accept them, and the
factory maps each onto a stable directory under its root — so a restarted
broker that re-creates its topics (or calls :meth:`DurableLogFactory.restore`)
reopens the same logs and replays every committed record to fresh
subscribers.
"""
from __future__ import annotations

import os
import struct
import threading
import time
import zlib
from typing import Any, Sequence

from repro.core.broker import Broker, Record
from repro.data.transport import (MAX_FRAME_BYTES, decode_message,
                                  encode_message)
from repro.utils import get_logger

log = get_logger(__name__)

_REC_HEADER = struct.Struct(">II")     # payload length | crc32 of payload
_SEGMENT_SUFFIX = ".seg"
FSYNC_POLICIES = ("always", "interval", "never")


class LogCorruptionError(RuntimeError):
    """A record frame failed its CRC (or header) *after* recovery accepted
    it — disk corruption under a live log. Never returns garbage instead."""


def frame_bytes(payload: bytes) -> bytes:
    """One CRC frame, ``u32 length | u32 crc32 | payload`` — the segment
    record format, shared with :mod:`repro.data.state`. Refuses payloads past
    ``MAX_FRAME_BYTES``: the recovery scan treats larger lengths as
    corruption, so such a frame would commit and then be destroyed (with
    everything after it) on the next open."""
    if len(payload) > MAX_FRAME_BYTES:
        raise ValueError(
            f"record of {len(payload)} bytes exceeds the "
            f"{MAX_FRAME_BYTES}-byte durable-log record limit")
    return _REC_HEADER.pack(len(payload), zlib.crc32(payload)) + payload


def scan_frames(path: str) -> tuple[list[tuple[int, int]], int]:
    """Recovery scan over one frame file: validate every frame front to back,
    stopping at the first that does not hold (torn tail, truncated file,
    insane length, CRC mismatch). Returns ``([(frame_pos, payload_len), ...],
    valid_end)`` — callers truncate the file at ``valid_end`` to cut the
    torn/corrupt tail and may re-read any listed frame at ``frame_pos``."""
    frames: list[tuple[int, int]] = []
    size = os.path.getsize(path)
    pos = 0
    with open(path, "rb") as f:
        while pos + _REC_HEADER.size <= size:
            length, crc = _REC_HEADER.unpack(f.read(_REC_HEADER.size))
            if length > MAX_FRAME_BYTES or \
                    pos + _REC_HEADER.size + length > size:
                break                      # torn tail / insane length
            payload = f.read(length)
            if zlib.crc32(payload) != crc:
                break                      # corrupt frame
            frames.append((pos, length))
            pos += _REC_HEADER.size + length
    return frames, pos


class DurablePartitionLog:
    """File-backed append-only log for one (topic, partition).

    Implements the :class:`~repro.core.broker.PartitionLog` protocol
    (``append``/``read``/``end_offset``) plus ``append_many`` — the batched
    append :meth:`Broker.produce_many` uses for one write + one fsync per
    batch. Thread-safe; offsets are dense from 0.
    """

    def __init__(self, path: str, segment_bytes: int = 64 * 1024 * 1024,
                 fsync: str = "interval", fsync_interval: float = 0.05
                 ) -> None:
        if fsync not in FSYNC_POLICIES:
            raise ValueError(f"fsync {fsync!r} not in {FSYNC_POLICIES}")
        self.path = path
        self.segment_bytes = segment_bytes
        self.fsync = fsync
        self.fsync_interval = fsync_interval
        from repro.data.locktrace import new_rlock  # lock seam (chaos suites)
        self._lock = new_rlock("DurablePartitionLog._lock")
        # offset -> (segment id, byte position, payload length)
        self._index: list[tuple[int, int, int]] = []
        self._readers: dict[int, int] = {}   # segment id -> read fd
        self._writer: Any = None
        self._active_seg = 0
        self._active_size = 0
        self._last_fsync = 0.0
        self.recovered_records = 0         # valid frames found on open
        self.truncated_bytes = 0           # torn/corrupt tail cut on open
        self.orphaned_segments = 0         # segments after a corrupt one
        os.makedirs(path, exist_ok=True)
        self._recover()

    # -- files -------------------------------------------------------------
    def _seg_path(self, seg_id: int) -> str:
        return os.path.join(self.path, f"{seg_id:08d}{_SEGMENT_SUFFIX}")

    def _reader_fd(self, seg_id: int) -> int:
        with self._lock:
            fd = self._readers.get(seg_id)
            if fd is None:
                fd = os.open(self._seg_path(seg_id), os.O_RDONLY)
                self._readers[seg_id] = fd
            return fd

    def _pread(self, fd: int, nbytes: int, pos: int) -> bytearray:
        """Positionless read into a fresh *writable* buffer (zero-copy array
        decode needs mutability). ``pread`` carries its own offset, so
        concurrent readers never race a shared file position — and never
        need the appender lock."""
        buf = bytearray(nbytes)
        view = memoryview(buf)
        done = 0
        while done < nbytes:
            got = os.preadv(fd, [view[done:]], pos + done)
            if got <= 0:
                raise LogCorruptionError(
                    f"{self.path}: short read at pos {pos} "
                    f"({done}/{nbytes} bytes)")
            done += got
        return buf

    def _fsync_dir(self) -> None:
        """Flush the partition *directory* entry (segment create/rename) —
        without it a power loss can undo the rename/creation even though the
        file contents were fsynced. Skipped under ``fsync="never"``."""
        if self.fsync == "never":
            return
        fd = os.open(self.path, os.O_RDONLY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)

    def _open_writer(self, seg_id: int) -> None:
        if self._writer is not None:
            self._writer.close()
        path = self._seg_path(seg_id)
        created = not os.path.exists(path)
        # unbuffered: every append is a real write(2), so a killed process
        # loses at most the frame being written, never a buffered batch
        self._writer = open(path, "ab", buffering=0)
        self._active_seg = seg_id
        self._active_size = self._writer.tell()
        if created:
            self._fsync_dir()

    # -- recovery ----------------------------------------------------------
    def _recover(self) -> None:
        seg_ids = sorted(
            int(name[:-len(_SEGMENT_SUFFIX)])
            for name in os.listdir(self.path)
            if name.endswith(_SEGMENT_SUFFIX))
        corrupt_at: int | None = None
        for seg_id in seg_ids:
            if corrupt_at is not None:
                self._orphan(seg_id)
                continue
            if not self._scan_segment(seg_id):
                corrupt_at = seg_id
        self.recovered_records = len(self._index)
        active = (corrupt_at if corrupt_at is not None
                  else (seg_ids[-1] if seg_ids else 0))
        self._open_writer(active)
        if self.truncated_bytes or self.orphaned_segments:
            log.warning(
                "recovered %s: %d records, truncated %d bytes, "
                "%d segments orphaned", self.path, self.recovered_records,
                self.truncated_bytes, self.orphaned_segments)

    def _scan_segment(self, seg_id: int) -> bool:
        """Validate every frame; truncate at the first that does not hold.
        Returns True if the whole segment was clean."""
        path = self._seg_path(seg_id)
        size = os.path.getsize(path)
        frames, valid_end = scan_frames(path)
        self._index.extend((seg_id, pos, length) for pos, length in frames)
        if valid_end < size:
            self.truncated_bytes += size - valid_end
            with open(path, "ab") as f:
                f.truncate(valid_end)
            return False
        return True

    def _orphan(self, seg_id: int) -> None:
        """A segment *after* a corrupt one cannot rejoin the offset space
        (offsets must stay dense); set it aside rather than delete it."""
        src = self._seg_path(seg_id)
        dst = src + ".orphan"
        n = 0
        while os.path.exists(dst):
            n += 1
            dst = f"{src}.orphan{n}"
        os.rename(src, dst)
        self._fsync_dir()
        self.orphaned_segments += 1

    # -- append ------------------------------------------------------------
    @staticmethod
    def _frame(key: bytes | None, value: Any, timestamp: float) -> bytes:
        return frame_bytes(b"".join(encode_message((key, value, timestamp))))

    def _maybe_roll(self) -> None:
        if self._active_size >= self.segment_bytes and self._active_size > 0:
            self._open_writer(self._active_seg + 1)

    def _maybe_fsync(self) -> None:
        if self.fsync == "never":
            return
        now = time.monotonic()
        if self.fsync == "always" or \
                now - self._last_fsync >= self.fsync_interval:
            os.fsync(self._writer.fileno())
            self._last_fsync = now

    def _append_frames(self, frames: list[bytes],
                       lengths: list[int]) -> list[int]:
        self._maybe_roll()
        pos = self._active_size
        base = len(self._index)
        offsets = list(range(base, base + len(frames)))
        blob = b"".join(frames)
        self._writer.write(blob)
        for length in lengths:
            self._index.append((self._active_seg, pos,
                                length - _REC_HEADER.size))
            pos += length
        self._active_size += len(blob)
        self._maybe_fsync()
        return offsets

    def append(self, key: bytes | None, value: Any,
               timestamp: float = 0.0) -> int:
        frame = self._frame(key, value, timestamp)
        with self._lock:
            return self._append_frames([frame], [len(frame)])[0]

    def append_many(self, pairs: Sequence[tuple], timestamp: float = 0.0
                    ) -> list[int]:
        """Batched append: one write(2) + at most one fsync for the whole
        batch — the disk half of ``produce_many``'s amortization."""
        frames = [self._frame(k, v, timestamp) for k, v in pairs]
        if not frames:
            return []
        with self._lock:
            return self._append_frames(frames, [len(f) for f in frames])

    # -- read --------------------------------------------------------------
    def _index_slice(self, start: int,
                     until: int) -> tuple[int, list[tuple[int, int, int]]]:
        """Snapshot the index entries for ``[start, min(until, end))`` under
        the lock. The disk I/O happens *outside* it: a slow or cold-cache
        reader (a catching-up replication follower is exactly that) must not
        stall hot-path appends, and committed index entries are immutable —
        frames are never rewritten in place, only appended after them."""
        with self._lock:
            begin = max(start, 0)
            end = min(until, len(self._index))
            return begin, self._index[begin:end]

    def _frame_at(self, offset: int, seg_id: int, pos: int,
                  length: int) -> bytearray:
        """Read + CRC-verify one whole frame (header included) lock-free."""
        raw = self._pread(self._reader_fd(seg_id),
                          _REC_HEADER.size + length, pos)
        stored_len, crc = _REC_HEADER.unpack_from(raw)
        if stored_len != length or \
                zlib.crc32(memoryview(raw)[_REC_HEADER.size:]) != crc:
            raise LogCorruptionError(
                f"{self.path}: offset {offset} failed its CRC "
                "(on-disk corruption under a live log)")
        return raw

    def read(self, start: int, until: int) -> list[Record]:
        begin, entries = self._index_slice(start, until)
        out: list[Record] = []
        for i, (seg_id, pos, length) in enumerate(entries):
            offset = begin + i
            raw = self._frame_at(offset, seg_id, pos, length)
            # slice off the header; the buffer stays writable (zero-copy
            # arrays decoded over it remain mutable downstream)
            key, value, ts = decode_message(memoryview(raw)[_REC_HEADER.size:])
            out.append(Record(key, value, offset, ts))
        return out

    def read_frames(self, start: int, until: int,
                    max_bytes: int = 4 * 1024 * 1024
                    ) -> tuple[bytes, list[int], int]:
        """Replication cursor: byte-exact segment contents for offsets
        ``[start, min(until, end))`` as one contiguous blob plus the
        per-frame sizes (header included), capped at ``max_bytes`` per call
        (at least one frame is always returned when any is available).
        Returns ``(blob, lengths, next_offset)``. CRCs are *not* checked
        here: the follower re-verifies every frame before appending
        (:meth:`append_frames`), so a corrupt byte still cannot enter the
        replica's offset space, and the primary's serving path stays a
        handful of preads — no per-frame Python work stealing cycles from
        concurrent producers."""
        begin, entries = self._index_slice(start, until)
        lengths: list[int] = []
        total = 0
        for _, _, length in entries:
            size = _REC_HEADER.size + length
            if lengths and total + size > max_bytes:
                break
            lengths.append(size)
            total += size
        entries = entries[:len(lengths)]
        chunks: list[bytes] = []
        i = 0
        while i < len(entries):
            # frames are append-only, so consecutive index entries in one
            # segment are physically contiguous: coalesce the whole span
            # into a single pread instead of one syscall per frame (a
            # catching-up follower pulls tens of thousands at a time)
            seg_id, pos, _ = entries[i]
            j, span = i, 0
            while j < len(entries) and entries[j][0] == seg_id and \
                    entries[j][1] == pos + span:
                span += lengths[j]
                j += 1
            chunks.append(self._pread(self._reader_fd(seg_id), span, pos))
            i = j
        return b"".join(chunks), lengths, begin + len(entries)

    def append_frames(self, frames: Sequence[bytes]) -> list[int]:
        """Follower-side replication append: verify and append pre-framed
        record bytes *verbatim* (no decode/re-encode round trip — the CRC
        frame is the wire format). A frame whose header or CRC does not hold
        fails the whole batch before anything is appended: a corrupt frame
        must never enter the offset space."""
        checked: list[bytes] = []
        for frame in frames:
            frame = bytes(frame)
            if len(frame) < _REC_HEADER.size:
                raise ValueError(
                    f"replicated frame of {len(frame)} bytes is shorter "
                    "than its header")
            length, crc = _REC_HEADER.unpack_from(frame)
            if length != len(frame) - _REC_HEADER.size or \
                    length > MAX_FRAME_BYTES or \
                    zlib.crc32(memoryview(frame)[_REC_HEADER.size:]) != crc:
                raise ValueError(
                    "replicated frame failed its CRC/length check "
                    "(corrupted in transit; refusing the batch)")
            checked.append(frame)
        if not checked:
            return []
        with self._lock:
            return self._append_frames(checked, [len(f) for f in checked])

    def end_offset(self) -> int:
        with self._lock:
            return len(self._index)

    # -- lifecycle ---------------------------------------------------------
    @property
    def segments(self) -> int:
        with self._lock:
            return len({seg for seg, _, _ in self._index}) or 1

    def close(self) -> None:
        with self._lock:
            if self._writer is not None:
                if self.fsync != "never":
                    os.fsync(self._writer.fileno())
                self._writer.close()
                self._writer = None
            for fd in self._readers.values():
                os.close(fd)
            self._readers.clear()

    def __enter__(self) -> "DurablePartitionLog":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()


class DurableLogFactory:
    """``Broker(log_factory=DurableLogFactory(root))``: one
    :class:`DurablePartitionLog` per (topic, partition) under ``root``.

    The broker passes ``topic``/``partition`` keywords (it probes the factory
    signature), and the factory maps them to ``root/<topic>/p<partition>`` —
    a *stable* location, so re-creating the topic after a restart reopens the
    same segments and recovers every record. :meth:`restore` re-creates all
    topics found on disk on a fresh broker in one call.
    """

    def __init__(self, root: str, **log_kwargs: Any) -> None:
        self.root = str(root)
        self._log_kwargs = log_kwargs
        os.makedirs(self.root, exist_ok=True)

    def __call__(self, topic: str, partition: int) -> DurablePartitionLog:
        if (not topic or os.sep in topic or (os.altsep or "/") in topic
                or topic in (".", "..") or "\x00" in topic):
            raise ValueError(f"topic {topic!r} is not a safe directory name")
        path = os.path.join(self.root, topic, f"p{partition:04d}")
        return DurablePartitionLog(path, **self._log_kwargs)

    def topics_on_disk(self) -> dict[str, int]:
        """Map of topic -> partition count found under ``root``."""
        found: dict[str, int] = {}
        for topic in sorted(os.listdir(self.root)):
            tdir = os.path.join(self.root, topic)
            if not os.path.isdir(tdir):
                continue
            parts = [name for name in os.listdir(tdir)
                     if name.startswith("p") and name[1:].isdigit()
                     and os.path.isdir(os.path.join(tdir, name))]
            if parts:
                found[topic] = max(int(p[1:]) for p in parts) + 1
        return found

    def restore(self, broker: Broker) -> list[str]:
        """Re-create every topic found on disk on a (fresh) broker — the
        restart path: records recovered by the per-partition scans become
        readable at their original offsets, so a new subscriber replays the
        full committed history."""
        topics = self.topics_on_disk()
        for topic, partitions in topics.items():
            broker.create_topic(topic, partitions)
        return sorted(topics)
