"""Process-wide metrics registry + batch-epoch trace spans.

PRs 1-5 each grew a private counter surface — ``DeliveryRuntime.report()``,
``IngestRunner.lag_snapshot()``, ``BrokerServer.requests_served``,
``MetricsSink.report()`` — none of them time-series, queryable, or visible
outside the process. Both exemplar systems couple the stream to a live
observability backend (DELTA stores per-chunk analysis timing into MongoDB
for a visualization consumer; CFAA writes InfluxDB points behind a Grafana
dashboard). This module is that backend's in-process half: one
:class:`MetricsRegistry` every layer registers into, served over HTTP by
:mod:`repro.data.obs_server`.

Three metric kinds, Prometheus-shaped:

- :class:`Counter` — monotonically increasing total (``inc``),
- :class:`Gauge`  — point-in-time value (``set``/``inc``/``dec``), or a
  *callback* gauge evaluated lazily at read time (per-topic log size, lane
  queue depth, consumer lag — reads that would cost something per event but
  are free to compute on scrape),
- :class:`Histogram` — observations bucketed into fixed latency buckets
  (``observe``), plus running sum/count.

Every metric additionally keeps a bounded ring buffer of ``(t, value)``
samples — :meth:`MetricsRegistry.sample` appends one point per metric, and
the observability endpoint calls it per scrape, so ``/metrics.json`` carries
a short time series without any per-event cost (sampling happens at read
frequency, exactly Prometheus's pull model).

Metric identity is ``(name, labels)``; registering the same identity twice
returns the existing instrument (so two ``Broker`` instances produce into
one shared counter), except that a callback gauge's callback is *replaced*
— latest wins — so a rebuilt component (a restarted broker, a new lane)
re-binds its live reads instead of leaving the registry pointing at a dead
object.

Hot-path cost discipline: incrementing a counter is one lock + one add, and
the instrumented layers cache their instruments at construction (no registry
lookup per record). ``benchmarks/run.py --check`` guards the total tax:
ingest with the registry on must stay within 1.1x of registry-off records/s.
The off switch is :class:`NullRegistry` (every operation a no-op) installed
via :func:`set_registry` / :func:`disabled`.

**Batch-epoch trace spans** (:class:`TraceLog`, :class:`BatchSpan`): the
streaming context stamps one span per micro-batch — pump, batch fn, serial
sinks, state commit, checkpoint, broker commit, delivery enqueue, each
timed — tagged with the PR-5 checkpoint epoch, into a bounded in-memory
log. A slow batch then decomposes into *which stage* ate the time
(``GET /traces?last=N``), the per-chunk timing record DELTA writes to Mongo.
"""
from __future__ import annotations

import math
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, Mapping

# Fixed latency buckets (seconds): micro-batch and sink-write timings land
# between ~0.5 ms and ~10 s on the paper's workloads.
DEFAULT_BUCKETS: tuple[float, ...] = (
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
    0.25, 0.5, 1.0, 2.5, 5.0, 10.0)

# Power-of-two size buckets for batch/record-count histograms (flush sizes,
# produce batch sizes) — same exposition format, different axis.
COUNT_BUCKETS: tuple[float, ...] = (
    1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024, 4096)

Labels = "Mapping[str, str] | None"


def _label_key(labels: Mapping[str, str] | None) -> tuple:
    return tuple(sorted((labels or {}).items()))


def _fmt_labels(items: tuple) -> str:
    if not items:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in items)
    return "{" + inner + "}"


class _Metric:
    """Common base: identity, help text, and the sample ring buffer."""

    kind = "untyped"

    def __init__(self, name: str, help: str, labels: tuple,
                 ring_size: int) -> None:
        self.name = name
        self.help = help
        self.labels = labels           # tuple of (key, value) pairs, sorted
        self.series: deque = deque(maxlen=ring_size)
        self._lock = threading.Lock()

    def value(self) -> float:          # pragma: no cover - overridden
        raise NotImplementedError

    def _record_sample(self, now: float) -> None:
        self.series.append((now, self.value()))

    def series_points(self) -> list[tuple[float, float]]:
        return list(self.series)


class Counter(_Metric):
    kind = "counter"

    def __init__(self, *args: Any, **kw: Any) -> None:
        super().__init__(*args, **kw)
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError("counters only go up; use a Gauge")
        with self._lock:
            self._value += n

    def value(self) -> float:
        with self._lock:
            return self._value


class Gauge(_Metric):
    kind = "gauge"

    def __init__(self, *args: Any,
                 callback: Callable[[], float] | None = None,
                 **kw: Any) -> None:
        super().__init__(*args, **kw)
        self._value = 0.0
        self.callback = callback

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    def dec(self, n: float = 1.0) -> None:
        with self._lock:
            self._value -= n

    def value(self) -> float:
        if self.callback is not None:
            # a callback over a torn-down component (closed broker, joined
            # lane) must not poison the whole scrape
            try:
                return float(self.callback())
            except Exception:
                return math.nan
        with self._lock:
            return self._value


class Histogram(_Metric):
    kind = "histogram"

    def __init__(self, *args: Any,
                 buckets: Iterable[float] = DEFAULT_BUCKETS,
                 **kw: Any) -> None:
        super().__init__(*args, **kw)
        self.buckets = tuple(sorted(buckets))
        if not self.buckets:
            raise ValueError("histogram needs at least one bucket")
        self._counts = [0] * (len(self.buckets) + 1)   # +1 for +Inf
        self._sum = 0.0
        self._count = 0

    def observe(self, v: float) -> None:
        with self._lock:
            self._sum += v
            self._count += 1
            for i, bound in enumerate(self.buckets):
                if v <= bound:
                    self._counts[i] += 1
                    return
            self._counts[-1] += 1

    def time(self) -> "_HistogramTimer":
        """``with hist.time(): ...`` observes the block's wall time."""
        return _HistogramTimer(self)

    def value(self) -> float:
        """Scalar view (for the ring buffer): total observations."""
        with self._lock:
            return float(self._count)

    def snapshot(self) -> dict[str, Any]:
        with self._lock:
            cum, counts = 0, []
            for c in self._counts:
                cum += c
                counts.append(cum)
            return {"buckets": list(self.buckets), "counts": counts,
                    "sum": self._sum, "count": self._count}


class _HistogramTimer:
    def __init__(self, hist: Histogram) -> None:
        self._hist = hist

    def __enter__(self) -> "_HistogramTimer":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc: Any) -> None:
        self._hist.observe(time.perf_counter() - self._t0)


class MetricsRegistry:
    """Get-or-create instrument registry with per-metric sample rings.

    ``ring_size`` bounds each metric's time series; ``namespace`` prefixes
    every rendered metric name (default ``repro``).
    """

    def __init__(self, ring_size: int = 256, namespace: str = "repro",
                 clock: Callable[[], float] = time.time) -> None:
        self.ring_size = ring_size
        self.namespace = namespace
        self._clock = clock
        self._metrics: dict[tuple[str, tuple], _Metric] = {}
        self._lock = threading.Lock()

    # -- registration ------------------------------------------------------
    def _get_or_create(self, cls, name: str, help: str,
                       labels: Mapping[str, str] | None,
                       **kw: Any) -> _Metric:
        key = (name, _label_key(labels))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = cls(name, help, key[1], self.ring_size, **kw)
                self._metrics[key] = m
            elif not isinstance(m, cls):
                raise ValueError(
                    f"metric {name!r} already registered as {m.kind}")
            return m

    def counter(self, name: str, help: str = "",
                labels: Mapping[str, str] | None = None) -> Counter:
        return self._get_or_create(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Mapping[str, str] | None = None,
              callback: Callable[[], float] | None = None) -> Gauge:
        g = self._get_or_create(Gauge, name, help, labels)
        if callback is not None:
            g.callback = callback      # latest live object wins
        return g

    def histogram(self, name: str, help: str = "",
                  labels: Mapping[str, str] | None = None,
                  buckets: Iterable[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get_or_create(Histogram, name, help, labels,
                                   buckets=buckets)

    # -- reads -------------------------------------------------------------
    def metrics(self) -> "list[_Metric]":
        with self._lock:
            return list(self._metrics.values())

    def sample(self, now: float | None = None) -> None:
        """Append one ``(t, value)`` point to every metric's ring buffer.
        Called per scrape by the observability endpoint (and wherever else a
        series point is wanted) — sampling frequency is read frequency."""
        now = self._clock() if now is None else now
        for m in self.metrics():
            m._record_sample(now)

    def snapshot(self) -> dict[str, Any]:
        """The full registry as JSON-ready data: every metric's current
        value, kind, labels, histogram buckets, and ring-buffer series."""
        out: dict[str, Any] = {"sampled_at": self._clock(), "metrics": []}
        for m in self.metrics():
            entry: dict[str, Any] = {
                "name": m.name, "kind": m.kind, "help": m.help,
                "labels": dict(m.labels), "value": _json_num(m.value()),
                "series": [(t, _json_num(v)) for t, v in m.series_points()],
            }
            if isinstance(m, Histogram):
                entry["histogram"] = m.snapshot()
            out["metrics"].append(entry)
        return out

    def prometheus_text(self) -> str:
        """Prometheus text exposition format (``GET /metrics``)."""
        by_name: dict[str, list[_Metric]] = {}
        for m in self.metrics():
            by_name.setdefault(m.name, []).append(m)
        lines: list[str] = []
        for name in sorted(by_name):
            group = by_name[name]
            full = f"{self.namespace}_{name}" if self.namespace else name
            head = group[0]
            if head.help:
                lines.append(f"# HELP {full} {head.help}")
            lines.append(f"# TYPE {full} {head.kind}")
            for m in group:
                lab = _fmt_labels(m.labels)
                if isinstance(m, Histogram):
                    snap = m.snapshot()
                    for bound, cum in zip(snap["buckets"], snap["counts"]):
                        ble = dict(m.labels)
                        ble["le"] = _fmt_float(bound)
                        lines.append(f"{full}_bucket"
                                     f"{_fmt_labels(tuple(sorted(ble.items())))}"
                                     f" {cum}")
                    inf = dict(m.labels)
                    inf["le"] = "+Inf"
                    lines.append(f"{full}_bucket"
                                 f"{_fmt_labels(tuple(sorted(inf.items())))}"
                                 f" {snap['count']}")
                    lines.append(f"{full}_sum{lab} {_fmt_float(snap['sum'])}")
                    lines.append(f"{full}_count{lab} {snap['count']}")
                else:
                    lines.append(f"{full}{lab} {_fmt_float(m.value())}")
        return "\n".join(lines) + "\n"


def _fmt_float(v: float) -> str:
    if math.isnan(v):
        return "NaN"
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _json_num(v: float):
    """JSON has no NaN: a dead callback gauge serializes as null."""
    return None if isinstance(v, float) and math.isnan(v) else v


class _NullInstrument:
    """Absorbs every instrument call; shared singleton."""

    def inc(self, n: float = 1.0) -> None: ...
    def dec(self, n: float = 1.0) -> None: ...
    def set(self, v: float) -> None: ...
    def observe(self, v: float) -> None: ...

    def time(self) -> "_NullTimer":
        return _NULL_TIMER

    def value(self) -> float:
        return 0.0


class _NullTimer:
    def __enter__(self) -> "_NullTimer":
        return self

    def __exit__(self, *exc: Any) -> None: ...


_NULL_INSTRUMENT = _NullInstrument()
_NULL_TIMER = _NullTimer()


class NullRegistry:
    """Registry-off: every instrument is a shared no-op. This is the "bare"
    leg of the ``--check`` overhead guard, and the escape hatch for a
    pipeline that wants zero telemetry tax."""

    def counter(self, *a: Any, **kw: Any) -> Any:
        return _NULL_INSTRUMENT

    def gauge(self, *a: Any, **kw: Any) -> Any:
        return _NULL_INSTRUMENT

    def histogram(self, *a: Any, **kw: Any) -> Any:
        return _NULL_INSTRUMENT

    def metrics(self) -> list:
        return []

    def sample(self, now: float | None = None) -> None: ...

    def snapshot(self) -> dict[str, Any]:
        return {"sampled_at": time.time(), "metrics": []}

    def prometheus_text(self) -> str:
        return "\n"


# -- process-wide default ----------------------------------------------------

_default_registry: Any = MetricsRegistry()
_default_lock = threading.Lock()


def get_registry() -> MetricsRegistry:
    """The process-wide registry every layer registers into by default."""
    return _default_registry


def set_registry(registry: Any) -> Any:
    """Swap the process-wide registry (returns the previous one). Pass a
    fresh :class:`MetricsRegistry` for test isolation, or a
    :class:`NullRegistry` to turn instrumentation off for components
    constructed afterwards (instruments are cached at construction)."""
    global _default_registry
    with _default_lock:
        prev = _default_registry
        _default_registry = registry
        return prev


class disabled:
    """``with metrics.disabled(): ...`` — components constructed inside see
    a :class:`NullRegistry` (the bench harness's bare leg)."""

    def __enter__(self) -> NullRegistry:
        self._prev = set_registry(NullRegistry())
        return _default_registry

    def __exit__(self, *exc: Any) -> None:
        set_registry(self._prev)


# -- batch-epoch trace spans -------------------------------------------------

@dataclass
class BatchSpan:
    """One micro-batch decomposed into stages. ``stages`` maps stage name ->
    seconds; ``epoch`` is the checkpoint epoch the batch committed as (the
    PR-5 atomic (offsets, window state) publication), so a span joins
    exactly one durable point in the stream."""
    batch_index: int
    epoch: int
    num_records: int
    started_at: float                # wall clock (time.time)
    total_s: float = 0.0
    stages: dict[str, float] = field(default_factory=dict)

    def as_dict(self) -> dict[str, Any]:
        return {"batch_index": self.batch_index, "epoch": self.epoch,
                "num_records": self.num_records,
                "started_at": self.started_at,
                "total_s": self.total_s,
                "stages": dict(self.stages)}


# Stage names in pipeline order (the trace-span table in
# docs/observability.md documents each):
SPAN_STAGES = ("pump", "batch_fn", "sinks", "state_commit", "checkpoint",
               "broker_commit", "delivery_submit")


class SpanRecorder:
    """Builds one :class:`BatchSpan` stage by stage.

    ``with rec.stage("pump"): ...`` accumulates (re-entering a stage adds to
    it); ``finish(epoch)`` stamps the epoch + total and hands the span to
    the trace log. Cost per batch: a few ``perf_counter`` calls and one
    deque append — priced by the same ``--check`` overhead guard as the
    registry.
    """

    def __init__(self, log: "TraceLog", batch_index: int,
                 num_records: int) -> None:
        self._log = log
        self.span = BatchSpan(batch_index=batch_index, epoch=-1,
                              num_records=num_records,
                              started_at=time.time())
        self._t0 = time.perf_counter()

    def stage(self, name: str) -> "_StageTimer":
        return _StageTimer(self.span.stages, name)

    def add(self, name: str, seconds: float) -> None:
        """Fold an externally-measured duration into a stage (accumulating)
        — for work timed before the recorder could exist (e.g. the source
        pump that discovers whether there is a batch at all)."""
        self.span.stages[name] = self.span.stages.get(name, 0.0) + seconds

    def finish(self, epoch: int) -> BatchSpan:
        self.span.epoch = epoch
        self.span.total_s = time.perf_counter() - self._t0
        self._log.record(self.span)
        return self.span


class _StageTimer:
    def __init__(self, stages: dict[str, float], name: str) -> None:
        self._stages = stages
        self._name = name

    def __enter__(self) -> "_StageTimer":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc: Any) -> None:
        dt = time.perf_counter() - self._t0
        self._stages[self._name] = self._stages.get(self._name, 0.0) + dt


class TraceLog:
    """Bounded in-memory log of recent :class:`BatchSpan` s."""

    def __init__(self, capacity: int = 512) -> None:
        self._spans: deque = deque(maxlen=capacity)
        self._lock = threading.Lock()
        self.recorded = 0

    def begin(self, batch_index: int, num_records: int) -> SpanRecorder:
        return SpanRecorder(self, batch_index, num_records)

    def record(self, span: BatchSpan) -> None:
        with self._lock:
            self._spans.append(span)
            self.recorded += 1

    def last(self, n: int | None = None) -> list[BatchSpan]:
        with self._lock:
            spans = list(self._spans)
        if n is None:
            return spans
        return spans[-n:] if n > 0 else []     # spans[-0:] would be all

    def stage_totals(self) -> dict[str, float]:
        """Cumulative seconds per stage across retained spans — the
        "which stage ate the time" rollup the ptycho example prints."""
        totals: dict[str, float] = {}
        for span in self.last():
            for name, dt in span.stages.items():
                totals[name] = totals.get(name, 0.0) + dt
        return totals
