"""Distributed RAAR ptychographic solver (the SHARP program, paper §III).

Per iteration (SHARP schedule — one overlap solve per iteration):

  1. π₁ (modulus):  ψ₁ = F⁻¹[ mag · Fψ / |Fψ| ]          (Pallas kernel)
  2. overlap update (eqs. 4–5): new probe P and object O from ψ₁ — the
     partial sums Σψ_jO*, Σ|O|², Σψ_jP*, Σ|P|² are *framewise independent*,
     so frames shard across workers and the sums combine with
     MPI_Allreduce ≡ ``jax.lax.psum`` (paper Fig. 9).       (Pallas products)
  3. π₂ψ₁ = P·O_patch  with the updated P, O.
  4. RAAR combine (eq. 7): ψ ← 2βπ₂π₁ψ + (1-2β)π₁ψ + β(ψ-π₂ψ)
     with π₂ψ ≈ π₂π₁ψ under the fixed-(P,O) projector — SHARP's
     single-overlap approximation.                           (Pallas kernel)

``raar_step`` is a pure function usable three ways: single-device (tests),
``shard_map`` over a worker mesh (the Spark-MPI bridge path — the paper's
deployment), and inside the streaming pipeline (frames arriving in
micro-batches).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps.ptycho.sim import PtychoProblem
from repro.kernels.modulus import ops as modulus_ops
from repro.kernels.overlap import ops as overlap_ops
from repro.kernels.raar import ops as raar_ops


@dataclass
class SolverConfig:
    beta: float = 0.75
    iterations: int = 100
    probe_update_start: int = 2     # iterations of object-only updates first
    eps: float = 1e-6
    use_pallas: bool | None = None  # None = auto by backend


def _patch_indices(positions: jax.Array, frame: int):
    iy = positions[:, 0, None, None] + jnp.arange(frame)[None, :, None]
    ix = positions[:, 1, None, None] + jnp.arange(frame)[None, None, :]
    return iy, ix


def overlap_update(psi: jax.Array, positions: jax.Array, probe: jax.Array,
                   obj_shape: tuple[int, int], eps: float = 1e-6,
                   axis_name: str | None = None,
                   update_probe: bool = True,
                   obj_prev: jax.Array | None = None,
                   use_pallas: bool | None = None
                   ) -> tuple[jax.Array, jax.Array]:
    """Eqs. (4)–(5): closed-form O and P from exit waves ψ.

    With ``axis_name``, partial sums are psum'd across the worker axis —
    the paper's MPI_Allreduce (Fig. 9)."""
    F, h, w = psi.shape
    iy, ix = _patch_indices(positions, h)

    # object update: O = Σ ψ_j P* / Σ |P|²
    num_o, den_o = overlap_ops.overlap_products(
        psi, jnp.broadcast_to(probe[None], psi.shape), use_pallas=use_pallas)
    num = jnp.zeros(obj_shape, psi.dtype).at[iy, ix].add(num_o)
    den = jnp.zeros(obj_shape, jnp.float32).at[iy, ix].add(den_o)
    if axis_name:
        num = jax.lax.psum(num, axis_name)
        den = jax.lax.psum(den, axis_name)
    obj = num / (den + eps)

    if not update_probe:
        return obj, probe
    # probe update: P = Σ ψ_j O*_patch / Σ |O_patch|²
    patches = obj[iy, ix]
    num_p, den_p = overlap_ops.overlap_products(psi, patches,
                                                use_pallas=use_pallas)
    nump = jnp.sum(num_p, axis=0)
    denp = jnp.sum(den_p, axis=0)
    if axis_name:
        nump = jax.lax.psum(nump, axis_name)
        denp = jax.lax.psum(denp, axis_name)
    new_probe = nump / (denp + eps)
    return obj, new_probe


def raar_step(psi: jax.Array, mag: jax.Array, positions: jax.Array,
              probe: jax.Array, obj_shape: tuple[int, int],
              config: SolverConfig, iteration: jax.Array | int = 0,
              axis_name: str | None = None
              ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One RAAR iteration. Returns (psi', obj, probe, fourier_error)."""
    up = config.use_pallas
    # π₁: modulus projection
    far = jnp.fft.fft2(psi)
    err = jnp.sum(jnp.square(jnp.abs(far) - mag))
    norm = jnp.sum(jnp.square(mag))
    if axis_name:
        err = jax.lax.psum(err, axis_name)
        norm = jax.lax.psum(norm, axis_name)
    far_proj = modulus_ops.modulus_project(far, mag, use_pallas=up)
    psi1 = jnp.fft.ifft2(far_proj)

    # overlap (eqs. 4-5) on the projected waves
    update_probe = jnp.asarray(iteration) >= config.probe_update_start \
        if not isinstance(iteration, int) else \
        iteration >= config.probe_update_start
    if isinstance(update_probe, bool):
        obj, new_probe = overlap_update(psi1, positions, probe, obj_shape,
                                        config.eps, axis_name,
                                        update_probe, use_pallas=up)
    else:
        obj, probe_candidate = overlap_update(psi1, positions, probe,
                                              obj_shape, config.eps,
                                              axis_name, True, use_pallas=up)
        new_probe = jnp.where(update_probe, probe_candidate, probe)

    # π₂π₁ψ with the refreshed (P, O)
    iy, ix = _patch_indices(positions, psi.shape[-1])
    p21 = new_probe[None] * obj[iy, ix]

    # RAAR combine (eq. 7); π₂ψ ≈ π₂π₁ψ under the fixed-(P,O) projector
    new_psi = raar_ops.raar_combine(psi, psi1, p21, p21, config.beta,
                                    use_pallas=up)
    rel_err = jnp.sqrt(err / jnp.maximum(norm, 1e-12))
    return new_psi, obj, new_probe, rel_err


def init_waves(problem_mag: jax.Array, probe: jax.Array) -> jax.Array:
    """ψ⁰: probe modulated by random phases, scaled to measured power."""
    F, h, w = problem_mag.shape
    power = jnp.sqrt(jnp.mean(jnp.square(problem_mag), axis=(1, 2)))
    base = probe[None] * (power / (jnp.mean(jnp.abs(probe)) * h * w + 1e-9)
                          )[:, None, None]
    return base.astype(jnp.complex64)


def reconstruct(problem: PtychoProblem, config: SolverConfig
                ) -> dict[str, Any]:
    """Single-device reference reconstruction (tests, small problems)."""
    positions = jnp.asarray(problem.positions)
    probe0 = problem.probe_true * 0 + jnp.asarray(
        np.asarray(problem.probe_true) *
        np.exp(1j * 0.5 * np.random.default_rng(0).standard_normal(
            problem.probe_true.shape)).astype(np.complex64))
    psi = init_waves(problem.magnitudes, probe0)
    obj_shape = problem.object_true.shape

    @jax.jit
    def body(carry, it):
        psi, probe = carry
        psi, obj, probe, err = raar_step(psi, problem.magnitudes, positions,
                                         probe, obj_shape, config, it)
        return (psi, probe), (err, obj)

    (psi, probe), (errs, objs) = jax.lax.scan(
        body, (psi, probe0), jnp.arange(config.iterations))
    obj = objs[-1]
    return {"object": obj, "probe": probe, "errors": errs, "psi": psi}


def reconstruction_quality(obj: jax.Array, truth: jax.Array,
                           margin: int = 48) -> float:
    """Phase correlation against ground truth on the interior (global phase
    offset removed) — a scalar in [-1, 1]."""
    o = np.asarray(obj)[margin:-margin, margin:-margin]
    t = np.asarray(truth)[margin:-margin, margin:-margin]
    # remove global phase
    offset = np.angle(np.vdot(t, o))
    o = o * np.exp(-1j * offset)
    po, pt = np.angle(o), np.angle(t)
    po -= po.mean()
    pt -= pt.mean()
    denom = np.sqrt((po**2).sum() * (pt**2).sum()) + 1e-12
    return float((po * pt).sum() / denom)
