"""Synthetic ptychography experiment (paper §III uses the same
simulation-based setup from the Sharp-Spark project).

Generates: a complex object (smooth amplitude, structured phase), a coherent
probe (Gaussian-apodized disk), an overlapping scan grid, and the measured
diffraction magnitudes  sqrt(I_j) = |F(P · O_patch_j)|  per eq. (1).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class PtychoProblem:
    object_true: jax.Array       # (H, W) complex64
    probe_true: jax.Array        # (h, w) complex64
    positions: np.ndarray        # (F, 2) int corner positions
    magnitudes: jax.Array        # (F, h, w) fp32 = sqrt(I_j)

    @property
    def num_frames(self) -> int:
        return len(self.positions)

    @property
    def frame_shape(self) -> tuple[int, int]:
        return self.probe_true.shape


def make_probe(size: int) -> jax.Array:
    """Gaussian-apodized circular probe with a quadratic phase (defocus)."""
    y, x = np.mgrid[:size, :size] - size / 2 + 0.5
    r2 = (x**2 + y**2) / (size / 3.5) ** 2
    amp = np.exp(-r2) * (r2 < 4.0)
    phase = 0.8 * r2
    return jnp.asarray((amp * np.exp(1j * phase)).astype(np.complex64))


def make_object(size: int, seed: int = 0) -> jax.Array:
    """Smooth random transmission function: amplitude in [0.7, 1],
    phase in [-pi/2, pi/2] with low-frequency structure."""
    rng = np.random.default_rng(seed)

    def smooth(scale):
        small = rng.standard_normal((size // scale, size // scale))
        img = np.kron(small, np.ones((scale, scale)))[:size, :size]
        k = np.ones((5, 5)) / 25.0
        from scipy.signal import convolve2d
        return convolve2d(img, k, mode="same", boundary="symm")

    amp = 0.85 + 0.15 * np.tanh(smooth(8))
    phase = 1.4 * np.tanh(smooth(4)) + 0.6 * np.tanh(smooth(16))
    return jnp.asarray((amp * np.exp(1j * phase)).astype(np.complex64))


def scan_grid(obj_size: int, probe_size: int, step: int) -> np.ndarray:
    """Overlapping raster grid of frame corner positions (+ small jitter)."""
    rng = np.random.default_rng(1)
    lim = obj_size - probe_size
    xs = np.arange(0, lim + 1, step)
    pos = np.array([(y, x) for y in xs for x in xs])
    jitter = rng.integers(-step // 4, step // 4 + 1, pos.shape)
    return np.clip(pos + jitter, 0, lim).astype(np.int32)


def gather_patches(obj: jax.Array, positions: np.ndarray,
                   frame: int) -> jax.Array:
    """(F, h, w) object patches at the scan positions."""
    pos = jnp.asarray(positions)
    iy = pos[:, 0, None, None] + jnp.arange(frame)[None, :, None]
    ix = pos[:, 1, None, None] + jnp.arange(frame)[None, None, :]
    return obj[iy, ix]


def scatter_add_patches(canvas: jax.Array, positions: np.ndarray,
                        patches: jax.Array) -> jax.Array:
    """Σ_j patch_j scattered at its position (the paper's eq. 4/5 sums)."""
    frame = patches.shape[-1]
    pos = jnp.asarray(positions)
    iy = pos[:, 0, None, None] + jnp.arange(frame)[None, :, None]
    ix = pos[:, 1, None, None] + jnp.arange(frame)[None, None, :]
    return canvas.at[iy, ix].add(patches)


def simulate(obj_size: int = 256, probe_size: int = 64, step: int = 12,
             seed: int = 0, photons: float = 0.0) -> PtychoProblem:
    """Build the synthetic problem; ``photons>0`` adds Poisson noise."""
    obj = make_object(obj_size, seed)
    probe = make_probe(probe_size)
    positions = scan_grid(obj_size, probe_size, step)
    patches = gather_patches(obj, positions, probe_size)
    exit_waves = probe[None] * patches
    far = jnp.fft.fft2(exit_waves)
    intensity = jnp.square(jnp.abs(far))
    if photons > 0:
        rng = np.random.default_rng(seed + 1)
        scale = photons / jnp.maximum(jnp.mean(intensity), 1e-9)
        noisy = rng.poisson(np.asarray(intensity * scale)) / np.asarray(scale)
        intensity = jnp.asarray(noisy.astype(np.float32))
    return PtychoProblem(object_true=obj, probe_true=probe,
                         positions=np.asarray(positions),
                         magnitudes=jnp.sqrt(intensity).astype(jnp.float32))
