"""Partition-parallel ART reconstruction (paper §IV, Figs. 11-12).

The tilt series is *slicewise independent*: slices are partitioned across
workers (the paper repartitions the RDD so neighbouring slices share a
partition), each partition runs the ART row-action sweep (Pallas kernel) on
its slices, and the reconstructed sub-volumes are gathered for the
rendering stage (apps/tomo/render.py — the ParaView stage of Fig. 11).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps.tomo.projector import make_system, project
from repro.kernels.art import ops as art_ops


@dataclass(frozen=True)
class TomoConfig:
    nray: int = 64
    angles: tuple = tuple(np.linspace(-75, 75, 25).tolist())
    beta: float = 1.0
    iterations: int = 2
    use_pallas: bool | None = None


def make_phantom(nslice: int, nray: int, seed: int = 0) -> np.ndarray:
    """Shepp-Logan-ish nested ellipsoids phantom volume."""
    rng = np.random.default_rng(seed)
    z, y, x = np.mgrid[:nslice, :nray, :nray].astype(np.float64)
    z = (z - nslice / 2) / (nslice / 2)
    y = (y - nray / 2) / (nray / 2)
    x = (x - nray / 2) / (nray / 2)
    vol = np.zeros((nslice, nray, nray))
    for _ in range(6):
        c = rng.uniform(-0.4, 0.4, 3)
        r = rng.uniform(0.15, 0.5, 3)
        a = rng.uniform(0.2, 1.0)
        mask = (((z - c[0]) / r[0]) ** 2 + ((y - c[1]) / r[1]) ** 2
                + ((x - c[2]) / r[2]) ** 2) < 1.0
        vol[mask] += a
    vol[((z**2 + y**2 + x**2) > 0.95)] = 0.0
    return vol.astype(np.float32)


def simulate_tilt_series(config: TomoConfig, nslice: int,
                         seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Returns (volume_true, sinogram (Nslice, Nproj*Nray))."""
    vol = make_phantom(nslice, config.nray, seed)
    A = make_system(config.nray, np.asarray(config.angles))
    sino = project(A, vol)
    return vol, sino.astype(np.float32)


import functools


@functools.lru_cache(maxsize=8)
def _slice_reconstructor(config: TomoConfig):
    """Jitted per-config slice solver (cached — compile once)."""
    n = config.nray

    def run(A, blocks):
        def one(b):
            f = art_ops.art_reconstruct_slice(
                A, b, jnp.zeros((n * n,), jnp.float32), beta=config.beta,
                iters=config.iterations, use_pallas=config.use_pallas)
            return f.reshape(n, n)
        return jax.vmap(one)(blocks)

    return jax.jit(run)


def reconstruct_slices(sino_slices: np.ndarray, config: TomoConfig
                       ) -> np.ndarray:
    """ART-reconstruct a block of slices (one RDD partition's work).

    sino_slices: (k, Nrow) -> (k, Nray, Nray)."""
    A = jnp.asarray(make_system(config.nray, np.asarray(config.angles)))
    out = _slice_reconstructor(config)(A, jnp.asarray(sino_slices))
    return np.asarray(out)


def residual(volume: np.ndarray, sino: np.ndarray,
             config: TomoConfig) -> float:
    A = make_system(config.nray, np.asarray(config.angles))
    pred = project(A, volume)
    return float(np.linalg.norm(pred - sino) / (np.linalg.norm(sino) + 1e-12))
