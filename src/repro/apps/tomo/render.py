"""Rendering stage (paper Figs. 13-15, ParaView/ParaViewWeb stand-in).

The paper's final pipeline stage converts partition results to VTK and
serves them through ParaViewWeb; reproducing that product is out of scope
(DESIGN.md §2) — the *pipeline stage* is kept: rank-parallel partitions emit
orthogonal slices + a max-intensity projection as PNG/NPY artifacts.
"""
from __future__ import annotations

import os

import numpy as np


def render_volume(volume: np.ndarray, outdir: str, prefix: str = "tomo"
                  ) -> list[str]:
    os.makedirs(outdir, exist_ok=True)
    paths = []
    mid = volume.shape[0] // 2
    views = {
        "slice_z": volume[mid],
        "slice_y": volume[:, volume.shape[1] // 2],
        "mip": volume.max(axis=0),
    }
    np.save(os.path.join(outdir, f"{prefix}_volume.npy"), volume)
    paths.append(os.path.join(outdir, f"{prefix}_volume.npy"))
    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
        fig, axes = plt.subplots(1, len(views), figsize=(4 * len(views), 4))
        for ax, (name, img) in zip(np.atleast_1d(axes), views.items()):
            ax.imshow(img, cmap="viridis")
            ax.set_title(name)
            ax.axis("off")
        path = os.path.join(outdir, f"{prefix}_views.png")
        fig.savefig(path, dpi=110, bbox_inches="tight")
        plt.close(fig)
        paths.append(path)
    # analyze: ok swallowed-exception - best-effort matplotlib; .npy already saved
    except Exception:  # rendering must never kill the pipeline
        pass
    return paths


def render_phase(obj: np.ndarray, outdir: str, prefix: str = "ptycho"
                 ) -> list[str]:
    """Paper Fig. 10: reconstructed object phases."""
    os.makedirs(outdir, exist_ok=True)
    phase = np.angle(obj)
    np.save(os.path.join(outdir, f"{prefix}_phase.npy"), phase)
    paths = [os.path.join(outdir, f"{prefix}_phase.npy")]
    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
        fig, axes = plt.subplots(1, 2, figsize=(9, 4.5))
        axes[0].imshow(phase, cmap="twilight")
        axes[0].set_title("reconstructed phase")
        axes[1].imshow(np.abs(obj), cmap="gray")
        axes[1].set_title("reconstructed amplitude")
        for ax in axes:
            ax.axis("off")
        path = os.path.join(outdir, f"{prefix}_object.png")
        fig.savefig(path, dpi=110, bbox_inches="tight")
        plt.close(fig)
        paths.append(path)
    # analyze: ok swallowed-exception - best-effort matplotlib; .npy already saved
    except Exception:  # rendering must never kill the pipeline
        pass
    return paths
