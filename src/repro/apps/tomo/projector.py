"""Parallel-ray projection matrix for ART (paper Fig. 12 ``parallelRay``).

Builds the dense system matrix A ∈ R^{(Nproj·Nray) × Nray²}: row (θ, r)
holds the pixel weights of the ray at angle θ and detector offset r,
assembled by sampling along the ray with bilinear interpolation (Joseph-
style). Dense is deliberate: the ART kernel streams rows HBM→VMEM, and a
dense (1, Ncol) row is exactly the MXU/VPU-friendly layout (the paper
itself densifies: ``A = A.todense()``).
"""
from __future__ import annotations

import functools

import numpy as np


@functools.lru_cache(maxsize=8)
def parallel_ray_matrix(nray: int, angles_key: tuple) -> np.ndarray:
    angles = np.asarray(angles_key, dtype=np.float64)
    n = nray
    nsamp = 2 * n
    ts = np.linspace(-n / 2, n / 2, nsamp)
    offs = np.arange(n) - n / 2 + 0.5
    A = np.zeros((len(angles) * n, n * n), dtype=np.float32)
    step = ts[1] - ts[0]
    for ai, theta in enumerate(np.deg2rad(angles)):
        d = np.array([np.cos(theta), np.sin(theta)])      # ray direction
        o = np.array([-np.sin(theta), np.cos(theta)])     # detector axis
        for ri, r in enumerate(offs):
            # sample points along the ray
            pts = r * o[None, :] + ts[:, None] * d[None, :] + n / 2 - 0.5
            ys, xs = pts[:, 0], pts[:, 1]
            y0 = np.floor(ys).astype(int)
            x0 = np.floor(xs).astype(int)
            fy, fx = ys - y0, xs - x0
            row = np.zeros(n * n, dtype=np.float32)
            for dy, dx, wgt in ((0, 0, (1 - fy) * (1 - fx)),
                                (0, 1, (1 - fy) * fx),
                                (1, 0, fy * (1 - fx)),
                                (1, 1, fy * fx)):
                yy, xx = y0 + dy, x0 + dx
                ok = (yy >= 0) & (yy < n) & (xx >= 0) & (xx < n)
                np.add.at(row, (yy[ok] * n + xx[ok]),
                          (wgt[ok] * step).astype(np.float32))
            A[ai * n + ri] = row
    return A


def make_system(nray: int, angles: np.ndarray) -> np.ndarray:
    return parallel_ray_matrix(nray, tuple(np.asarray(angles).tolist()))


def project(A: np.ndarray, volume: np.ndarray) -> np.ndarray:
    """Forward-project a (Nslice, Nray, Nray) volume -> tilt series
    (Nslice, Nrow) with Nrow = Nproj·Nray."""
    nslice = volume.shape[0]
    flat = volume.reshape(nslice, -1)
    return flat @ A.T
