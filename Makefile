# Tier-1 verify and friends. `make test` is the command the driver runs;
# keeping it here means an environment failure mode (missing dev dep,
# wrong PYTHONPATH) surfaces as a red make target, not a silent skip.

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-data test-delivery test-state test-transport test-obs test-groups test-replication test-codec test-analyze analyze lint bench bench-check examples deps-check

test:           ## tier-1: invariant analyzer first, then the full suite, stop at first failure
	$(PYTHON) -m tools.analyze src/ tests/
	$(PYTHON) -m pytest -x -q

analyze:        ## project invariant analyzer (docs/static_analysis.md); exit 1 on findings
	$(PYTHON) -m tools.analyze src/ tests/

lint: analyze   ## alias for analyze

test-analyze:   ## the analyzer's own suite + the lock-order harness unit tests
	$(PYTHON) -m pytest -q tests/test_analyze.py tests/test_locktrace.py

test-data:      ## just the data subsystem (sources/sinks/windows/broker/durability)
	$(PYTHON) -m pytest -q tests/test_data_sources.py tests/test_data_sinks.py \
	    tests/test_data_window.py tests/test_broker_dstream.py \
	    tests/test_broker_parity.py tests/test_durable_log.py \
	    tests/test_window_state.py

test-state:     ## restart-safe windowed state (stores, atomic checkpoint, SIGKILL crash)
	$(PYTHON) -m pytest -q tests/test_window_state.py tests/test_data_window.py \
	    tests/test_broker_dstream.py

test-delivery:  ## parallel sink delivery chaos suite + lag-driven elastic ingest
	$(PYTHON) -m pytest -q tests/test_delivery.py tests/test_elastic_ingest.py

test-transport: ## socket broker transport (framing properties, reconnect, cross-process)
	$(PYTHON) -m pytest -q tests/test_transport.py tests/test_transport_frames.py \
	    tests/test_broker_parity.py

test-obs:       ## telemetry: metrics registry, trace spans, observability endpoint
	$(PYTHON) -m pytest -q tests/test_metrics.py tests/test_obs_server.py

test-groups:    ## consumer groups: assignor properties, fencing, partition-handoff chaos suite
	$(PYTHON) -m pytest -q tests/test_groups.py tests/test_broker_parity.py

test-replication: ## broker HA: follower replication, failover promotion, epoch fencing
	$(PYTHON) -m pytest -q tests/test_replication.py tests/test_broker_parity.py \
	    tests/test_durable_log.py

test-codec:     ## per-topic payload codecs: int8/zlib roundtrips, wire refusal, parity matrix
	$(PYTHON) -m pytest -q tests/test_codec.py tests/test_broker_parity.py

bench:          ## CSV benchmark sweep (includes bench_ingest)
	$(PYTHON) -m benchmarks.run

bench-check:    ## guards: produce_many >= 3x per-record, fan-out >= 2x serial, durable window state <= 1.3x in-memory, metrics registry <= 1.1x registry-off, replicated produce <= 1.3x unreplicated, shm frames >= 5x 'A'-frames, int8 codec >= 2x raw on a throttled link
	$(PYTHON) -m benchmarks.run --check

examples:       ## fast end-to-end example runs
	$(PYTHON) examples/ptycho_pipeline.py --fast
	$(PYTHON) examples/tomo_pipeline.py --nray 32 --nslice 16
	$(PYTHON) examples/remote_ingest.py --frames 48
	$(PYTHON) examples/ha_failover.py --batches 40

deps-check:     ## verify runtime imports resolve (no installs) + docs links
	$(PYTHON) -c "import jax, numpy, scipy; print('runtime deps ok')"
	-$(PYTHON) -c "import hypothesis; print('hypothesis ok')"
	$(PYTHON) tools/check_docs_links.py
