"""Train a ~100M-parameter LM through the streaming pipeline.

A ~100M decoder-only config (internlm2 family: 12L, d_model 576, SwiGLU)
streams synthetic token micro-batches through the broker and trains with
AdamW + checkpointing. ``--steps 300`` is the few-hundred-step deliverable
run (hours on this 1-core container — results land in out/train_lm.log);
the default is a quick demonstration.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 30
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import OptimizerConfig
from repro.launch.train import assemble_batch, synthetic_producer
from repro.checkpoint import AsyncCheckpointer
from repro.core import Broker, Context, StreamingContext
from repro.training import build_train_step, init_state
from repro.utils import human_count, tree_params


def model_100m():
    return get_config("internlm2-1.8b").replace(
        num_layers=12, d_model=576, num_heads=8, num_kv_heads=4,
        head_dim=72, d_ff=2304, vocab_size=49152, remat="none")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--ckpt-dir", default="out/ckpt_100m")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    config = model_100m()
    opt = OptimizerConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 5),
                          total_steps=args.steps, zero1=False)
    state = init_state(jax.random.PRNGKey(args.seed), config, opt)
    n = tree_params(state["params"])
    print(f"model: {human_count(n)} params "
          f"({config.num_layers}L d={config.d_model})")

    broker = Broker()
    broker.create_topic("tokens", partitions=1)
    synthetic_producer(broker, config, args.steps, args.batch, args.seq,
                       args.seed)
    ctx = Context()
    sc = StreamingContext(ctx, broker,
                          max_records_per_partition=args.batch)
    sc.subscribe(["tokens"])
    step_fn = jax.jit(build_train_step(config, opt), donate_argnums=(0,))
    ckpt = AsyncCheckpointer(args.ckpt_dir)

    losses = []
    t0 = time.time()

    def on_batch(rdd, info):
        records = rdd.collect()
        if len(records) < args.batch:
            return None
        nonlocal state
        state, metrics = step_fn(state, assemble_batch(records, config))
        losses.append(float(metrics["loss"]))
        s = len(losses)
        if s % 5 == 0 or s == 1:
            tok_s = s * args.batch * args.seq / (time.time() - t0)
            print(f"step {s:4d} loss {losses[-1]:.4f} "
                  f"lr {float(metrics['lr']):.2e} {tok_s:.0f} tok/s")
        if s % 50 == 0:
            ckpt.save(s, state)
        return losses[-1]

    sc.foreach_batch(on_batch)
    while len(losses) < args.steps and sc.run_one_batch() is not None:
        pass
    ckpt.save(len(losses), state)
    ckpt.wait()
    print(f"\n{len(losses)} steps in {time.time()-t0:.0f}s; "
          f"loss {losses[0]:.3f} -> {min(losses[-5:]):.3f}")
    assert losses[-1] < losses[0], "loss must decrease"


if __name__ == "__main__":
    main()
