"""Two-process near-real-time ingest: detector host -> socket -> consumer.

The paper's Fig. 7 topology split across OS processes, the first step toward
its beamline/cluster deployment (and its ZeroMQ future-work item):

  producer process                          consumer process (this one)
  ----------------                          ---------------------------
  DetectorSource (frame simulator)          Broker (in-memory logs)
    -> IngestRunner (block backpressure)    BrokerServer on a socket
    -> RemoteBroker ──── TCP/Unix ────────▶   -> StreamingContext micro-batches
       (lag measured against the                -> per-batch photon statistics
        offsets the consumer committed          -> commits pushed broker-side,
        broker-side)                               closing the backpressure loop

The producer never shares memory with the consumer: every frame crosses the
length-prefixed socket transport (``docs/transport.md``) on its fast path —
detector frames are ndarrays, so they ride zero-copy *array frames* (raw
dtype/shape + bytes, no pickle), and the runner batches them through
``produce_many`` (one socket round trip per flush, not per frame). The
producer's backpressure is bounded against what the consumer has
*processed*, not what it has buffered. Swap ``--addr host:port`` for a
reachable interface and the two halves run on different machines unchanged.

Run:  PYTHONPATH=src python examples/remote_ingest.py --frames 96
      PYTHONPATH=src python examples/remote_ingest.py --addr /tmp/broker.sock
"""
import argparse
import multiprocessing as mp
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def produce_frames(address, frames: int, obj_size: int, probe_size: int,
                   max_pending: int) -> None:
    """Producer process: simulate the detector, pump frames over the socket."""
    from repro.apps.ptycho.sim import simulate
    from repro.data import (DetectorSource, IngestConfig, IngestRunner,
                            RemoteBroker)

    problem = simulate(obj_size, probe_size, step=max(8, probe_size // 4))
    # the scan may hold fewer frames than asked for; the detector emits
    # min(frames, problem.num_frames) and the consumer checks against what
    # actually reached the broker
    source = DetectorSource(problem, max_frames=frames, emit_frames=True)
    remote = RemoteBroker(address)
    # The client doubles as the consumer view: lag() is served from the
    # offsets the consumer-side StreamingContext committed on its broker.
    runner = IngestRunner(remote, consumer=remote)
    runner.add(source, IngestConfig(topic="frames", partitions=2,
                                    policy="block", max_pending=max_pending,
                                    poll_batch=16))
    runner.run_inline(timeout=120)
    m = runner.metrics[0]
    print(f"[producer pid={os.getpid()}] pumped "
          f"{m.produced}/{len(source)} frames in "
          f"{m.produce_calls} batched produce calls "
          f"(~{m.produced / max(m.produce_calls, 1):.0f} frames/round trip), "
          f"blocked {m.blocked_s:.2f}s on backpressure, "
          f"max lag seen {m.max_observed_lag}")
    remote.close()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=96)
    ap.add_argument("--obj-size", type=int, default=96)
    ap.add_argument("--probe-size", type=int, default=32)
    ap.add_argument("--batch", type=int, default=16,
                    help="max records per partition per micro-batch")
    ap.add_argument("--max-pending", type=int, default=64,
                    help="producer backpressure bound (records in flight)")
    ap.add_argument("--addr", default="127.0.0.1:0",
                    help='"host:port" for TCP (port 0 = ephemeral) or a '
                         "filesystem path for a Unix domain socket")
    args = ap.parse_args()

    from repro.core import Broker, Context, StreamingContext
    from repro.data import parse_address, serve_broker

    # consumer side owns the broker; the server publishes it on a socket
    broker = Broker()
    server = serve_broker(broker, parse_address(args.addr))
    print(f"[consumer pid={os.getpid()}] broker served on {server.address}")

    producer = mp.get_context("spawn").Process(
        target=produce_frames,
        args=(server.address, args.frames, args.obj_size, args.probe_size,
              args.max_pending),
        name="detector-producer")
    producer.start()

    sc = StreamingContext(Context(), broker, batch_interval=0.05,
                          max_records_per_partition=args.batch)
    # the producer creates the topic over the wire; wait for it to appear
    while "frames" not in broker.topics():
        if not producer.is_alive():
            server.stop()
            raise SystemExit(
                f"producer died before creating the topic "
                f"(exit code {producer.exitcode})")
        time.sleep(0.01)
    sc.subscribe(["frames"])

    stats = {"frames": 0, "photons": 0.0, "peak": 0.0}

    def process(rdd, info):
        frames = rdd.collect()             # (index, magnitude_frame) payloads
        mags = np.stack([f for _, f in frames])
        stats["frames"] += len(frames)
        stats["photons"] += float((mags ** 2).sum())
        stats["peak"] = max(stats["peak"], float(mags.max()))
        print(f"  batch {info.index}: {len(frames)} frames over the wire, "
              f"{stats['frames']} total, lag {sc.lag('frames')}")

    sc.foreach_batch(process)
    t0 = time.time()
    while producer.is_alive() or sc.lag("frames") > 0:
        if sc.run_one_batch() is None:
            time.sleep(0.005)
    producer.join(timeout=30)
    wall = time.time() - t0

    rep = sc.realtime_report()
    print(f"\nconsumed {stats['frames']} frames in {wall:.2f}s "
          f"({stats['frames'] / max(wall, 1e-9):.0f} frames/s over the "
          f"socket); total photons {stats['photons']:.3e}, "
          f"peak magnitude {stats['peak']:.2f}")
    print(f"micro-batches: {rep['batches']}, mean processing "
          f"{rep['mean_processing_s'] * 1e3:.1f} ms, keeps up with "
          f"{sc.batch_interval * 1e3:.0f} ms interval: {rep['keeps_up']}")
    print(f"server stats: {server.requests_served} requests served, "
          f"{server.frames_rejected} frames rejected")
    appended = sum(broker.end_offsets("frames"))
    assert appended > 0 and stats["frames"] == appended, \
        f"lost frames: consumed {stats['frames']} != appended {appended}"
    server.stop()
    if isinstance(server.address, str) and os.path.exists(server.address):
        os.unlink(server.address)
    print("remote ingest complete: every frame crossed the socket exactly "
          "once (block policy; no drops possible)")


if __name__ == "__main__":
    main()
