"""Tomography pipeline (paper §IV, Figs. 11-16): load -> partition -> ART ->
gather -> render.

The four paper steps, on the RDD layer with speculative-execution enabled:
  1. the TEM tilt series loads into an RDD (slicewise records);
  2. repartition groups neighbouring slices (paper step 2);
  3. every partition runs the ART sweep (Pallas kernel) in parallel —
     the scheduler retries failures and re-executes stragglers;
  4. sub-volumes gather on the driver and render to PNG/NPY (the
     ParaView/ParaViewWeb stage, stubbed per DESIGN.md).

Run:  PYTHONPATH=src python examples/tomo_pipeline.py --nray 64 --nslice 32
"""
import argparse
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.apps.tomo.render import render_volume
from repro.apps.tomo.solver import (TomoConfig, reconstruct_slices, residual,
                                    simulate_tilt_series)
from repro.core import Context
from repro.core.rdd import TaskScheduler


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nray", type=int, default=64)
    ap.add_argument("--nslice", type=int, default=32)
    ap.add_argument("--angles", type=int, default=25)
    ap.add_argument("--iterations", type=int, default=2)
    ap.add_argument("--partitions", type=int, default=4)
    ap.add_argument("--out", default="out")
    args = ap.parse_args()

    cfg = TomoConfig(
        nray=args.nray,
        angles=tuple(np.linspace(-75, 75, args.angles).tolist()),
        iterations=args.iterations, use_pallas=False)

    # step 1: "load the TEM dataset into RDD format"
    vol_true, sino = simulate_tilt_series(cfg, args.nslice)
    ctx = Context(scheduler=TaskScheduler(num_executors=args.partitions,
                                          speculation=True))
    records = [(i, sino[i]) for i in range(args.nslice)]
    rdd = ctx.parallelize(records, args.partitions)

    # step 2: repartition so neighbouring slices share a partition
    rdd = rdd.repartition(args.partitions)

    # step 3: ART on each partition in parallel
    def process_partition(items):
        idx = [i for i, _ in items]
        block = np.stack([b for _, b in items])
        return idx, reconstruct_slices(block, cfg)

    t0 = time.time()
    parts = rdd.map_partitions(process_partition).collect_partitions()
    recon = np.zeros((args.nslice, args.nray, args.nray), np.float32)
    for idx, block in parts:
        recon[idx] = block
    dt = time.time() - t0

    # step 4: gather + render
    r = residual(recon, sino, cfg)
    err = np.linalg.norm(recon - vol_true) / np.linalg.norm(vol_true)
    print(f"ART: {args.nslice} slices x {args.nray}^2, "
          f"{args.angles} angles, {args.iterations} sweeps "
          f"on {args.partitions} partitions: {dt:.1f}s")
    print(f"sinogram residual {r:.3f}; volume rel. error {err:.3f}")
    print(f"scheduler metrics: {ctx.scheduler.metrics}")
    paths = render_volume(recon, args.out)
    print("artifacts:", paths)


if __name__ == "__main__":
    main()
