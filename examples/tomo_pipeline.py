"""Tomography pipeline (paper §IV, Figs. 11-16): stream -> partition -> ART ->
gather -> render, on the data subsystem.

The paper's four steps, streamed instead of preloaded:
  1. the TEM tilt series arrives as slice records through a
     ProjectionSource (paper: "load the TEM dataset into RDD format");
  2. each micro-batch groups neighbouring slices (paper step 2 —
     repartition by proximity; slices stream in scan order);
  3. every batch runs the ART sweep (Pallas kernel) partition-parallel —
     the scheduler retries failures and re-executes stragglers;
  4. sub-volumes land in an idempotent NpzDirectorySink (checkpoint store),
     assemble, and render to PNG/NPY (the ParaView/ParaViewWeb stage,
     stubbed per DESIGN.md).

Run:  PYTHONPATH=src python examples/tomo_pipeline.py --nray 64 --nslice 32
"""
import argparse
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.apps.tomo.render import render_volume
from repro.apps.tomo.solver import (TomoConfig, reconstruct_slices, residual,
                                    simulate_tilt_series)
from repro.core import Broker, Context, NearRealTimePipeline, PipelineConfig
from repro.core.rdd import TaskScheduler
from repro.data import MetricsSink, NpzDirectorySink, ProjectionSource


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nray", type=int, default=64)
    ap.add_argument("--nslice", type=int, default=32)
    ap.add_argument("--angles", type=int, default=25)
    ap.add_argument("--iterations", type=int, default=2)
    ap.add_argument("--partitions", type=int, default=4)
    ap.add_argument("--slice-interval", type=float, default=0.0,
                    help="seconds between streamed slices (acquisition rate)")
    ap.add_argument("--obs-port", type=int, default=None,
                    help="serve the observability endpoint on this port "
                         "while the pipeline runs (0 = ephemeral port)")
    ap.add_argument("--out", default="out")
    args = ap.parse_args()

    cfg = TomoConfig(
        nray=args.nray,
        angles=tuple(np.linspace(-75, 75, args.angles).tolist()),
        iterations=args.iterations, use_pallas=False)

    # step 1: the tilt series streams in as (slice_index, sinogram_row)
    vol_true, sino = simulate_tilt_series(cfg, args.nslice)
    source = ProjectionSource(sino, interval=args.slice_interval)
    # per-run-shape directory: the gather below reads every key on disk, so
    # sub-volumes from a differently-shaped run must not share the store
    # (same-shape reruns resume idempotently, which is the point)
    run_tag = f"{args.nslice}x{args.nray}x{args.angles}x{args.iterations}"
    sink = NpzDirectorySink(os.path.join(args.out,
                                         f"tomo_subvolumes_{run_tag}"))
    metrics = MetricsSink()
    ctx = Context(scheduler=TaskScheduler(num_executors=args.partitions,
                                          speculation=True))
    batch_slices = max(1, args.nslice // args.partitions)

    # steps 2+3 per micro-batch: repartition neighbouring slices, ART sweep
    def process(rdd, info, bridge):
        records = sorted(rdd.collect())          # (i, row), scan order
        if not records:
            return None
        part = ctx.parallelize(records, min(args.partitions, len(records)))

        def art_sweep(items):
            idx = [i for i, _ in items]
            block = np.stack([b for _, b in items])
            return idx, reconstruct_slices(block, cfg)

        parts = part.map_partitions(art_sweep).collect_partitions()
        out = []
        for idx, block in parts:
            key = f"slices-{idx[0]:04d}-{idx[-1]:04d}"
            out.append((key, {"idx": np.asarray(idx, np.int64),
                              "block": block}))
        return out

    pipeline = NearRealTimePipeline(
        Broker(),
        PipelineConfig(batch_interval=0.02,
                       max_records_per_partition=batch_slices),
        process,
        context=ctx,
        sinks=[sink, metrics])
    pipeline.subscribe_source(source, topic="tilt-series")
    obs = None
    if args.obs_port is not None:
        obs = pipeline.serve_observability(("127.0.0.1", args.obs_port))
        print(f"observability endpoint: {obs.url}")

    t0 = time.time()
    pipeline.run_until_drained()
    dt = time.time() - t0
    if obs is not None:
        spans = pipeline.streaming.traces.last()
        stages = pipeline.streaming.traces.stage_totals()
        top = max(stages, key=stages.get) if stages else "-"
        print(f"observability: {len(spans)} batch spans at {obs.url}/traces; "
              f"slowest stage: {top} ({stages.get(top, 0.0):.3f}s)")
        pipeline.close()       # stops the endpoint with the lanes

    # step 4: gather sub-volumes from the checkpoint store + render
    recon = np.zeros((args.nslice, args.nray, args.nray), np.float32)
    for key in sink.keys_on_disk():
        with np.load(sink.path_for(key)) as z:
            recon[z["idx"]] = z["block"]
    r = residual(recon, sino, cfg)
    err = np.linalg.norm(recon - vol_true) / np.linalg.norm(vol_true)
    rep = metrics.report()
    print(f"ART: {args.nslice} slices x {args.nray}^2, "
          f"{args.angles} angles, {args.iterations} sweeps "
          f"on {args.partitions} partitions: {dt:.1f}s "
          f"({rep['batches']} micro-batches, "
          f"{rep['throughput_rec_per_s']:.1f} slices/s)")
    print(f"sinogram residual {r:.3f}; volume rel. error {err:.3f}")
    print(f"scheduler metrics: {ctx.scheduler.metrics}")
    print(f"sub-volume artifacts: {sink.keys_on_disk()}")
    paths = render_volume(recon, args.out)
    print("artifacts:", paths)


if __name__ == "__main__":
    main()
