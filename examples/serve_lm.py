"""Serve a small LM with batched requests through the streaming pipeline.

Requests land on a broker topic; micro-batches prefill once and decode
greedily with the KV cache; the report compares per-batch latency to the
batch interval (the paper's near-real-time criterion applied to serving).

Run:  PYTHONPATH=src python examples/serve_lm.py --requests 12 --gen 24
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import serve


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()
    sys.argv = ["serve", "--arch", args.arch, "--reduced",
                "--requests", str(args.requests), "--batch", str(args.batch),
                "--prompt-len", str(args.prompt_len), "--gen", str(args.gen)]
    serve.main()


if __name__ == "__main__":
    main()
