"""Broker HA end to end: SIGKILL the primary mid-stream, keep producing.

The crash the replication layer exists for (``docs/replication.md``), run
as a demo: a producer streams numbered records through a ``FailoverBroker``
while the durable primary — a separate OS process — is SIGKILLed halfway.
The standby ``ReplicaFollower`` (which has been pulling the primary's CRC
frames all along) is promoted at a fenced epoch, the client re-sends its
unconfirmed tail, and the stream resumes. At the end the record set read
back from the promoted broker must cover *every* produced record — the
at-least-once contract: nothing committed is lost, duplicates collapse
under idempotent-by-key consumption (here, a ``set``). The killed primary
is then restarted on its old log to show the zombie getting fenced.

Run:  PYTHONPATH=src python examples/ha_failover.py --batches 60
"""
import argparse
import multiprocessing as mp
import os
import shutil
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def primary_main(root: str, sock: str) -> None:
    """Primary process: durable broker served on a Unix socket."""
    import threading

    from repro.core import Broker
    from repro.core.broker import COMMIT_TOPIC
    from repro.data import DurableLogFactory, serve_broker

    factory = DurableLogFactory(root)
    broker = Broker(log_factory=factory, commit_topic=COMMIT_TOPIC)
    factory.restore(broker)                # a restarted zombie reopens its log
    broker.restore_commits()
    serve_broker(broker, sock)
    print(f"[primary pid={os.getpid()}] serving {sock}", flush=True)
    threading.Event().wait()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batches", type=int, default=60)
    ap.add_argument("--batch", type=int, default=50, help="records per batch")
    args = ap.parse_args()

    from repro.core.broker import BrokerFencedError, OffsetRange
    from repro.data import FailoverBroker, RemoteBroker, ReplicaFollower

    work = tempfile.mkdtemp(prefix="ha-failover-")
    psock = os.path.join(work, "p.sock")
    proc = mp.get_context("spawn").Process(
        target=primary_main, args=(os.path.join(work, "primary"), psock),
        name="primary-broker")
    proc.start()
    while not os.path.exists(psock):
        time.sleep(0.01)

    follower = ReplicaFollower(psock, os.path.join(work, "replica"),
                               poll_interval=0.005)
    standby = follower.serve(os.path.join(work, "f.sock"))
    follower.start()

    client = FailoverBroker([psock, standby])
    client.create_topic("t", 2)
    kill_at = args.batches // 2
    t0 = time.perf_counter()
    for n in range(args.batches):
        if n == kill_at:
            proc.kill()                    # SIGKILL, mid-stream, no goodbye
            print(f"[client] SIGKILLed the primary before batch {n}")
        client.produce_many(
            "t", [(None, n * args.batch + i) for i in range(args.batch)],
            partition=n % 2)
    wall = time.perf_counter() - t0
    assert client.flush(timeout=30.0), "replica never caught up"
    proc.join(timeout=10)

    # every produced record must be readable from the promoted broker;
    # resend duplicates collapse in the set (the idempotent-sink stand-in)
    seen: set[int] = set()
    for p in range(2):
        end = client.end_offset("t", p)
        for rec in client.read(OffsetRange("t", p, 0, end)):
            seen.add(rec.value)
    produced = args.batches * args.batch
    missing = set(range(produced)) - seen
    assert not missing, f"lost committed records: {sorted(missing)[:10]}"
    dup = (sum(client.end_offsets("t")) - produced)
    print(f"[client] {args.batches} batches x {args.batch} records in "
          f"{wall:.2f}s across the kill; {client.failovers} failover to "
          f"epoch {client.epoch}; all {produced} records survived "
          f"({dup} duplicate{'s' if dup != 1 else ''} from the resend "
          f"window, absorbed by the set)")

    # restart the dead primary on its old log: it comes back writable at
    # epoch 0, i.e. a zombie — fence it and show a direct write bouncing
    os.unlink(psock)                       # SIGKILL left the socket file
    zombie = mp.get_context("spawn").Process(
        target=primary_main, args=(os.path.join(work, "primary"), psock),
        name="zombie-primary")
    zombie.start()
    while not os.path.exists(psock):
        time.sleep(0.01)
    time.sleep(0.1)
    fenced = client.fence_stale()
    direct = RemoteBroker(psock)
    try:
        direct.produce("t", -1, partition=0)
        raise SystemExit("zombie accepted a write — fencing is broken")
    except BrokerFencedError as e:
        print(f"[client] zombie primary fenced ({len(fenced)} broker): {e}")
    finally:
        direct.close()
    client.produce("t", produced, partition=0)   # real primary still writable

    client.close()
    follower.stop()
    zombie.kill()
    zombie.join(timeout=10)
    shutil.rmtree(work, ignore_errors=True)
    print("ha failover complete: primary SIGKILLed, follower promoted, "
          "stream resumed, zombie fenced — no committed record lost")


if __name__ == "__main__":
    main()
