"""End-to-end near-real-time ptychography pipeline (paper §III, Figs. 7-10).

The full Spark-MPI loop, on the data subsystem:
  DetectorSource (frame simulator at the acquisition rate)
     --> broker topic --> StreamingContext micro-batches
     --> RAAR reconstruction on accumulated frames (the "MPI application":
         modulus + overlap + combine, Pallas kernels; partial sums psum
         across the worker mesh when world > 1)
     --> sinks: NpzDirectorySink artifacts + MetricsSink latency accounting
         + final phase image (Fig. 10)

No hand-rolled producer thread and no direct ``broker.produce`` calls: the
pipeline pulls the detector through ``subscribe_source`` and pushes results
through idempotent keyed sinks.

The paper's near-real-time criterion: 512 frames arrive in ~25 s; the
pipeline reports whether reconstruction kept pace.

Sinks ride the parallel delivery runtime: the NPZ artifact store gets its
own lane (retry x2, bounded queue) so a slow disk cannot stall the batch
loop, and per-lane depth/latency counters print next to the MetricsSink
report. With ``--elastic`` the detector is pumped by a threaded IngestRunner
and a LagPolicy watches its backpressure lag, growing an ElasticController's
worker set when reconstruction falls behind the acquisition rate and
handing the pipeline the re-formed mesh. This demos the control loop
(signal -> policy -> controller -> new mesh) on virtual devices; the RAAR
step itself stays single-device, so scale events change the mesh, not the
reconstruction speed.

With ``--restart`` the example demos the restart-safe windowed state path
instead: detector frames land in a durable-log broker, reconstruction runs
per *window* of frames (``NearRealTimePipeline(window=..., window_state=
DurableStateStore(...))``), and the consumer is SIGKILLed mid-window. The
resumed run restores the open window atomically with the consumed offsets
and must produce the exact per-window reconstruction set an uncrashed run
produces — no frame lost off the open window, none duplicated.

Run:  PYTHONPATH=src python examples/ptycho_pipeline.py \
          --frames 512 --obj-size 256 --probe-size 64 --final-iters 60
(defaults are a few-minute CPU run; --fast shrinks everything)
"""
import argparse
import json
import multiprocessing
import os
import shutil
import signal
import sys
import time

# the elastic demo grows the worker set: give XLA virtual devices to grow
# into (must be set before jax initializes)
if "--elastic" in sys.argv and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.apps.ptycho.sim import simulate
from repro.apps.ptycho.solver import (SolverConfig, init_waves, raar_step,
                                      reconstruction_quality)
from repro.apps.tomo.render import render_phase
from repro.core import (Broker, ElasticController, LagPolicy,
                        NearRealTimePipeline, PipelineConfig)
from repro.data import (DetectorSource, DurableLogFactory, DurableStateStore,
                        IngestConfig, IngestRunner, MetricsSink,
                        NpzDirectorySink, SinkPolicy, WindowSpec)


def _restart_consume(root: str, sim_args: tuple, n_frames: int, window: int,
                     batch: int, iters: int, sleep_s: float = 0.0) -> None:
    """Consumer half of the ``--restart`` demo: windowed RAAR over a durable
    broker with restart-safe window state. Run once in a child (killed
    mid-window), then again in-process to resume from the checkpoint."""
    problem = simulate(*sim_args)
    positions = jnp.asarray(problem.positions)
    probe = jnp.asarray(problem.probe_true)
    obj_shape = problem.object_true.shape
    cfg = SolverConfig(beta=0.75, iterations=iters, use_pallas=False)

    factory = DurableLogFactory(os.path.join(root, "wal"))
    broker = Broker(log_factory=factory)
    factory.restore(broker)                # reopen the on-disk frame log
    sink = NpzDirectorySink(os.path.join(root, "windows"))

    def process(frame_ids, winfo, bridge):
        ids = np.asarray(sorted(frame_ids))
        mags = problem.magnitudes[ids]
        psi, pr = init_waves(mags, probe), probe
        for it in range(iters):
            psi, obj, pr, err = raar_step(psi, mags, positions[ids], pr,
                                          obj_shape, cfg, it)
        tag = "partial-" if winfo.partial else ""
        print(f"  window {tag}{winfo.index}: frames "
              f"[{ids[0]}..{ids[-1]}], fourier err {float(err):.4f}")
        return (f"win-{tag}{winfo.index:04d}",
                {"frames": ids, "fourier_err": np.float32(err)})

    pipeline = NearRealTimePipeline(
        broker,
        PipelineConfig(topics=("frames",), batch_interval=0.01,
                       max_records_per_partition=batch,
                       checkpoint_path=os.path.join(root, "ckpt.json")),
        process,
        window=WindowSpec(size=window),
        window_state=DurableStateStore(os.path.join(root, "wstate")),
        sinks=[sink])
    if sleep_s:                            # slow the batch loop so the
        pipeline.streaming.add_sink(       # parent can catch it mid-window
            lambda info: time.sleep(sleep_s))
    pipeline.run_until_drained(producer_done=lambda: True, idle_timeout=0.2)
    pipeline.flush_windows()     # partial window -> keyed sinks, THEN ckpt
    pipeline.close()


def run_restart_demo(args) -> None:
    root = os.path.join(args.out, "ptycho-restart")
    shutil.rmtree(root, ignore_errors=True)
    os.makedirs(root)
    sim_args = (args.obj_size, args.probe_size, args.scan_step)
    problem = simulate(*sim_args)
    n_frames = min(args.frames, problem.num_frames)
    window, batch = args.batch_frames, max(1, args.batch_frames // 3)
    print(f"restart demo: {n_frames} frames -> durable WAL, window {window}, "
          f"{batch} frames/batch")

    # produce the acquisition into the durable log (survives the kill)
    factory = DurableLogFactory(os.path.join(root, "wal"))
    producer = Broker(log_factory=factory)
    producer.create_topic("frames", 1)
    source = DetectorSource(problem, max_frames=n_frames)
    while not source.exhausted:
        producer.produce_many("frames", source.poll(64), partition=0)

    consume = (root, sim_args, n_frames, window, batch, args.iters_per_batch)
    proc = multiprocessing.get_context("spawn").Process(
        target=_restart_consume, args=consume + (0.3,), daemon=True)
    proc.start()
    ckpt = os.path.join(root, "ckpt.json")
    deadline = time.monotonic() + 300
    while time.monotonic() < deadline:
        if not proc.is_alive():
            raise SystemExit("consumer drained before it could be killed — "
                             "raise --frames")
        try:
            with open(ckpt) as f:
                consumed = sum(sum(v)
                               for v in json.load(f)["offsets"].values())
        except (OSError, ValueError, KeyError):
            consumed = 0
        if consumed > window and consumed % window != 0:
            os.kill(proc.pid, signal.SIGKILL)
            print(f"SIGKILL at {consumed} frames consumed "
                  f"({consumed % window} accumulated in the open window)")
            break
        time.sleep(0.01)
    else:
        proc.kill()
        raise SystemExit("never caught the consumer mid-window")
    proc.join(timeout=30)
    before = set(NpzDirectorySink(os.path.join(root, "windows"))
                 .keys_on_disk())
    print(f"windows on disk at crash: {sorted(before)}")

    print("resuming from the (offsets, window state) checkpoint ...")
    _restart_consume(*consume)

    sink = NpzDirectorySink(os.path.join(root, "windows"))
    got = {}
    for key in sink.keys_on_disk():
        with np.load(sink.path_for(key)) as z:
            got[key] = z["frames"].tolist()
    expect = {f"win-{k:04d}": list(range(k * window, (k + 1) * window))
              for k in range(n_frames // window)}
    if n_frames % window:
        k = n_frames // window
        expect[f"win-partial-{k:04d}"] = list(range(k * window, n_frames))
    if got != expect:
        raise SystemExit(f"MISMATCH after restart:\n  got {got}\n"
                         f"  want {expect}")
    print(f"restart OK: {len(got)} windows, identical reconstruction set "
          f"(no frame lost off the open window, none duplicated)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=512)
    ap.add_argument("--obj-size", type=int, default=256)
    ap.add_argument("--probe-size", type=int, default=64)
    ap.add_argument("--scan-step", type=int, default=12)
    ap.add_argument("--frame-interval", type=float, default=0.0,
                    help="seconds between produced frames (paper: 0.05)")
    ap.add_argument("--batch-frames", type=int, default=64)
    ap.add_argument("--iters-per-batch", type=int, default=6)
    ap.add_argument("--final-iters", type=int, default=60)
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--elastic", action="store_true",
                    help="threaded ingest + LagPolicy-driven elastic scaling")
    ap.add_argument("--restart", action="store_true",
                    help="SIGKILL mid-window + resume: restart-safe windowed "
                         "state demo (durable WAL + DurableStateStore)")
    ap.add_argument("--obs-port", type=int, default=None,
                    help="serve the observability endpoint (/metrics, "
                         "/metrics.json, /traces, /health) on this port "
                         "while the pipeline runs (0 = ephemeral port)")
    ap.add_argument("--out", default="out")
    args = ap.parse_args()
    if args.fast or args.restart:
        args.frames, args.obj_size, args.probe_size = 81, 96, 32
        args.scan_step, args.batch_frames = 8, 27
        args.final_iters, args.iters_per_batch = 30, 4
    if args.restart:
        run_restart_demo(args)
        return

    # ground truth + measurements (the detector)
    problem = simulate(args.obj_size, args.probe_size, args.scan_step)
    n_frames = min(args.frames, problem.num_frames)
    print(f"scan: {problem.num_frames} frames of "
          f"{problem.frame_shape}; streaming {n_frames}")

    source = DetectorSource(problem, max_frames=n_frames,
                            frame_interval=args.frame_interval)
    artifact_sink = NpzDirectorySink(os.path.join(args.out, "ptycho"))
    metrics = MetricsSink()

    # reconstruction state (solver warm-starts across micro-batches)
    cfg = SolverConfig(beta=0.75, iterations=args.final_iters,
                       use_pallas=False)
    positions_all = jnp.asarray(problem.positions)
    mags_all = problem.magnitudes
    probe = jnp.asarray(problem.probe_true)      # known probe mode to start
    state = {"probe": probe, "n_seen": 0, "psi": None, "obj": None,
             "iteration": 0, "errs": []}
    obj_shape = problem.object_true.shape
    step = jax.jit(lambda psi, mag, pos, probe, it: raar_step(
        psi, mag, pos, probe, obj_shape, cfg, it))

    def process(rdd, info, bridge):
        ids = sorted(rdd.collect())
        if not ids:
            return None
        n_new = state["n_seen"] + len(ids)
        mags = mags_all[:n_new]
        pos = positions_all[:n_new]
        if state["psi"] is None:
            psi = init_waves(mags, state["probe"])
        else:
            psi = jnp.concatenate(
                [state["psi"], init_waves(mags[state["n_seen"]:],
                                          state["probe"])])
        for _ in range(args.iters_per_batch):
            psi, obj, probe_new, err = step(psi, mags, pos, state["probe"],
                                            state["iteration"])
            state["probe"] = probe_new
            state["iteration"] += 1
        state.update(psi=psi, obj=obj, n_seen=n_new)
        state["errs"].append(float(err))
        print(f"  batch {info.index}: {n_new}/{n_frames} frames, "
              f"fourier err {float(err):.4f}, "
              f"proc {info.processing_time:.2f}s")
        # keyed result -> idempotent sink (replays overwrite, not duplicate)
        return [(f"batch-{info.index:06d}",
                 {"fourier_err": np.float32(err),
                  "frames_seen": np.int32(n_new)})]

    broker = Broker()
    if args.elastic:
        broker.create_topic("frames", 2)
    pipeline = NearRealTimePipeline(
        broker,
        PipelineConfig(topics=("frames",) if args.elastic else (),
                       batch_interval=0.05,
                       max_records_per_partition=args.batch_frames // 2,
                       source_partitions=2),
        process,
        # artifact store on its own delivery lane: a slow disk can no longer
        # stall the batch loop, and transient write errors retry twice
        sinks=[metrics, (artifact_sink, SinkPolicy.retry(2, queue_depth=32))])

    runner = controller = policy = None
    if args.elastic:
        # threaded ingest with block backpressure against consumed offsets;
        # LagPolicy grows the worker set when reconstruction falls behind
        controller = ElasticController(initial_workers=1)
        policy = LagPolicy(scale_up_lag=args.batch_frames // 2,
                           scale_down_lag=max(1, args.batch_frames // 8),
                           sustain=2, cooldown=0.5)
        runner = IngestRunner(broker, consumer=pipeline.streaming)
        runner.add(source, IngestConfig(
            topic="frames", partitions=2, policy="block",
            poll_batch=args.batch_frames,
            max_pending=4 * args.batch_frames))

        def drive_elastic(info):
            # on a scale event, hand the pipeline the re-formed mesh. The
            # RAAR step here stays single-device (process() ignores the
            # bridge), so this demo exercises the CONTROL loop — signal ->
            # policy -> controller -> new mesh — not parallel reconstruction.
            if policy.drive(controller, runner) != 0:
                pipeline.bridge = controller.bridge()

        pipeline.streaming.add_sink(drive_elastic)
        print(f"elastic: starting on {controller.world}/"
              f"{controller.max_workers} workers")
        runner.start()
    else:
        pipeline.subscribe_source(source, topic="frames")

    obs = None
    if args.obs_port is not None:
        # live while the stream runs: scrape /metrics mid-run, or watch
        # /health flip to degraded when reconstruction falls behind
        obs = pipeline.serve_observability(("127.0.0.1", args.obs_port),
                                           lag_policy=policy)
        print(f"observability endpoint: {obs.url}")

    t0 = time.time()
    report = pipeline.run_until_drained(
        producer_done=(lambda: runner.done) if runner else None)
    if runner is not None:
        runner.stop()
    obs_snap = obs_spans = None
    if obs is not None:        # fetch THROUGH the endpoint before close()
        import urllib.request  # stops it — this is the end-to-end demo
        with urllib.request.urlopen(obs.url + "/metrics.json") as r:
            obs_snap = json.load(r)
        with urllib.request.urlopen(obs.url + "/traces?last=1024") as r:
            obs_spans = json.load(r)["spans"]
    pipeline.close()           # drain the artifact lane: all batches on disk
    stream_time = time.time() - t0

    # refinement to convergence (the offline tail, paper Table II setup)
    psi, pos, mags = state["psi"], positions_all[:n_frames], \
        mags_all[:n_frames]
    probe = state["probe"]
    for it in range(args.final_iters):
        psi, obj, probe, err = step(psi, mags, pos, probe,
                                    state["iteration"] + it)
    total = time.time() - t0
    q = reconstruction_quality(obj, problem.object_true,
                               margin=args.probe_size // 2)
    # overwrite: the final object must track THIS run, not a previous one
    artifact_sink.write_batch([
        ("object-final", {"obj": np.asarray(obj),
                          "fourier_err": np.float32(err)})], overwrite=True)
    acq = 0.05 * n_frames
    rep = metrics.report()
    print(f"\nstreaming phase: {stream_time:.1f}s for {report.records} frames"
          f" ({rep['mean_latency_s']:.2f}s/batch, "
          f"{rep['throughput_rec_per_s']:.0f} rec/s)")
    print(f"total (incl. {args.final_iters} refinement iters): {total:.1f}s "
          f"vs paper acquisition window {acq:.0f}s "
          f"-> near-real-time: {total < acq}")
    for name, lane in pipeline.delivery_report().items():
        print(f"sink lane {name}: delivered {lane['delivered']}, "
              f"failed {lane['failed']}, retries {lane['retries']}, "
              f"max depth {lane['max_depth']}, "
              f"mean latency {lane.get('mean_latency_s', 0.0):.4f}s")
    if obs_spans:
        # the trace spans answer "which stage ate the time", per batch epoch
        stages: dict = {}
        for s in obs_spans:
            for k, v in s["stages"].items():
                stages[k] = stages.get(k, 0.0) + v
        span_total = max(sum(s["total_s"] for s in obs_spans), 1e-9)
        batch_vals = {m["name"]: m["value"] for m in obs_snap["metrics"]
                      if not m["labels"]}
        print(f"\nobservability: {len(obs_spans)} batch spans (epochs "
              f"{obs_spans[0]['epoch']}..{obs_spans[-1]['epoch']}), "
              f"{batch_vals.get('stream_records_total', 0):.0f} records via "
              f"{batch_vals.get('stream_batches_total', 0):.0f} batches; "
              f"per-stage time:")
        for k, v in sorted(stages.items(), key=lambda kv: -kv[1]):
            print(f"  {k:16s} {v:8.3f}s  ({100 * v / span_total:5.1f}%)")
    if args.elastic:
        shed = sum(m.dropped + m.sampled_out for m in runner.metrics)
        peak = max((o.lag for o in policy.history), default=0)
        print(f"elastic: peak consumer lag {peak} records, {shed} shed; "
              f"world {controller.world}/{controller.max_workers} after "
              f"{len(controller.events)} scale event(s)")
        for ev in controller.events:
            print(f"  gen {ev.generation}: {ev.reason} (world {ev.world})")
    print(f"final fourier error {float(err):.4f}, "
          f"phase correlation vs truth {q:.3f}")
    print(f"sink artifacts: {len(artifact_sink.keys_on_disk())} npz files "
          f"in {artifact_sink.directory}")
    paths = render_phase(np.asarray(obj), args.out)
    print("artifacts:", paths)


if __name__ == "__main__":
    main()
