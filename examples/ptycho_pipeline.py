"""End-to-end near-real-time ptychography pipeline (paper §III, Figs. 7-10).

The full Spark-MPI loop, on the data subsystem:
  DetectorSource (frame simulator at the acquisition rate)
     --> broker topic --> StreamingContext micro-batches
     --> RAAR reconstruction on accumulated frames (the "MPI application":
         modulus + overlap + combine, Pallas kernels; partial sums psum
         across the worker mesh when world > 1)
     --> sinks: NpzDirectorySink artifacts + MetricsSink latency accounting
         + final phase image (Fig. 10)

No hand-rolled producer thread and no direct ``broker.produce`` calls: the
pipeline pulls the detector through ``subscribe_source`` and pushes results
through idempotent keyed sinks.

The paper's near-real-time criterion: 512 frames arrive in ~25 s; the
pipeline reports whether reconstruction kept pace.

Run:  PYTHONPATH=src python examples/ptycho_pipeline.py \
          --frames 512 --obj-size 256 --probe-size 64 --final-iters 60
(defaults are a few-minute CPU run; --fast shrinks everything)
"""
import argparse
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.apps.ptycho.sim import simulate
from repro.apps.ptycho.solver import (SolverConfig, init_waves, raar_step,
                                      reconstruction_quality)
from repro.apps.tomo.render import render_phase
from repro.core import Broker, NearRealTimePipeline, PipelineConfig
from repro.data import DetectorSource, MetricsSink, NpzDirectorySink


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=512)
    ap.add_argument("--obj-size", type=int, default=256)
    ap.add_argument("--probe-size", type=int, default=64)
    ap.add_argument("--scan-step", type=int, default=12)
    ap.add_argument("--frame-interval", type=float, default=0.0,
                    help="seconds between produced frames (paper: 0.05)")
    ap.add_argument("--batch-frames", type=int, default=64)
    ap.add_argument("--iters-per-batch", type=int, default=6)
    ap.add_argument("--final-iters", type=int, default=60)
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--out", default="out")
    args = ap.parse_args()
    if args.fast:
        args.frames, args.obj_size, args.probe_size = 81, 96, 32
        args.scan_step, args.batch_frames = 8, 27
        args.final_iters, args.iters_per_batch = 30, 4

    # ground truth + measurements (the detector)
    problem = simulate(args.obj_size, args.probe_size, args.scan_step)
    n_frames = min(args.frames, problem.num_frames)
    print(f"scan: {problem.num_frames} frames of "
          f"{problem.frame_shape}; streaming {n_frames}")

    source = DetectorSource(problem, max_frames=n_frames,
                            frame_interval=args.frame_interval)
    artifact_sink = NpzDirectorySink(os.path.join(args.out, "ptycho"))
    metrics = MetricsSink()

    # reconstruction state (solver warm-starts across micro-batches)
    cfg = SolverConfig(beta=0.75, iterations=args.final_iters,
                       use_pallas=False)
    positions_all = jnp.asarray(problem.positions)
    mags_all = problem.magnitudes
    probe = jnp.asarray(problem.probe_true)      # known probe mode to start
    state = {"probe": probe, "n_seen": 0, "psi": None, "obj": None,
             "iteration": 0, "errs": []}
    obj_shape = problem.object_true.shape
    step = jax.jit(lambda psi, mag, pos, probe, it: raar_step(
        psi, mag, pos, probe, obj_shape, cfg, it))

    def process(rdd, info, bridge):
        ids = sorted(rdd.collect())
        if not ids:
            return None
        n_new = state["n_seen"] + len(ids)
        mags = mags_all[:n_new]
        pos = positions_all[:n_new]
        if state["psi"] is None:
            psi = init_waves(mags, state["probe"])
        else:
            psi = jnp.concatenate(
                [state["psi"], init_waves(mags[state["n_seen"]:],
                                          state["probe"])])
        for _ in range(args.iters_per_batch):
            psi, obj, probe_new, err = step(psi, mags, pos, state["probe"],
                                            state["iteration"])
            state["probe"] = probe_new
            state["iteration"] += 1
        state.update(psi=psi, obj=obj, n_seen=n_new)
        state["errs"].append(float(err))
        print(f"  batch {info.index}: {n_new}/{n_frames} frames, "
              f"fourier err {float(err):.4f}, "
              f"proc {info.processing_time:.2f}s")
        # keyed result -> idempotent sink (replays overwrite, not duplicate)
        return [(f"batch-{info.index:06d}",
                 {"fourier_err": np.float32(err),
                  "frames_seen": np.int32(n_new)})]

    pipeline = NearRealTimePipeline(
        Broker(),
        PipelineConfig(batch_interval=0.05,
                       max_records_per_partition=args.batch_frames // 2,
                       source_partitions=2),
        process,
        sinks=[artifact_sink, metrics])
    pipeline.subscribe_source(source, topic="frames")

    t0 = time.time()
    report = pipeline.run_until_drained()
    stream_time = time.time() - t0

    # refinement to convergence (the offline tail, paper Table II setup)
    psi, pos, mags = state["psi"], positions_all[:n_frames], \
        mags_all[:n_frames]
    probe = state["probe"]
    for it in range(args.final_iters):
        psi, obj, probe, err = step(psi, mags, pos, probe,
                                    state["iteration"] + it)
    total = time.time() - t0
    q = reconstruction_quality(obj, problem.object_true,
                               margin=args.probe_size // 2)
    # overwrite: the final object must track THIS run, not a previous one
    artifact_sink.write_batch([
        ("object-final", {"obj": np.asarray(obj),
                          "fourier_err": np.float32(err)})], overwrite=True)
    acq = 0.05 * n_frames
    rep = metrics.report()
    print(f"\nstreaming phase: {stream_time:.1f}s for {report.records} frames"
          f" ({rep['mean_latency_s']:.2f}s/batch, "
          f"{rep['throughput_rec_per_s']:.0f} rec/s)")
    print(f"total (incl. {args.final_iters} refinement iters): {total:.1f}s "
          f"vs paper acquisition window {acq:.0f}s "
          f"-> near-real-time: {total < acq}")
    print(f"final fourier error {float(err):.4f}, "
          f"phase correlation vs truth {q:.3f}")
    print(f"sink artifacts: {len(artifact_sink.keys_on_disk())} npz files "
          f"in {artifact_sink.directory}")
    paths = render_phase(np.asarray(obj), args.out)
    print("artifacts:", paths)


if __name__ == "__main__":
    main()
