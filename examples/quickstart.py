"""Quickstart: the Spark-MPI pattern in one page (paper Figs. 5-6).

Builds an RDD of per-worker arrays, then reduces it two ways:
  1. the Spark driver-worker path  (collect to driver, sum on driver);
  2. the Spark-MPI path            (in-place allreduce on the worker mesh).
Both give the same numbers; Table I of the paper (and
benchmarks/bench_allreduce.py here) quantifies why path 2 wins by ~100x on
a real fabric.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import Context, MPIBridge

N = 2_000_000                      # the paper's 2M-float payload


def main() -> None:
    ctx = Context()
    bridge = MPIBridge()           # one rank per local device
    world = bridge.world

    # Fig. 5/6: every rank holds arange(N) with a sentinel at the end
    def make_payload(rank: int) -> np.ndarray:
        buf = np.arange(N, dtype=np.float32)
        buf[-1] = 5.0
        return buf

    rdd = ctx.from_partitions([make_payload(r) for r in range(world)])

    # path 1: driver-worker (collect + sum on the driver)
    driver_sum = MPIBridge.driver_reduce(rdd)
    # path 2: Spark-MPI (MPI_Allreduce == psum on the mesh)
    mpi_sum = bridge.allreduce(rdd)

    print(f"world={world}")
    print(f"driver path : buffer[-1] = {driver_sum[-1]:.1f}")
    print(f"spark-mpi   : buffer[-1] = {np.asarray(mpi_sum)[-1]:.1f}")
    assert np.allclose(driver_sum, np.asarray(mpi_sum))
    print("identical results; see benchmarks/bench_allreduce.py for Table I")


if __name__ == "__main__":
    main()
