"""Benchmark harness: one entry per paper table/figure.

  Table I  -> bench_allreduce   (driver-worker vs in-place collectives)
  Table II -> bench_ptycho      (RAAR solver scaling)
  Fig. 16  -> bench_tomo        (ART scaling + TomViz baseline)
  Fig. 7-8 -> bench_streaming   (micro-batch pipeline overhead)
  §V       -> bench_ingest      (source->batch throughput + backpressure)

Prints ``name,us_per_call,derived`` CSV. Roofline numbers for the LM cells
come from the dry-run artifacts (launch/roofline.py), not from here.
"""
from __future__ import annotations

import traceback


def main() -> None:
    print("name,us_per_call,derived")
    from benchmarks import (bench_allreduce, bench_ingest, bench_ptycho,
                            bench_streaming, bench_tomo)
    for mod in (bench_allreduce, bench_ptycho, bench_tomo, bench_streaming,
                bench_ingest):
        try:
            mod.run()
        except Exception:
            print(f"{mod.__name__},nan,FAILED: "
                  + traceback.format_exc().strip().splitlines()[-1])


if __name__ == "__main__":
    main()
