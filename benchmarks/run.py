"""Benchmark harness: one entry per paper table/figure.

  Table I  -> bench_allreduce   (driver-worker vs in-place collectives)
  Table II -> bench_ptycho      (RAAR solver scaling)
  Fig. 16  -> bench_tomo        (ART scaling + TomViz baseline)
  Fig. 7-8 -> bench_streaming   (micro-batch pipeline overhead)
  §V       -> bench_ingest      (source->batch throughput + backpressure)

Prints ``name,us_per_call,derived`` CSV. Roofline numbers for the LM cells
come from the dry-run artifacts (launch/roofline.py), not from here.

``--check`` first runs the project invariant analyzer (``tools/analyze``,
exit 1 on findings — perf numbers from a tree violating the invariants
are not comparable), then only the regression guards: batched ``ingest/produce_many``
must beat per-record ``ingest/remote_transport`` on records/s, the
parallel delivery runtime (``ingest/fanout_parallel``) must beat serial
``fan_out`` by >= 2x wall-clock on the metrics path with one slow sink in
the fan, the durable window state store (``ingest/window_restore``)
must cost <= 1.3x the in-memory store per windowed batch, the metrics
registry (``ingest/obs_overhead``) must tax the instrumented ingest hot
path by <= 1.1x the registry-off run, four group consumers
(``ingest/group_scaleout``) must drain a 4-partition topic at >= 2x the
single-consumer rate, and a live broker replica
(``ingest/replication_overhead``) must tax the durable produce path by
<= 1.3x the unreplicated run, same-host shm frames
(``ingest/shm_fastpath``) must beat 'A'-frame produce by >= 5x on bulk
frames, and int8-codec ingest (``ingest/compressed_ingest``) must beat
raw ingest over a bandwidth-limited link by >= 2x (exit 1 on regression;
``make bench-check`` wires it into CI).
"""
from __future__ import annotations

import argparse
import os
import sys
import traceback


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--check", action="store_true",
                    help="regression guards only: batched produce beats "
                         "per-record produce, parallel fan-out beats serial "
                         "fan_out; exit 1 if not")
    ap.add_argument("--check-ratio", type=float, default=3.0,
                    help="minimum produce_many / remote_transport records/s "
                         "ratio for --check (default 3.0)")
    ap.add_argument("--check-fanout-ratio", type=float, default=2.0,
                    help="minimum serial/parallel fan-out wall-clock ratio "
                         "with one slow sink for --check (default 2.0)")
    ap.add_argument("--check-window-overhead", type=float, default=1.3,
                    help="maximum durable/in-memory window state store "
                         "per-batch cost ratio for --check (default 1.3)")
    ap.add_argument("--check-obs-overhead", type=float, default=1.1,
                    help="maximum instrumented/registry-off ingest "
                         "wall-clock ratio for --check (default 1.1)")
    ap.add_argument("--check-group-scaleout", type=float, default=2.0,
                    help="minimum 4-consumer/1-consumer group drain "
                         "throughput ratio for --check (default 2.0)")
    ap.add_argument("--check-replication-overhead", type=float, default=1.3,
                    help="maximum replicated/unreplicated durable produce "
                         "wall-clock ratio for --check (default 1.3)")
    ap.add_argument("--check-shm-ratio", type=float, default=5.0,
                    help="minimum shm/'A'-frame same-host bulk produce "
                         "wall-clock ratio for --check (default 5.0)")
    ap.add_argument("--check-codec-ratio", type=float, default=2.0,
                    help="minimum int8-codec/raw ingest wall-clock ratio "
                         "over a bandwidth-limited link for --check "
                         "(default 2.0)")
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    if args.check:
        # guard the guards: perf numbers from a tree that violates the
        # project invariants (docs/static_analysis.md) are not comparable
        from tools.analyze import run as analyze_run
        repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        findings = analyze_run([os.path.join(repo, "src"),
                                os.path.join(repo, "tests")], root=repo)
        for f in findings:
            print(f"analyze,nan,FAILED: {f.format()}")
        print(f"analyze,0,clean" if not findings
              else f"analyze,nan,{len(findings)} finding(s)")
        if findings:
            return 1
        from benchmarks import bench_ingest
        return 0 if bench_ingest.check(
            min_ratio=args.check_ratio,
            min_fanout_ratio=args.check_fanout_ratio,
            max_window_overhead=args.check_window_overhead,
            max_obs_overhead=args.check_obs_overhead,
            min_group_scaleout=args.check_group_scaleout,
            max_replication_overhead=args.check_replication_overhead,
            min_shm_ratio=args.check_shm_ratio,
            min_codec_ratio=args.check_codec_ratio) else 1

    from benchmarks import (bench_allreduce, bench_ingest, bench_ptycho,
                            bench_streaming, bench_tomo)
    for mod in (bench_allreduce, bench_ptycho, bench_tomo, bench_streaming,
                bench_ingest):
        try:
            mod.run()
        except Exception:
            print(f"{mod.__name__},nan,FAILED: "
                  + traceback.format_exc().strip().splitlines()[-1])
    return 0


if __name__ == "__main__":
    sys.exit(main())
