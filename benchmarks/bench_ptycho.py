"""Paper Table II: SHARP-NSLS2 ptychographic solver scaling (512 frames,
100 iterations; paper: 22.7 / 13.6 / 8.6 s on 1/2/4 K80 nodes).

Measured: RAAR iteration time on this CPU (reduced frames for tractability,
then scaled to the paper's 512×64² workload by FLOP ratio). Derived: the
v5e model — per-iteration FLOPs (2 FFTs + overlap products + combine per
frame) over peak, plus the two psum allreduces of the object/probe
numerators (paper Fig. 9) over ICI — for 1/2/4 chips, the Table II layout.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (HBM_BW, ICI_BW, PEAK_FLOPS,
                               allreduce_model_time, emit, time_call)


def _iteration_flops(frames: int, fsize: int, obj: int) -> float:
    fft = 2 * 5.0 * frames * fsize * fsize * np.log2(fsize * fsize)  # c2c x2
    elemwise = 40.0 * frames * fsize * fsize       # modulus+overlap+combine
    return fft + elemwise


def _iteration_bytes(frames: int, fsize: int, obj: int) -> float:
    # psi read/write ~6 passes of complex64 + object/probe canvases
    return 6.0 * frames * fsize * fsize * 8 + 4.0 * obj * obj * 8


def run(frames: int = 128, fsize: int = 32, iters: int = 10) -> None:
    import jax
    import jax.numpy as jnp
    from repro.apps.ptycho.sim import simulate
    from repro.apps.ptycho.solver import SolverConfig, init_waves, raar_step

    prob = simulate(obj_size=96, probe_size=fsize, step=8)
    n = min(frames, prob.num_frames)
    mags = prob.magnitudes[:n]
    pos = jnp.asarray(prob.positions[:n])
    cfg = SolverConfig(use_pallas=False)
    probe = jnp.asarray(prob.probe_true)
    psi = init_waves(mags, probe)
    obj_shape = prob.object_true.shape

    @jax.jit
    def one_iter(psi, probe):
        psi, obj, probe, err = raar_step(psi, mags, pos, probe, obj_shape,
                                         cfg, 3)
        return psi, probe

    psi, probe = one_iter(psi, probe)   # compile
    t = time_call(lambda: jax.block_until_ready(one_iter(psi, probe)),
                  repeats=3)
    emit("ptycho/raar_iter_cpu", t,
         f"measured: {n} frames of {fsize}^2 per iteration")

    # scale to the paper workload and derive the v5e Table II row
    paper_frames, paper_fsize, paper_iters = 512, 64, 100
    scale = (_iteration_flops(paper_frames, paper_fsize, 256)
             / _iteration_flops(n, fsize, 96))
    cpu_100 = t * scale * paper_iters
    emit("ptycho/100iter_512f_cpu_scaled", cpu_100,
         f"CPU-scaled paper workload (paper 1 node: 22.7s)")
    for chips in (1, 2, 4):
        fl = _iteration_flops(paper_frames // chips, paper_fsize, 256)
        by = _iteration_bytes(paper_frames // chips, paper_fsize, 256)
        # overlap allreduce: object+probe numerators+denominators, complex64
        ar_bytes = 256 * 256 * 12 + paper_fsize * paper_fsize * 12
        t_it = max(fl / PEAK_FLOPS, by / HBM_BW) + \
            allreduce_model_time(ar_bytes, chips, ICI_BW, latency=1e-6)
        emit(f"ptycho/model_{chips}chips_100iter", t_it * paper_iters,
             f"v5e roofline model (paper K80 row: "
             f"{ {1: 22.7, 2: 13.6, 4: 8.6}[chips] }s)")


if __name__ == "__main__":
    run()
