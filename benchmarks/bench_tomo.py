"""Paper Fig. 16: ART reconstruction time vs workers (+ the 6×-over-TomViz
claim).

Measured: (a) a TomViz-style pure-NumPy row loop (the paper's baseline),
(b) our jitted ART kernel path, both on one slice — the single-worker
speedup reproduces the paper's '6x improvement' claim class. Worker scaling
is measured through the RDD scheduler at 1/2/4 partitions (thread executors
on 1 core — scaling is derived for the TPU model where slices are
embarrassingly parallel, paper Fig. 16 shape).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import HBM_BW, emit, time_call


def tomviz_art(A: np.ndarray, b: np.ndarray, iters: int = 1,
               beta: float = 1.0) -> np.ndarray:
    """Paper Fig. 12 pseudocode, faithfully row-by-row in NumPy."""
    nrow, ncol = A.shape
    f = np.zeros(ncol, np.float32)
    rip = (A * A).sum(1)
    for _ in range(iters):
        for j in range(nrow):
            row = A[j]
            a = (b[j] - row @ f) / max(rip[j], 1e-12)
            f = f + row * a * beta
    return f


def run(nray: int = 32, angles: int = 19, nslice: int = 8) -> None:
    from repro.apps.tomo.projector import make_system
    from repro.apps.tomo.solver import (TomoConfig, reconstruct_slices,
                                        simulate_tilt_series)

    cfg = TomoConfig(nray=nray,
                     angles=tuple(np.linspace(-75, 75, angles).tolist()),
                     iterations=1, use_pallas=False)
    vol, sino = simulate_tilt_series(cfg, nslice)
    A = make_system(nray, np.asarray(cfg.angles))

    t_tomviz = time_call(lambda: tomviz_art(A, sino[0]), repeats=3)
    emit("tomo/tomviz_numpy_slice", t_tomviz,
         f"measured: {angles * nray} rows x {nray}^2, pure numpy")

    reconstruct_slices(sino[:1], cfg)  # compile
    t_ours = time_call(lambda: reconstruct_slices(sino[:1], cfg), repeats=3)
    emit("tomo/art_jax_slice", t_ours,
         f"measured: same slice, jitted ART; speedup x{t_tomviz / t_ours:.1f}"
         f" (paper claims 6x over TomViz)")

    for workers in (1, 2, 4):
        from repro.core import Context
        from repro.core.rdd import TaskScheduler
        ctx = Context(scheduler=TaskScheduler(num_executors=workers,
                                              speculation=False))
        rdd = ctx.parallelize([(i, sino[i]) for i in range(nslice)], workers)

        def job():
            rdd.map_partitions(
                lambda items: reconstruct_slices(
                    np.stack([b for _, b in items]), cfg)).collect_partitions()

        t = time_call(job, repeats=2)
        # embarrassingly parallel on real hardware: derived = t1 / workers
        emit(f"tomo/art_{workers}workers", t,
             f"measured on 1 core; ideal-scaling model: "
             f"{t_ours * nslice / workers:.4f}s")


if __name__ == "__main__":
    run()
