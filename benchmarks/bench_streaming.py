"""Paper Fig. 7-8: streaming micro-batch demo — broker -> per-topic RDDs ->
union -> collective job per batch.

Measures end-to-end micro-batch overhead (records/s through broker +
scheduler + union + a small allreduce per batch) and whether the pipeline
keeps up with the batch interval (the near-real-time criterion)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_call


def run(topics: int = 2, records: int = 400, batch: int = 40) -> None:
    from repro.core import Broker, Context, MPIBridge, StreamingContext

    broker = Broker()
    for t in range(topics):
        broker.create_topic(f"topic-{t}", partitions=1)
    for i in range(records):
        broker.produce(f"topic-{i % topics}", np.float32(i))

    ctx = Context()
    bridge = MPIBridge()
    sc = StreamingContext(ctx, broker, batch_interval=0.05,
                          max_records_per_partition=batch // topics)
    sc.subscribe([f"topic-{t}" for t in range(topics)])

    def on_batch(rdd, info):
        # the paper's allreduce.py applied to the micro-batch
        vals = np.asarray(rdd.collect(), dtype=np.float32)
        payload = np.tile(vals.sum(), 1024)
        part = ctx.from_partitions([payload] * bridge.world)
        return bridge.allreduce(part)

    sc.foreach_batch(on_batch)
    infos = sc.run_batches(max_batches=records // batch, wait_for_data=1.0)
    rep = sc.realtime_report()
    emit("streaming/per_batch", rep["mean_processing_s"],
         f"{rep['records']} records in {rep['batches']} batches; "
         f"throughput {rep['throughput_rec_per_s']:.0f} rec/s; "
         f"keeps_up={rep['keeps_up']}")


if __name__ == "__main__":
    run()
