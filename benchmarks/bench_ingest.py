"""Ingest path: source -> broker -> micro-batch throughput, and backpressure
behavior under overload (the near-real-time criterion stressed past its
breaking point instead of only at the happy path).

Four measurements:
  1. ingest/source_to_batch — raw records/s through SyntheticRateSource ->
     IngestRunner -> broker -> StreamingContext micro-batches.
  2. ingest/remote_transport — the same end-to-end path with every produce,
     offset query and commit crossing the socket transport (RemoteBroker ->
     BrokerServer over a Unix domain socket): the per-record cost of the
     multi-host topology vs. measurement 1's shared-memory baseline.
  3. ingest/backpressure_drop — a rate-limited (slow) pipeline fed ~10x over
     capacity with the drop policy: lag stays bounded, overload is shed.
  4. ingest/backpressure_sample — same overload with the sample policy: the
     stream thins (every k-th record survives) but stays ordered and bounded.
"""
from __future__ import annotations

import os
import tempfile
import time

from benchmarks.common import emit, time_call


def _throughput(records: int, batch: int) -> None:
    from repro.core import Broker, Context, StreamingContext
    from repro.data import IngestConfig, IngestRunner, SyntheticRateSource

    def once() -> None:
        broker = Broker()
        sc = StreamingContext(Context(), broker,
                              max_records_per_partition=batch // 2)
        runner = IngestRunner(broker, consumer=sc)
        src = SyntheticRateSource(rate=1e9, total=records)
        runner.add(src, IngestConfig(topic="t", partitions=2,
                                     poll_batch=batch))
        sc.subscribe(["t"])
        sc.foreach_batch(lambda rdd, info: rdd.count())
        runner.start()
        while not runner.done or sc.lag("t") > 0:
            if sc.run_one_batch() is None:
                time.sleep(0.0005)
        runner.stop()
        assert sum(b.num_records for b in sc.history) == records

    sec = time_call(once, repeats=3)
    emit("ingest/source_to_batch", sec / records,
         f"{records} records end-to-end in {sec:.3f}s; "
         f"throughput {records / sec:.0f} rec/s")


def _remote_throughput(records: int, batch: int) -> None:
    """Measurement 1 with the broker behind the socket transport: the ingest
    thread speaks RemoteBroker, the consumer commits after every batch, and
    backpressure lag is computed server-side from those commits."""
    from repro.core import Broker, Context, StreamingContext
    from repro.data import (IngestConfig, IngestRunner, RemoteBroker,
                            SyntheticRateSource, serve_broker)

    def once() -> None:
        path = os.path.join(tempfile.mkdtemp(prefix="bench-broker-"), "b.sock")
        broker = Broker()
        server = serve_broker(broker, path)
        remote = RemoteBroker(server.address)
        sc = StreamingContext(Context(), broker,
                              max_records_per_partition=batch // 2)
        runner = IngestRunner(remote, consumer=remote)
        src = SyntheticRateSource(rate=1e9, total=records)
        runner.add(src, IngestConfig(topic="t", partitions=2,
                                     poll_batch=batch, max_pending=4 * batch))
        sc.subscribe(["t"])
        sc.foreach_batch(lambda rdd, info: rdd.count())
        runner.start()
        while not runner.done or sc.lag("t") > 0:
            if sc.run_one_batch() is None:
                time.sleep(0.0005)
        runner.stop()
        remote.close()
        server.stop()
        os.unlink(path)
        assert sum(b.num_records for b in sc.history) == records

    sec = time_call(once, repeats=3)
    emit("ingest/remote_transport", sec / records,
         f"{records} records through the Unix-socket broker in {sec:.3f}s; "
         f"throughput {records / sec:.0f} rec/s")


def _backpressure(policy: str, records: int = 2000,
                  capacity_rec_s: float = 4000.0) -> None:
    """Overloaded pipeline: source produces ~10x what the consumer sustains.
    Graceful degradation = bounded lag + shed/thinned load, not an unbounded
    queue."""
    from repro.core import Broker, Context, StreamingContext
    from repro.data import IngestConfig, IngestRunner, SyntheticRateSource

    broker = Broker()
    per_batch = 32
    sc = StreamingContext(Context(), broker,
                          max_records_per_partition=per_batch)
    runner = IngestRunner(broker, consumer=sc)
    src = SyntheticRateSource(rate=1e9, total=records)
    cfg = IngestConfig(topic="t", policy=policy, max_pending=128,
                       poll_batch=64, sample_stride=8)
    m = runner.add(src, cfg)
    sc.subscribe(["t"])
    # consumer capacity: sleep to simulate per-batch processing cost
    sc.foreach_batch(lambda rdd, info:
                     time.sleep(per_batch / capacity_rec_s))
    t0 = time.perf_counter()
    runner.start()
    max_lag = 0
    while not runner.done or sc.lag("t") > 0:
        max_lag = max(max_lag, sc.lag("t"))
        if sc.run_one_batch() is None:
            time.sleep(0.0005)
    runner.stop()
    sec = time.perf_counter() - t0
    bound = cfg.max_pending + cfg.poll_batch
    shed = m.dropped + m.sampled_out
    emit(f"ingest/backpressure_{policy}", sec,
         f"{records} offered, {m.produced} delivered, {shed} shed; "
         f"max lag {max(max_lag, m.max_observed_lag)} (bound {bound}); "
         f"graceful={max(max_lag, m.max_observed_lag) <= bound and shed > 0}")


def run(records: int = 20000, batch: int = 200) -> None:
    _throughput(records, batch)
    _remote_throughput(records // 4, batch)
    _backpressure("drop")
    _backpressure("sample")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
