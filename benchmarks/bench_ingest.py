"""Ingest path: source -> broker -> micro-batch throughput, the transport
fast path, and backpressure behavior under overload (the near-real-time
criterion stressed past its breaking point instead of only at the happy
path).

Fifteen measurements:
  1. ingest/source_to_batch — raw records/s through SyntheticRateSource ->
     IngestRunner -> broker -> StreamingContext micro-batches (in-process).
  2. ingest/remote_transport — the same end-to-end path with every produce,
     offset query and commit crossing the socket transport (RemoteBroker ->
     BrokerServer over a Unix domain socket), *one round trip per record*
     (flush_records=1): the PR 2 baseline the fast path is measured against.
  3. ingest/produce_many — measurement 2 with batched produce: polled
     records flush through produce_many, one frame per batch. The regression
     guard (`benchmarks/run.py --check`, `make bench-check`) asserts this
     beats measurement 2 on records/s.
  4. ingest/zero_copy — batched produce with 64x64 float32 detector-style
     frames as values; array payloads cross the socket as raw-buffer array
     frames (no pickle of the bytes). The derived column compares the same
     workload with array frames disabled (every frame pickled).
  4b. ingest/shm_fastpath — measurement 4's workload pushed through the
     same-host shared-memory 'S' frames: bulk array bytes land in a
     server-owned /dev/shm segment and only a descriptor crosses the
     socket, skipping both socket copies and both CRC passes over the
     bulk. The regression guard asserts >= 5x the 'A'-frame records/s on
     large frames.
  4c. ingest/compressed_ingest — per-topic codecs under a simulated
     bandwidth-limited link (a token-bucket relay pacing producer->server
     bytes, the WAN the paper's detector streams cross): int8-codec'd
     float32 frames vs raw over the same choked link. The regression guard
     asserts >= 2x end-to-end ingest throughput at fixed link bandwidth.
  5. ingest/fanout_parallel — the output stage under a slow sink: N sinks,
     one of them 100x slower than the rest. Serial `fan_out` pays the slow
     sink inside the batch loop; the delivery runtime gives each sink its
     own lane, so the metrics path (time for every FAST sink to see every
     batch) collapses to the enqueue cost. The regression guard asserts the
     parallel metrics path beats serial fan_out by >= 2x wall-clock.
  6. ingest/elastic_scale — the elasticity loop under the same overload: a
     LagPolicy watches the runner's lag and drives a worker controller;
     reports time-to-first-scale-up and the up/down event counts (hysteresis
     means a handful of decisive events, not flapping).
  7. ingest/window_restore — restart-safe windowed state: per-batch overhead
     of the DurableStateStore (CRC-framed snapshot+delta log, committed
     atomically with the offset checkpoint) vs the in-memory store on the
     same windowed stream (guard: <= 1.3x), and a mid-stream kill+resume vs
     cold re-ingest of the whole stream.
  8. ingest/backpressure_drop — a rate-limited (slow) pipeline fed ~10x over
     capacity with the drop policy: lag stays bounded, overload is shed.
  9. ingest/backpressure_sample — same overload with the sample policy: the
     stream thins (every k-th record survives) but stays ordered and bounded.
  10. ingest/obs_overhead — the telemetry tax: the source_to_batch run with a
     live MetricsRegistry vs under metrics.disabled() (NullRegistry). The
     regression guard asserts instrumented <= 1.1x registry-off wall-clock.
  11. ingest/group_scaleout — consumer groups: records/s draining a
     4-partition topic with 1, 2 and 4 group consumers (threaded, GIL-free
     per-record work), plus the failover gap — wall-clock from one of two
     consumers going silent (no leave) to the survivor owning its
     partitions. The regression guard asserts 4 consumers >= 2x the
     single-consumer rate.
  12. ingest/replication_overhead — broker HA tax: produce_many batches
     paced at a fixed ingest cadence (the paper's pipelines are driven by
     a detector's frame rate, not socket saturation) against a durable
     Unix-socket primary with a live ReplicaFollower pulling CRC frames,
     vs the identical paced run with no follower deployed. Replication is
     asynchronous by design, so it must fit inside the cadence slack; any
     protocol that stalls the produce path (per-frame RPCs, reads holding
     the appender lock, unpaced pull loops) overruns the schedule and
     inflates the elapsed time. The regression guard asserts <= 1.3x.
  13. ingest/failover_gap — broker HA availability: a FailoverBroker
     producing batches against a subprocess primary that gets SIGKILLed
     mid-stream; the follower is promoted at a fenced epoch and the
     unconfirmed tail is resent. Reports the produce stall (longest
     inter-batch gap) and the batches it spans at the pre-kill cadence.
"""
from __future__ import annotations

import os
import tempfile
import time

from benchmarks.common import emit, time_call


def _source_to_batch_once(records: int, batch: int) -> None:
    """One in-process source -> ingest -> broker -> micro-batch drain (the
    hot path both measurement 1 and the obs-overhead guard time)."""
    from repro.core import Broker, Context, StreamingContext
    from repro.data import IngestConfig, IngestRunner, SyntheticRateSource

    broker = Broker()
    sc = StreamingContext(Context(), broker,
                          max_records_per_partition=batch // 2)
    runner = IngestRunner(broker, consumer=sc)
    src = SyntheticRateSource(rate=1e9, total=records)
    runner.add(src, IngestConfig(topic="t", partitions=2,
                                 poll_batch=batch))
    sc.subscribe(["t"])
    sc.foreach_batch(lambda rdd, info: rdd.count())
    runner.start()
    while not runner.done or sc.lag("t") > 0:
        if sc.run_one_batch() is None:
            time.sleep(0.0005)
    runner.stop()
    assert sum(b.num_records for b in sc.history) == records


def _throughput(records: int, batch: int) -> float:
    sec = time_call(lambda: _source_to_batch_once(records, batch), repeats=3)
    emit("ingest/source_to_batch", sec / records,
         f"{records} records end-to-end in {sec:.3f}s; "
         f"throughput {records / sec:.0f} rec/s")
    return records / sec


def _obs_overhead(records: int = 20000, batch: int = 200) -> float:
    """Measurement 10: the telemetry tax on the hot ingest path. The
    identical source->batch run with a live MetricsRegistry (every layer's
    counters/gauges/histograms registered and incremented) vs the same
    components constructed under ``metrics.disabled()`` (NullRegistry no-op
    instruments). Returns instrumented/bare wall-clock — the ``--check``
    guard asserts <= 1.1x, so telemetry can never silently tax the path."""
    from repro.data import metrics as M

    # interleave the legs and keep each one's best pass: the run is short
    # enough (~0.1s) that scheduler drift between two back-to-back blocks
    # would otherwise dominate the few-percent effect being measured
    t_on = t_off = float("inf")
    for _ in range(2):
        prev = M.set_registry(M.MetricsRegistry())
        try:
            t_on = min(t_on, time_call(
                lambda: _source_to_batch_once(records, batch), repeats=3))
        finally:
            M.set_registry(prev)
        with M.disabled():
            t_off = min(t_off, time_call(
                lambda: _source_to_batch_once(records, batch), repeats=3))
    ratio = t_on / t_off
    emit("ingest/obs_overhead", t_on / records,
         f"{records} records: instrumented {t_on:.3f}s "
         f"({records / t_on:.0f} rec/s) vs registry-off {t_off:.3f}s "
         f"({records / t_off:.0f} rec/s) = {ratio:.3f}x")
    return ratio


def _remote_once(records: int, batch: int, flush_records: int,
                 value_fn=None) -> None:
    """One end-to-end run with the broker behind the socket transport: the
    ingest thread speaks RemoteBroker, the consumer commits after every
    batch, and backpressure lag is computed server-side from those commits."""
    from repro.core import Broker, Context, StreamingContext
    from repro.data import (IngestConfig, IngestRunner, RemoteBroker,
                            SyntheticRateSource, serve_broker)

    path = os.path.join(tempfile.mkdtemp(prefix="bench-broker-"), "b.sock")
    broker = Broker()
    server = serve_broker(broker, path)
    remote = RemoteBroker(server.address)
    sc = StreamingContext(Context(), broker,
                          max_records_per_partition=batch // 2)
    runner = IngestRunner(remote, consumer=remote)
    src = SyntheticRateSource(rate=1e9, total=records, value_fn=value_fn)
    runner.add(src, IngestConfig(topic="t", partitions=2, poll_batch=batch,
                                 max_pending=4 * batch,
                                 flush_records=flush_records))
    sc.subscribe(["t"])
    sc.foreach_batch(lambda rdd, info: rdd.count())
    runner.start()
    while not runner.done or sc.lag("t") > 0:
        if sc.run_one_batch() is None:
            time.sleep(0.0005)
    runner.stop()
    remote.close()
    server.stop()
    os.unlink(path)
    assert sum(b.num_records for b in sc.history) == records


def _remote_throughput(records: int, batch: int) -> float:
    """Measurement 2: one produce round trip per record (PR 2 baseline)."""
    sec = time_call(lambda: _remote_once(records, batch, flush_records=1),
                    repeats=3)
    emit("ingest/remote_transport", sec / records,
         f"{records} records through the Unix-socket broker in {sec:.3f}s, "
         f"per-record produce; throughput {records / sec:.0f} rec/s")
    return records / sec


def _produce_many_throughput(records: int, batch: int) -> float:
    """Measurement 3: the batched fast path (one frame per flush)."""
    sec = time_call(lambda: _remote_once(records, batch, flush_records=batch),
                    repeats=3)
    emit("ingest/produce_many", sec / records,
         f"{records} records through the Unix-socket broker in {sec:.3f}s, "
         f"batched produce_many (flush={batch}); "
         f"throughput {records / sec:.0f} rec/s")
    return records / sec


def _zero_copy_once(records: int, batch: int, value_fn) -> None:
    """Producer-side hot path only: IngestRunner pumping ndarray payloads
    into a remote broker over the Unix socket, batched, no consumer — the
    transport cost of the detector stream in isolation (the consumer drain
    rate is an order of magnitude above it and would only add scheduling
    noise to the measurement)."""
    from repro.core import Broker
    from repro.data import (IngestConfig, IngestRunner, RemoteBroker,
                            SyntheticRateSource, serve_broker)

    path = os.path.join(tempfile.mkdtemp(prefix="bench-broker-"), "b.sock")
    broker = Broker()
    server = serve_broker(broker, path)
    remote = RemoteBroker(server.address)
    runner = IngestRunner(remote)       # no consumer: measure arrival rate
    src = SyntheticRateSource(rate=1e9, total=records, value_fn=value_fn)
    runner.add(src, IngestConfig(topic="t", partitions=2, poll_batch=batch,
                                 max_pending=1 << 30, flush_records=batch))
    runner.run_inline()
    remote.close()
    server.stop()
    os.unlink(path)
    assert sum(broker.end_offsets("t")) == records


def _zero_copy_throughput(records: int, batch: int, edge: int = 64) -> float:
    """Measurement 4: ndarray payloads; array frames on vs off."""
    import numpy as np

    import repro.data.transport as tr

    frame = np.random.default_rng(0).standard_normal(
        (edge, edge)).astype(np.float32)
    value_fn = frame.__mul__            # fresh array per record, same bytes
    mb = records * frame.nbytes / 1e6

    sec = time_call(lambda: _zero_copy_once(records, batch, value_fn),
                    repeats=3)
    saved = tr.USE_ARRAY_FRAMES
    tr.USE_ARRAY_FRAMES = False
    try:
        sec_pickle = time_call(
            lambda: _zero_copy_once(records, batch, value_fn), repeats=3)
    finally:
        tr.USE_ARRAY_FRAMES = saved
    emit("ingest/zero_copy", sec / records,
         f"{records} {edge}x{edge} f32 frames ({mb:.0f} MB) over the socket "
         f"in {sec:.3f}s ({mb / sec:.0f} MB/s, {records / sec:.0f} rec/s) vs "
         f"{sec_pickle:.3f}s pickled ({records / sec_pickle:.0f} rec/s); "
         f"array-frame speedup {sec_pickle / sec:.2f}x")
    return records / sec


class _DiscardLog:
    """PartitionLog that counts appends and retains nothing. The shm bench
    measures the produce path in isolation; an in-memory log would hold the
    zero-copy views decoded out of every 'S' frame, pinning each pooled
    segment forever and measuring the pool cap instead of the transport."""

    def __init__(self) -> None:
        self.n = 0

    def append(self, key, value, timestamp) -> int:
        self.n += 1
        return self.n - 1

    def read(self, start, until) -> list:
        return []

    def end_offset(self) -> int:
        return self.n


def _shm_once(records: int, frame, shm: bool) -> tuple[float, int]:
    """Seconds to push ``records`` one-frame produces through a Unix-socket
    broker, with the shared-memory fast path on or off. Returns
    ``(seconds, s_frames_sent)``."""
    from repro.core import Broker
    from repro.data import RemoteBroker, serve_broker

    path = os.path.join(tempfile.mkdtemp(prefix="bench-shm-"), "b.sock")
    broker = Broker(log_factory=_DiscardLog)
    server = serve_broker(broker, path)
    client = RemoteBroker(server.address, shm=shm)
    client.create_topic("t", 1)
    client.produce("t", (0, frame), partition=0)      # connect + negotiate
    t0 = time.perf_counter()
    for i in range(records):
        client.produce("t", (i, frame), partition=0)
    sec = time.perf_counter() - t0
    sent = client.shm_frames_sent
    assert broker.end_offsets("t") == [records + 1]
    client.close()
    server.stop()
    os.unlink(path)
    return sec, sent


def _shm_fastpath(records: int = 48, edge: int = 512) -> float:
    """Measurement 4b: large detector frames over 'A' frames vs 'S' frames
    on the same host. Returns the shm/array records-per-second ratio (the
    --check guard wants >= 5x). Frames are sized where the bulk bytes
    dominate — exactly the regime the shm path exists for; descriptor-sized
    payloads stay on the plain path anyway (``_send_shm`` needs buffers)."""
    import numpy as np

    frame = np.random.default_rng(0).standard_normal(
        (edge, edge)).astype(np.float32)
    mb = records * frame.nbytes / 1e6

    t_arr = t_shm = float("inf")
    for _ in range(3):                     # interleave legs, keep best pass
        sec, sent = _shm_once(records, frame, shm=False)
        assert sent == 0
        t_arr = min(t_arr, sec)
        sec, sent = _shm_once(records, frame, shm=True)
        assert sent == records + 1        # every produce rode an 'S' frame
        t_shm = min(t_shm, sec)
    ratio = t_arr / t_shm
    emit("ingest/shm_fastpath", t_shm / records,
         f"{records} {edge}x{edge} f32 frames ({mb:.0f} MB) same-host: "
         f"shm 'S' frames {t_shm:.3f}s ({mb / t_shm:.0f} MB/s) vs 'A' "
         f"frames {t_arr:.3f}s ({mb / t_arr:.0f} MB/s); speedup "
         f"{ratio:.1f}x")
    return ratio


class _ThrottledRelay:
    """Single-hop Unix-socket relay pacing client→server bytes with a token
    bucket — a same-host stand-in for the bandwidth-limited WAN the paper's
    detector streams cross (DELTA's KSTAR→NERSC link). Server→client acks
    flow unthrottled; they are not the constrained direction."""

    def __init__(self, upstream: str, path: str, bytes_per_s: float) -> None:
        self.upstream = upstream
        self.address = path
        self.rate = float(bytes_per_s)
        self._listener: "socket.socket | None" = None
        self._threads: list = []
        self._stop = False

    def start(self) -> "_ThrottledRelay":
        import socket
        import threading

        self._listener = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        self._listener.bind(self.address)
        self._listener.listen(4)
        t = threading.Thread(target=self._accept_loop, daemon=True)
        t.start()
        self._threads.append(t)
        return self

    def _accept_loop(self) -> None:
        import socket
        import threading

        while not self._stop:
            try:
                conn, _ = self._listener.accept()
            except OSError:
                return
            up = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            up.connect(self.upstream)
            for src, dst, rate in ((conn, up, self.rate), (up, conn, 0.0)):
                t = threading.Thread(target=self._pump,
                                     args=(src, dst, rate), daemon=True)
                t.start()
                self._threads.append(t)

    @staticmethod
    def _pump(src, dst, rate: float) -> None:
        import socket

        burst = 65536.0                    # one recv's worth of credit
        allowance, last = burst, time.perf_counter()
        try:
            while True:
                data = src.recv(65536)
                if not data:
                    break
                if rate > 0:
                    now = time.perf_counter()
                    allowance = min(burst, allowance + (now - last) * rate)
                    last = now
                    short = len(data) - allowance
                    if short > 0:
                        time.sleep(short / rate)
                        allowance = 0.0
                        last = time.perf_counter()
                    else:
                        allowance -= len(data)
                dst.sendall(data)
        except OSError:
            pass
        finally:
            for s in (src, dst):
                try:
                    s.shutdown(socket.SHUT_RDWR)
                except OSError:
                    pass

    def stop(self) -> None:
        self._stop = True
        if self._listener is not None:
            self._listener.close()


def _compressed_once(records: int, frame, codec: "str | None",
                     bytes_per_s: float) -> float:
    """Seconds for IngestRunner to push ``records`` float32 frames through
    the throttled relay into a served broker, with or without a per-topic
    codec encoding at the flush boundary."""
    import shutil

    from repro.core import Broker, OffsetRange
    from repro.data import (IngestConfig, IngestRunner, RemoteBroker,
                            SyntheticRateSource, serve_broker)

    work = tempfile.mkdtemp(prefix="bench-codec-")
    broker = Broker()
    server = serve_broker(broker, os.path.join(work, "b.sock"))
    relay = _ThrottledRelay(server.address, os.path.join(work, "relay.sock"),
                            bytes_per_s).start()
    # shm=False on purpose, twice over: the relay is same-host, so a
    # negotiated shm path would hand the bulk bytes around the simulated
    # link — and the WAN clients this models are never same-host anyway
    client = RemoteBroker(relay.address, shm=False)
    runner = IngestRunner(client)
    src = SyntheticRateSource(rate=1e9, total=records,
                              value_fn=frame.__mul__)
    cfg = IngestConfig(topic="t", partitions=1, poll_batch=16,
                       flush_records=16, max_pending=1 << 30, codec=codec)
    runner.add(src, cfg)
    t0 = time.perf_counter()
    runner.run_inline(timeout=120)
    sec = time.perf_counter() - t0
    assert broker.end_offsets("t") == [records]
    if codec:                              # values really travel encoded
        (rec,) = broker.read(OffsetRange("t", 0, 0, 1))
        assert isinstance(rec.value, dict) and rec.value["__codec__"] == codec
    client.close()
    relay.stop()
    server.stop()
    shutil.rmtree(work, ignore_errors=True)
    return sec


def _compressed_ingest(records: int = 600, edge: int = 64,
                       bytes_per_s: float = 24e6) -> float:
    """Measurement 4c: int8-codec'd vs raw ingest over a fixed simulated
    link bandwidth. Returns the raw/compressed wall-clock ratio (the
    --check guard wants >= 2x): int8 moves ~4x fewer bytes, so on a
    link-dominated path the ratio approaches the compression factor minus
    the quantization CPU."""
    import numpy as np

    frame = np.random.default_rng(0).standard_normal(
        (edge, edge)).astype(np.float32)
    mb = records * frame.nbytes / 1e6

    t_raw = t_codec = float("inf")
    for _ in range(3):                     # interleave legs, keep best pass
        t_raw = min(t_raw,
                    _compressed_once(records, frame, None, bytes_per_s))
        t_codec = min(t_codec,
                      _compressed_once(records, frame, "int8", bytes_per_s))
    ratio = t_raw / t_codec
    emit("ingest/compressed_ingest", t_codec / records,
         f"{records} {edge}x{edge} f32 frames ({mb:.0f} MB) over a "
         f"{bytes_per_s / 1e6:.0f} MB/s simulated link: int8 codec "
         f"{t_codec:.3f}s ({records / t_codec:.0f} rec/s) vs raw "
         f"{t_raw:.3f}s ({records / t_raw:.0f} rec/s); speedup {ratio:.1f}x")
    return ratio


def _fanout_batches(n_sinks: int, batches: int, slow_s: float):
    """Build the fan-out workload: n_sinks keyed sinks, the last one slow."""
    import time as _time

    class _Sink:
        def __init__(self, sleep: float = 0.0) -> None:
            self.sleep = sleep
            self.batches = 0

        def write_batch(self, items) -> int:
            if self.sleep:
                _time.sleep(self.sleep)
            self.batches += 1
            return len(items)

        def close(self) -> None:
            pass

    sinks = [_Sink() for _ in range(n_sinks - 1)] + [_Sink(sleep=slow_s)]
    items = [[(f"b{i:04d}-k{j}", j) for j in range(4)] for i in range(batches)]
    return sinks, items


def _fanout_serial(batches: int, n_sinks: int, slow_s: float) -> float:
    """Serial fan_out: the batch thread pays every sink, slow one included.
    Returns seconds until every FAST sink has seen every batch (= the whole
    loop: serially there is no way to finish the fast sinks early)."""
    from repro.data import fan_out

    sinks, items = _fanout_batches(n_sinks, batches, slow_s)
    write = fan_out(sinks)
    t0 = time.perf_counter()
    for batch in items:
        write(batch)
    return time.perf_counter() - t0


def _fanout_parallel(batches: int, n_sinks: int, slow_s: float) -> float:
    """Delivery runtime: per-sink lanes. Returns seconds until every FAST
    sink delivered every batch — the metrics-path latency; the slow lane
    keeps draining in the background and is settled by close()."""
    from repro.data import DeliveryRuntime, SinkPolicy

    sinks, items = _fanout_batches(n_sinks, batches, slow_s)
    runtime = DeliveryRuntime()
    lanes = [runtime.add_sink(s, SinkPolicy.skip_batch(queue_depth=batches),
                              name=f"sink-{i}") for i, s in enumerate(sinks)]
    fast = lanes[:-1]

    class _Info:
        def __init__(self, i: int, result) -> None:
            self.index, self.result = i, result

    t0 = time.perf_counter()
    for i, batch in enumerate(items):
        runtime.submit(_Info(i, batch))
    while any(lane.metrics.delivered < batches for lane in fast):
        time.sleep(0.0002)
    sec = time.perf_counter() - t0
    runtime.close(drain=True)
    assert all(s.batches == batches for s in sinks)   # nothing lost
    return sec


def _fanout_throughput(batches: int = 40, n_sinks: int = 4,
                       slow_s: float = 0.005) -> float:
    """Measurement 5: serial fan_out vs per-sink delivery lanes. Returns the
    serial/parallel wall-clock ratio on the metrics path."""
    serial = min(_fanout_serial(batches, n_sinks, slow_s) for _ in range(3))
    parallel = min(_fanout_parallel(batches, n_sinks, slow_s)
                   for _ in range(3))
    emit("ingest/fanout_parallel", parallel / batches,
         f"{batches} batches x {n_sinks} sinks (one sleeping {slow_s}s): "
         f"fast sinks complete in {parallel:.4f}s parallel vs "
         f"{serial:.3f}s serial fan_out; speedup {serial / parallel:.1f}x")
    return serial / parallel


def _elastic_scale(records: int = 2000, capacity_rec_s: float = 4000.0
                   ) -> None:
    """Measurement 6: overloaded pipeline with the elasticity loop closed —
    LagPolicy reads the runner's lag each batch and scales a (stub) worker
    controller; hysteresis should produce a few decisive events."""
    from repro.core import Broker, Context, LagPolicy, StreamingContext
    from repro.data import IngestConfig, IngestRunner, SyntheticRateSource

    class _Controller:
        def __init__(self) -> None:
            self.world, self.max_workers, self.calls = 1, 8, []

        def add_workers(self, n: int) -> None:
            self.world += n
            self.calls.append("add")

        def fail_workers(self, n: int) -> None:
            self.world -= n
            self.calls.append("fail")

    broker = Broker()
    per_batch = 32
    sc = StreamingContext(Context(), broker,
                          max_records_per_partition=per_batch)
    runner = IngestRunner(broker, consumer=sc)
    src = SyntheticRateSource(rate=1e9, total=records)
    runner.add(src, IngestConfig(topic="t", policy="block", max_pending=512,
                                 poll_batch=64))
    sc.subscribe(["t"])
    sc.foreach_batch(lambda rdd, info: time.sleep(per_batch / capacity_rec_s))
    ctl = _Controller()
    policy = LagPolicy(256, 32, sustain=2, cooldown=0.05)
    t0 = time.perf_counter()
    first_up = None
    runner.start()
    while not runner.done or sc.lag("t") > 0:
        if sc.run_one_batch() is None:
            time.sleep(0.0005)
        policy.drive(ctl, runner)
        if first_up is None and ctl.calls:
            first_up = time.perf_counter() - t0
    runner.stop()
    sec = time.perf_counter() - t0
    peak = max((o.lag for o in policy.history), default=0)
    emit("ingest/elastic_scale", sec,
         f"{records} records ~10x overloaded: peak lag {peak}, first "
         f"scale-up after {(first_up or sec) * 1e3:.0f}ms, "
         f"{ctl.calls.count('add')} up / {ctl.calls.count('fail')} down "
         f"events, final world {ctl.world}/8")


def _window_state_once(batch: int, size: int, store, ckpt_path: str,
                       broker, max_batches: int | None = None) -> int:
    """Drain a windowed stream over ``broker`` topic 'w' with the given
    window state store + checkpoint; returns batches run."""
    from repro.core import Context, StreamingContext
    from repro.data import WindowSpec, windowed

    sc = StreamingContext(Context(), broker, max_records_per_partition=batch,
                          checkpoint_path=ckpt_path)
    sc.subscribe(["w"])
    sc.foreach_batch(windowed(WindowSpec(size=size), lambda recs, wi: len(recs),
                              store=store))
    n = 0
    while sc.run_one_batch() is not None:
        n += 1
        if max_batches is not None and n >= max_batches:
            break
    return n


def _window_restore(records: int = 8000, batch: int = 200) -> float:
    """Measurement 7: restart-safe windowed state. (a) Per-batch overhead of
    DurableStateStore (snapshot+delta frames, atomic with the offset
    checkpoint) against InMemoryStateStore on the identical windowed stream
    — the regression guard asserts <= 1.3x; (b) killing the stream
    mid-window and resuming from the checkpoint vs cold re-ingest of the
    whole stream. Returns the overhead ratio."""
    import shutil

    from repro.core import Broker
    from repro.data import DurableStateStore, InMemoryStateStore

    def fill() -> "Broker":
        b = Broker()
        b.create_topic("w", 1)
        b.produce_many("w", [(None, i) for i in range(records)], partition=0)
        return b

    work = tempfile.mkdtemp(prefix="bench-wstate-")
    size = 2 * batch + batch // 2          # windows straddle batch boundaries
    batches = records // batch

    def timed(store_factory) -> float:
        def once() -> None:
            root = tempfile.mkdtemp(dir=work)
            store = store_factory(root)
            _window_state_once(batch, size, store,
                               os.path.join(root, "ckpt.json"), fill())
            store.close()
        return time_call(once, repeats=3)

    t_mem = timed(lambda root: InMemoryStateStore())
    t_dur = timed(
        lambda root: DurableStateStore(os.path.join(root, "state")))
    overhead = t_dur / t_mem

    # restart-and-resume: checkpoint mid-stream, 'crash', reopen, finish
    broker = fill()
    root = tempfile.mkdtemp(dir=work)
    ckpt = os.path.join(root, "ckpt.json")
    store = DurableStateStore(os.path.join(root, "state"))
    _window_state_once(batch, size, store, ckpt, broker,
                       max_batches=batches // 2)
    store.close()
    t0 = time.perf_counter()
    store = DurableStateStore(os.path.join(root, "state"))
    _window_state_once(batch, size, store, ckpt, broker)
    resume = time.perf_counter() - t0
    store.close()
    shutil.rmtree(work, ignore_errors=True)
    emit("ingest/window_restore", t_dur / batches,
         f"{batches} windowed batches x {batch} rec (window {size}): durable "
         f"state {t_dur:.3f}s vs in-memory {t_mem:.3f}s = "
         f"{overhead:.2f}x/batch; mid-stream restart resumes the remaining "
         f"half in {resume:.3f}s vs {t_dur:.3f}s cold re-ingest "
         f"({t_dur / max(resume, 1e-9):.1f}x)")
    return overhead


def _group_drain(consumers: int, per_part: int, work_s: float,
                 group: str = "bench") -> float:
    """Wall-clock for N threaded group consumers to drain a 4-partition
    topic, each record costing ``work_s`` of sleep (releases the GIL, so
    consumers genuinely overlap — the shape of a real per-record transform).
    """
    import threading

    from repro.core import Broker, Context, StreamingContext
    from repro.data import IngestConfig  # noqa: F401 (import parity)

    parts = 4
    broker = Broker()
    broker.create_topic("t", parts)
    for p in range(parts):
        broker.produce_many("t", [(None, i) for i in range(per_part)],
                            partition=p)
    ctxs = []
    for i in range(consumers):
        sc = StreamingContext(Context(), broker,
                              max_records_per_partition=25)
        sc.subscribe(["t"])
        sc.foreach_batch(lambda rdd, info: time.sleep(
            work_s * info.num_records))
        sc.join_group(group, consumer_id=f"c{i}", heartbeat_interval=0.05)
        ctxs.append(sc)
    for sc in ctxs:                        # settle before the clock starts
        sc.group_member.maintain(force=True)

    def drain(sc) -> None:
        while broker.lag("t", group=group) > 0:
            if sc.run_one_batch() is None:
                time.sleep(0.0005)

    threads = [threading.Thread(target=drain, args=(sc,)) for sc in ctxs]
    t0 = time.perf_counter()
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    sec = time.perf_counter() - t0
    assert broker.lag("t", group=group) == 0
    for sc in ctxs:
        sc.close()
    return sec


def _group_failover_gap(per_part: int = 2000, work_s: float = 0.0002,
                        session_timeout: float = 0.4) -> float:
    """Two group consumers; one goes silent mid-stream without leaving (a
    crash). Returns seconds from silence to the survivor owning all four
    partitions — the availability gap, bounded by the session timeout plus
    one heartbeat round."""
    import threading

    from repro.core import Broker, Context, StreamingContext

    broker = Broker()
    broker.create_topic("t", 4)
    for p in range(4):
        broker.produce_many("t", [(None, i) for i in range(per_part)],
                            partition=p)
    stop = {"dead": False}
    ctxs = []
    for i in range(2):
        sc = StreamingContext(Context(), broker,
                              max_records_per_partition=25)
        sc.subscribe(["t"])
        sc.foreach_batch(lambda rdd, info: time.sleep(
            work_s * info.num_records))
        sc.join_group("benchf", consumer_id=f"c{i}",
                      heartbeat_interval=0.05,
                      session_timeout=session_timeout)
        ctxs.append(sc)
    survivor, victim = ctxs
    survivor.group_member.maintain(force=True)

    def run(sc, is_victim: bool) -> None:
        while broker.lag("t", group="benchf") > 0:
            if is_victim and stop["dead"]:
                return                     # silent: no leave, no heartbeat
            if sc.run_one_batch() is None:
                time.sleep(0.0005)

    threads = [threading.Thread(target=run, args=(sc, sc is victim))
               for sc in ctxs]
    for th in threads:
        th.start()
    time.sleep(0.1)                        # both consuming
    stop["dead"] = True
    t0 = time.perf_counter()
    gap = None
    while time.perf_counter() - t0 < 30.0:
        owned = sum(len(ps) for ps in
                    survivor.group_member.assignment.values())
        if owned == 4:
            gap = time.perf_counter() - t0
            break
        time.sleep(0.002)
    for th in threads:
        th.join()
    for sc in ctxs:
        sc.close()
    return gap if gap is not None else float("inf")


def _group_scaleout(per_part: int = 600, work_s: float = 0.0002) -> float:
    """Measurement 11: group-consumer scale-out + failover gap. Returns the
    4-consumer/1-consumer throughput ratio (the --check guard wants >= 2x).
    """
    total = 4 * per_part
    rates = {}
    for n in (1, 2, 4):
        sec = min(_group_drain(n, per_part, work_s, group=f"bench{n}")
                  for _ in range(3))
        rates[n] = total / sec
    gap = _group_failover_gap()
    ratio = rates[4] / rates[1]
    emit("ingest/group_scaleout", 1.0 / rates[4],
         f"{total} records x {work_s * 1e6:.0f}us work: "
         f"{rates[1]:.0f} rec/s @1 consumer, {rates[2]:.0f} @2, "
         f"{rates[4]:.0f} @4 ({ratio:.1f}x); failover gap "
         f"{gap * 1e3:.0f}ms (session timeout 400ms)")
    return ratio


_FOLLOWER_PROC = """\
import sys, threading
from repro.data.replication import ReplicaFollower
psock, root, fsock = sys.argv[1], sys.argv[2], sys.argv[3]
# stock poll cadence; fsync off because this bench puts the follower on the
# *same disk* as the primary — its fsyncs would contend in the filesystem
# journal and charge the primary's produce path for an artifact a real
# deployment (follower on its own machine) never pays. The guard measures
# the replication protocol's tax, not the bench box's disk.
follower = ReplicaFollower(psock, root, fsync="never")
follower.serve(fsock)
follower.start()
print("ready", flush=True)
threading.Event().wait()
"""


def _subproc_env() -> dict:
    """Child env with the repo's ``src`` on PYTHONPATH (the bench may run
    from a checkout without an installed package)."""
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [src] + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    return env


def _replication_once(records: int, batch: int, replicated: bool,
                      interval: float) -> tuple[float, float]:
    """One cadence-paced produce run against a durable Unix-socket primary,
    all calls through FailoverBroker; with ``replicated`` a ReplicaFollower
    in its own process (as deployed — in-process it would share the GIL
    with the producer and inflate the tax ~2x) pulls committed CRC frames
    concurrently. The producer fires one batch every ``interval`` seconds
    on an absolute schedule (a late batch does not push later ones), the
    way a detector stream arrives at frame rate; the elapsed time equals
    the schedule length unless something stalls batches past the cadence
    slack for good. That is exactly the guard's contract — replication is
    asynchronous and must ride the slack — and it is also the only stable
    formulation on a small host: a saturating burst makes the follower's
    own CPU (CRC re-verify + append, inherently ~half the produce path's)
    compete for the same cores and measures the box, not the protocol.
    Returns ``(produce_seconds, drain_seconds)``: the paced loop the
    <= 1.3x guard protects, and the closing flush() waiting for replica
    high-watermarks to cover every produced offset (the window
    ``failover_gap`` would have to resend if the primary died right here).
    Setup/teardown are fixed per-deployment costs and stay untimed."""
    import shutil
    import subprocess
    import sys

    from repro.core import Broker
    from repro.core.broker import COMMIT_TOPIC
    from repro.data import FailoverBroker, serve_broker
    from repro.data.durable_log import DurableLogFactory

    work = tempfile.mkdtemp(prefix="bench-repl-")
    primary = Broker(log_factory=DurableLogFactory(os.path.join(work, "p")),
                     commit_topic=COMMIT_TOPIC)
    server = serve_broker(primary, os.path.join(work, "p.sock"))
    proc = None
    addrs = [server.address]
    if replicated:
        fsock = os.path.join(work, "f.sock")
        proc = subprocess.Popen(
            [sys.executable, "-c", _FOLLOWER_PROC, server.address,
             os.path.join(work, "f"), fsock],
            env=_subproc_env(), stdout=subprocess.PIPE, text=True)
        assert proc.stdout.readline().strip() == "ready"
        addrs.append(fsock)
        time.sleep(0.05)                   # let the first pull round settle
    client = FailoverBroker(addrs)
    client.create_topic("t", 2)
    pairs = [(None, i) for i in range(batch)]
    t0 = time.perf_counter()
    next_t = t0
    for i in range(records // batch):
        now = time.perf_counter()
        if now < next_t:
            time.sleep(next_t - now)
        client.produce_many("t", pairs, partition=i % 2)
        next_t += interval
    t_produce = time.perf_counter() - t0
    t0 = time.perf_counter()
    assert client.flush(timeout=30.0)
    t_drain = time.perf_counter() - t0
    assert sum(client.end_offsets("t")) == (records // batch) * batch
    client.close()
    if proc is not None:
        proc.kill()
        proc.wait()
    server.stop()
    shutil.rmtree(work, ignore_errors=True)
    return t_produce, t_drain


def _replication_overhead(records: int = 10000, batch: int = 200,
                          interval: float = 0.002) -> float:
    """Measurement 12: replicated vs unreplicated durable produce_many
    throughput at a fixed ingest cadence (``batch`` records every
    ``interval`` seconds — 100k rec/s at the defaults, roughly a third of
    this box's saturated durable rate, the kind of margin a real beamline
    deployment is provisioned with). Returns the replicated/plain elapsed
    ratio (the --check guard wants <= 1.3x). Sized so the run spans many
    follower poll rounds — shorter runs make the ratio a coin flip on
    whether a single pull lands mid-run."""
    # interleave the legs and keep each one's best pass: disk and scheduler
    # conditions drift on the tens-of-ms scale of one run, and back-to-back
    # blocks would hand one leg a systematically luckier window
    t_plain = t_repl = t_drain = float("inf")
    for _ in range(5):
        t_plain = min(t_plain,
                      _replication_once(records, batch, False, interval)[0])
        got = _replication_once(records, batch, True, interval)
        if got[0] < t_repl:
            t_repl, t_drain = got
    ratio = t_repl / t_plain
    emit("ingest/replication_overhead", t_repl / records,
         f"{records} records to a durable primary at a "
         f"{batch / interval:.0f} rec/s cadence: with a live follower "
         f"{t_repl:.3f}s ({records / t_repl:.0f} rec/s) vs unreplicated "
         f"{t_plain:.3f}s ({records / t_plain:.0f} rec/s) = {ratio:.2f}x; "
         f"replica fully caught up {t_drain * 1e3:.0f}ms after the last "
         f"ack")
    return ratio


_PRIMARY_PROC = """\
import sys
from repro.core import Broker
from repro.core.broker import COMMIT_TOPIC
from repro.data import serve_broker
from repro.data.durable_log import DurableLogFactory
root, sock = sys.argv[1], sys.argv[2]
factory = DurableLogFactory(root)
broker = Broker(log_factory=factory, commit_topic=COMMIT_TOPIC)
factory.restore(broker)
broker.restore_commits()
server = serve_broker(broker, sock)
print("ready", flush=True)
import threading
threading.Event().wait()
"""


def _failover_gap(batches: int = 120, batch: int = 50) -> float:
    """Measurement 13: SIGKILL the primary (a real subprocess) halfway
    through a batched produce stream; FailoverBroker promotes the follower
    at a fenced epoch and resends the unconfirmed window. Returns the
    longest inter-batch stall in seconds — the availability gap."""
    import shutil
    import subprocess
    import sys

    from repro.data import FailoverBroker, ReplicaFollower

    work = tempfile.mkdtemp(prefix="bench-failover-")
    psock = os.path.join(work, "p.sock")
    proc = subprocess.Popen(
        [sys.executable, "-c", _PRIMARY_PROC, os.path.join(work, "p"), psock],
        env=_subproc_env(), stdout=subprocess.PIPE, text=True)
    assert proc.stdout.readline().strip() == "ready"
    follower = ReplicaFollower(psock, os.path.join(work, "f"),
                               poll_interval=0.001)
    faddr = follower.serve(os.path.join(work, "f.sock"))
    follower.start()
    client = FailoverBroker([psock, faddr])
    client.create_topic("t", 2)
    pairs = [(None, i) for i in range(batch)]
    kill_at = batches // 2
    stamps = [time.perf_counter()]
    for i in range(batches):
        if i == kill_at:
            proc.kill()
            proc.wait()
        client.produce_many("t", pairs, partition=i % 2)
        stamps.append(time.perf_counter())
    assert client.flush(timeout=30.0)
    assert client.failovers == 1
    # resend of the unconfirmed window may duplicate already-replicated
    # batches (at-least-once), never lose them
    assert sum(client.end_offsets("t")) >= batches * batch
    client.close()
    follower.stop()
    shutil.rmtree(work, ignore_errors=True)
    deltas = [b - a for a, b in zip(stamps, stamps[1:])]
    gap = max(deltas)
    steady = sorted(deltas[:kill_at])[kill_at // 2]   # pre-kill median
    emit("ingest/failover_gap", gap,
         f"{batches} batches x {batch} rec, primary SIGKILLed at batch "
         f"{kill_at}: produce stalls {gap * 1e3:.0f}ms (~{gap / steady:.0f} "
         f"batches at the {steady * 1e3:.1f}ms pre-kill cadence), then the "
         f"promoted follower takes writes at epoch {client.epoch}")
    return gap


def _backpressure(policy: str, records: int = 2000,
                  capacity_rec_s: float = 4000.0) -> None:
    """Overloaded pipeline: source produces ~10x what the consumer sustains.
    Graceful degradation = bounded lag + shed/thinned load, not an unbounded
    queue."""
    from repro.core import Broker, Context, StreamingContext
    from repro.data import IngestConfig, IngestRunner, SyntheticRateSource

    broker = Broker()
    per_batch = 32
    sc = StreamingContext(Context(), broker,
                          max_records_per_partition=per_batch)
    runner = IngestRunner(broker, consumer=sc)
    src = SyntheticRateSource(rate=1e9, total=records)
    cfg = IngestConfig(topic="t", policy=policy, max_pending=128,
                       poll_batch=64, sample_stride=8)
    m = runner.add(src, cfg)
    sc.subscribe(["t"])
    # consumer capacity: sleep to simulate per-batch processing cost
    sc.foreach_batch(lambda rdd, info:
                     time.sleep(per_batch / capacity_rec_s))
    t0 = time.perf_counter()
    runner.start()
    max_lag = 0
    while not runner.done or sc.lag("t") > 0:
        max_lag = max(max_lag, sc.lag("t"))
        if sc.run_one_batch() is None:
            time.sleep(0.0005)
    runner.stop()
    sec = time.perf_counter() - t0
    bound = cfg.max_pending + cfg.poll_batch
    shed = m.dropped + m.sampled_out
    emit(f"ingest/backpressure_{policy}", sec,
         f"{records} offered, {m.produced} delivered, {shed} shed; "
         f"max lag {max(max_lag, m.max_observed_lag)} (bound {bound}); "
         f"graceful={max(max_lag, m.max_observed_lag) <= bound and shed > 0}")


def run(records: int = 20000, batch: int = 200) -> dict[str, float]:
    rates = {
        "ingest/source_to_batch": _throughput(records, batch),
        "ingest/remote_transport": _remote_throughput(records // 4, batch),
        "ingest/produce_many": _produce_many_throughput(records, batch),
        "ingest/zero_copy": _zero_copy_throughput(2000, batch),
        "ingest/shm_fastpath": _shm_fastpath(),
        "ingest/compressed_ingest": _compressed_ingest(),
        "ingest/fanout_parallel": _fanout_throughput(),
        "ingest/window_restore": _window_restore(),
        "ingest/obs_overhead": _obs_overhead(records, batch),
        "ingest/group_scaleout": _group_scaleout(),
        "ingest/replication_overhead": _replication_overhead(),
        "ingest/failover_gap": _failover_gap(),
    }
    _elastic_scale()
    _backpressure("drop")
    _backpressure("sample")
    return rates


def check(records: int = 8000, batch: int = 200, min_ratio: float = 3.0,
          min_fanout_ratio: float = 2.0,
          max_window_overhead: float = 1.3,
          max_obs_overhead: float = 1.1,
          min_group_scaleout: float = 2.0,
          max_replication_overhead: float = 1.3,
          min_shm_ratio: float = 5.0,
          min_codec_ratio: float = 2.0) -> bool:
    """Regression guards (`benchmarks/run.py --check`): batched produce_many
    must beat per-record produce on records/s by min_ratio, the parallel
    delivery runtime must beat serial fan_out on metrics-path wall-clock by
    min_fanout_ratio with one slow sink in the fan, the durable window
    state store must cost at most max_window_overhead x the in-memory store
    per windowed batch, the metrics registry must tax the ingest hot
    path by at most max_obs_overhead x the registry-off run, four group
    consumers must drain a 4-partition topic at >= min_group_scaleout x the
    single-consumer rate, and a live ReplicaFollower (plus the flush that
    waits for its high-watermarks) must cost at most
    max_replication_overhead x the unreplicated durable produce run,
    same-host shm 'S' frames must beat 'A' frames on bulk produce
    wall-clock by min_shm_ratio, and int8-codec ingest must beat raw
    ingest over a bandwidth-limited link by min_codec_ratio."""
    per_record = _remote_throughput(records // 4, batch)
    batched = _produce_many_throughput(records, batch)
    ratio = batched / per_record
    ok = ratio >= min_ratio
    print(f"# produce_many {batched:.0f} rec/s vs per-record "
          f"{per_record:.0f} rec/s = {ratio:.2f}x "
          f"(required >= {min_ratio}x): {'OK' if ok else 'REGRESSION'}")
    fan_ratio = _fanout_throughput()
    fan_ok = fan_ratio >= min_fanout_ratio
    print(f"# fanout_parallel metrics path {fan_ratio:.1f}x serial fan_out "
          f"with one slow sink (required >= {min_fanout_ratio}x): "
          f"{'OK' if fan_ok else 'REGRESSION'}")
    overhead = _window_restore(records, batch)
    w_ok = overhead <= max_window_overhead
    print(f"# durable window state {overhead:.2f}x in-memory per batch "
          f"(required <= {max_window_overhead}x): "
          f"{'OK' if w_ok else 'REGRESSION'}")
    obs = _obs_overhead(records, batch)
    obs_ok = obs <= max_obs_overhead
    print(f"# metrics registry {obs:.3f}x registry-off on the ingest hot "
          f"path (required <= {max_obs_overhead}x): "
          f"{'OK' if obs_ok else 'REGRESSION'}")
    scale = _group_scaleout()
    scale_ok = scale >= min_group_scaleout
    print(f"# group scale-out {scale:.1f}x throughput at 4 consumers vs 1 "
          f"(required >= {min_group_scaleout}x): "
          f"{'OK' if scale_ok else 'REGRESSION'}")
    repl = _replication_overhead()
    repl_ok = repl <= max_replication_overhead
    print(f"# replication {repl:.2f}x unreplicated durable produce "
          f"(required <= {max_replication_overhead}x): "
          f"{'OK' if repl_ok else 'REGRESSION'}")
    shm = _shm_fastpath()
    shm_ok = shm >= min_shm_ratio
    print(f"# shm fastpath {shm:.1f}x 'A'-frame produce on same-host bulk "
          f"frames (required >= {min_shm_ratio}x): "
          f"{'OK' if shm_ok else 'REGRESSION'}")
    codec = _compressed_ingest()
    codec_ok = codec >= min_codec_ratio
    print(f"# int8 codec ingest {codec:.1f}x raw over a 24 MB/s link "
          f"(required >= {min_codec_ratio}x): "
          f"{'OK' if codec_ok else 'REGRESSION'}")
    return (ok and fan_ok and w_ok and obs_ok and scale_ok and repl_ok
            and shm_ok and codec_ok)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
