"""Paper Table I: AllReduce — driver-worker vs Spark-MPI vs slow transport.

Measured on this container: the driver-collect path (threads + host sum)
and the psum path (8 virtual devices in a subprocess, exercising the real
shard_map collective program). Derived: the communication-model times for
2/4/8/10 nodes on the paper's three transports (Ethernet driver-worker,
InfiniBand MPI, Ethernet MPI) and on the TPU target (ICI psum) — the
apples-to-apples reproduction of Table I's shape: in-place collectives beat
driver funnels by ~2 orders of magnitude.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import numpy as np

from benchmarks.common import (ETHERNET_BW, IB_BW, ICI_BW, allreduce_model_time,
                               emit, gather_model_time, time_call)

N = 2_000_000          # paper payload: 2M float32
BYTES = N * 4

_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json, time
sys.path.insert(0, "src")
import numpy as np
from repro.core import Context, MPIBridge
ctx = Context()
bridge = MPIBridge()
parts = [np.arange(2_000_000, dtype=np.float32) for _ in range(bridge.world)]
rdd = ctx.from_partitions(parts)
bridge.allreduce(rdd)                      # warmup/compile
stacked = bridge._stack_partitions(rdd)
prog = bridge.spmd(lambda x: __import__("jax").lax.psum(x, "workers"))
prog(stacked)[0].block_until_ready()
times = []
for _ in range(5):
    t0 = time.perf_counter()
    prog(stacked)[0].block_until_ready()
    times.append(time.perf_counter() - t0)
print(json.dumps(sorted(times)[2]))
"""


def run() -> None:
    from repro.core import Context, MPIBridge

    ctx = Context()
    world = 8
    parts = [np.arange(N, dtype=np.float32) for _ in range(world)]
    rdd = ctx.from_partitions(parts)

    t_driver = time_call(lambda: MPIBridge.driver_reduce(rdd))
    emit("allreduce/driver_collect_8p_cpu", t_driver,
         "measured: collect+sum on driver, 8 partitions")

    out = subprocess.run([sys.executable, "-c", _SUBPROC],
                         capture_output=True, text=True,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    if out.returncode == 0:
        t_psum = json.loads(out.stdout.strip().splitlines()[-1])
        emit("allreduce/psum_8dev_cpu", t_psum,
             "measured: shard_map psum, 8 virtual devices")
    else:
        emit("allreduce/psum_8dev_cpu", float("nan"),
             "subprocess failed: " + out.stderr.strip()[-120:])

    # Table I reproduction via the communication model
    for n in (2, 4, 8, 10):
        t_spark = gather_model_time(BYTES, n, ETHERNET_BW) + N * n / 2e9
        t_mpi_ib = allreduce_model_time(BYTES, n, IB_BW)
        t_mpi_eth = allreduce_model_time(BYTES, n, ETHERNET_BW)
        t_tpu = allreduce_model_time(BYTES, n, ICI_BW, latency=1e-6)
        emit(f"allreduce/model_{n}nodes", t_mpi_ib,
             f"driver/eth={t_spark:.4f}s mpi/ib={t_mpi_ib:.4f}s "
             f"mpi/eth={t_mpi_eth:.4f}s tpu/ici={t_tpu:.6f}s "
             f"(paper: {dict([(2,(0.20,0.0036,0.07)),(4,(0.37,0.0049,0.14)),(8,(0.95,0.0060,0.31)),(10,(1.12,0.0097,0.36))])[n]})")


if __name__ == "__main__":
    run()
