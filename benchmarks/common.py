"""Benchmark harness plumbing: timing + the ``name,us_per_call,derived``
CSV contract + TPU roofline-model derivations (this container is CPU-only,
so every benchmark reports measured CPU time AND the v5e model time)."""
from __future__ import annotations

import time
from typing import Callable

# v5e-class constants (launch/mesh.py)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
ETHERNET_BW = 1.25e9        # 10 GbE, the paper's slow transport
IB_BW = 6.0e9               # FDR InfiniBand ~56 Gb/s, the paper's fast one


def time_call(fn: Callable[[], None], repeats: int = 5,
              warmup: int = 1) -> float:
    """Median wall time per call, seconds."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, seconds: float, derived: str = "") -> str:
    line = f"{name},{seconds * 1e6:.1f},{derived}"
    print(line)
    return line


def allreduce_model_time(bytes_total: int, n: int, bw: float,
                         latency: float = 20e-6) -> float:
    """Ring all-reduce: 2·b·(n-1)/n over the slowest link + per-step latency."""
    if n <= 1:
        return 0.0
    return 2 * bytes_total * (n - 1) / n / bw + 2 * (n - 1) * latency


def gather_model_time(bytes_total: int, n: int, bw: float,
                      latency: float = 20e-6) -> float:
    """Driver gather: all partitions funnel into one NIC, then host sum."""
    return n * bytes_total / bw + n * latency
