"""Sinks: idempotence under duplicated (at-least-once) batch delivery, and
the exactly-once upgrade end-to-end through NearRealTimePipeline."""
import os

import numpy as np
import pytest

from repro.core import (Broker, NearRealTimePipeline, PipelineConfig,
                        StreamingContext)
from repro.data import (CallbackSink, MetricsSink, NpzDirectorySink,
                        SyntheticRateSource, TopicSink, describe_result_items,
                        fan_out)


def test_npz_sink_idempotent_under_duplicate_delivery(tmp_path):
    sink = NpzDirectorySink(str(tmp_path / "artifacts"))
    items = [(f"k{i}", np.full((2, 2), i)) for i in range(4)]
    assert sink.write_batch(items) == 4
    assert sink.write_batch(items) == 0          # replayed batch: all skipped
    assert sink.written == 4 and sink.skipped == 4
    assert sink.keys_on_disk() == ["k0", "k1", "k2", "k3"]
    with np.load(sink.path_for("k2")) as z:
        np.testing.assert_array_equal(z["value"], np.full((2, 2), 2))


def test_npz_sink_idempotent_across_restart(tmp_path):
    d = str(tmp_path / "artifacts")
    NpzDirectorySink(d).write_batch([("a", np.arange(3))])
    sink2 = NpzDirectorySink(d)                   # fresh process, same dir
    assert sink2.write_batch([("a", np.arange(3)), ("b", np.arange(2))]) == 1
    assert sink2.keys_on_disk() == ["a", "b"]


def test_npz_sink_overwrite_tracks_latest(tmp_path):
    """overwrite=True bypasses dedupe for keys that must reflect the
    current run (final-result artifacts)."""
    d = str(tmp_path)
    NpzDirectorySink(d).write_batch([("final", np.asarray([1]))])
    sink2 = NpzDirectorySink(d)
    assert sink2.write_batch([("final", np.asarray([2]))]) == 0   # deduped
    assert sink2.write_batch([("final", np.asarray([2]))],
                             overwrite=True) == 1
    with np.load(sink2.path_for("final")) as z:
        np.testing.assert_array_equal(z["value"], [2])


def test_npz_sink_dict_and_scalar_values(tmp_path):
    sink = NpzDirectorySink(str(tmp_path))
    sink.write_batch([("d", {"x": np.arange(2), "y": np.arange(3)}),
                      ("s", 3.5)])
    with np.load(sink.path_for("d")) as z:
        assert set(z.files) == {"x", "y"}
    with np.load(sink.path_for("s")) as z:
        assert float(z["value"]) == 3.5


def test_topic_sink_chains_and_dedupes():
    broker = Broker()
    sink = TopicSink(broker, "downstream", partitions=2)
    items = [(f"k{i}", i * 10) for i in range(6)]
    assert sink.write_batch(items) == 6
    assert sink.write_batch(items) == 0
    assert sum(broker.end_offsets("downstream")) == 6   # no duplicates in log


def test_callback_and_metrics_sinks():
    seen = []
    cb = CallbackSink(lambda k, v: seen.append((k, v)))
    cb.write_batch([("a", 1), ("b", 2)])
    cb.write_batch([("b", 2), ("c", 3)])
    assert seen == [("a", 1), ("b", 2), ("c", 3)]

    m = MetricsSink()

    class Info:
        num_records, processing_time = 5, 0.01
    m.observe(Info())
    m.observe(Info())
    rep = m.report()
    assert rep["batches"] == 2 and rep["records"] == 10
    assert rep["throughput_rec_per_s"] == pytest.approx(10 / 0.02)


def test_fan_out_writes_all_sinks(tmp_path):
    npz = NpzDirectorySink(str(tmp_path))
    seen = []
    write = fan_out([npz, CallbackSink(lambda k, v: seen.append(k))])
    assert write([("a", np.arange(2))]) == 2      # one write per sink
    assert npz.keys_on_disk() == ["a"] and seen == ["a"]


def test_describe_result_items_normalization():
    assert describe_result_items(None, 3) == []
    assert describe_result_items([("k", 1), (b"j", 2)], 0) == \
        [("k", 1), ("j", 2)]
    assert describe_result_items(0.25, 7) == [("batch-000007", 0.25)]
    # a list that is NOT keyed items becomes a single batch-keyed item
    assert describe_result_items([1, 2, 3], 1) == [("batch-000001", [1, 2, 3])]


def _keyed_process(rdd, info, bridge):
    return [(f"rec-{v:04d}", np.asarray([v])) for v in rdd.collect()]


def test_pipeline_keyed_sinks_upgrade_replay_to_exactly_once(tmp_path):
    """At-least-once delivery duplicated on purpose: a second pipeline with
    no offset checkpoint re-processes the whole topic into the same sink
    directory. The keyed sink skips every duplicate — exactly-once storage."""
    broker = Broker()
    out = str(tmp_path / "out")
    metrics = MetricsSink()
    pipe = NearRealTimePipeline(
        broker,
        PipelineConfig(batch_interval=0.01, max_records_per_partition=4),
        _keyed_process,
        sources=[SyntheticRateSource(rate=1e9, total=12)],
        sinks=[NpzDirectorySink(out), metrics])
    topic = pipe.streaming._topics[0]
    report = pipe.run_until_drained()
    assert report.records == 12 and metrics.batches == report.batches
    expected = [f"rec-{v:04d}" for v in range(12)]

    # "restart" with a lost checkpoint: offsets reset to 0, every batch is
    # re-delivered; a fresh sink instance over the same directory dedupes.
    sink2 = NpzDirectorySink(out)
    pipe2 = NearRealTimePipeline(
        broker,
        PipelineConfig(topics=[topic], batch_interval=0.01,
                       max_records_per_partition=4),
        _keyed_process,
        sinks=[sink2])
    report2 = pipe2.run_until_drained(producer_done=lambda: True)
    assert report2.records == 12                  # duplicated delivery...
    assert sink2.written == 0 and sink2.skipped == 12   # ...zero new writes
    assert sink2.keys_on_disk() == expected
