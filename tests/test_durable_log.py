"""DurablePartitionLog: persistence, segment roll, recovery-scan truncation
of torn/corrupt tails, orphan handling, and a real SIGKILL mid-produce crash
(spawn-context child, like ``examples/remote_ingest.py``'s producer).

The recovery contract: whatever survives is a dense, garbage-free *prefix*
of what was appended — committed records never vanish behind later
corruption, torn bytes never surface as records.
"""
import glob
import multiprocessing as mp
import os
import signal
import time

import numpy as np
import pytest

from repro.core import (Broker, Context, OffsetRange, PartitionLog,
                        StreamingContext)
from repro.data.durable_log import (DurableLogFactory, DurablePartitionLog,
                                    LogCorruptionError)


def _seg_files(path):
    return sorted(glob.glob(os.path.join(path, "*.seg")))


# -- basics ------------------------------------------------------------------

def test_protocol_and_roundtrip(tmp_path):
    log = DurablePartitionLog(str(tmp_path / "p0"))
    assert isinstance(log, PartitionLog)
    assert log.end_offset() == 0
    assert log.append(b"k0", {"v": 0}, 1.5) == 0
    assert log.append(None, "plain", 2.5) == 1
    recs = log.read(0, 10)
    assert [(r.key, r.value, r.offset, r.timestamp) for r in recs] == \
        [(b"k0", {"v": 0}, 0, 1.5), (None, "plain", 1, 2.5)]
    assert log.read(1, 2)[0].value == "plain"
    log.close()


def test_reopen_recovers_records(tmp_path):
    path = str(tmp_path / "p0")
    with DurablePartitionLog(path) as log:
        for i in range(20):
            log.append(str(i).encode(), i, float(i))
    reopened = DurablePartitionLog(path)
    assert reopened.recovered_records == 20
    assert reopened.truncated_bytes == 0
    assert reopened.end_offset() == 20
    assert [r.value for r in reopened.read(0, 99)] == list(range(20))
    # appends continue the offset space after recovery
    assert reopened.append(None, "next", 0.0) == 20
    reopened.close()


def test_append_many_and_segment_roll(tmp_path):
    path = str(tmp_path / "p0")
    log = DurablePartitionLog(path, segment_bytes=512)
    offs = log.append_many([(None, f"value-{i:04d}") for i in range(40)], 1.0)
    assert offs == list(range(40))
    offs2 = log.append_many([(b"k", i) for i in range(40, 50)], 2.0)
    assert offs2 == list(range(40, 50))
    assert log.append_many([], 0.0) == []
    assert len(_seg_files(path)) > 1       # rolled past 512 bytes
    assert log.segments > 1
    vals = [r.value for r in log.read(0, 999)]
    assert vals == [f"value-{i:04d}" for i in range(40)] \
        + list(range(40, 50))              # reads span segments
    log.close()
    reopened = DurablePartitionLog(path, segment_bytes=512)
    assert reopened.end_offset() == 50
    assert [r.value for r in reopened.read(38, 42)] == \
        ["value-0038", "value-0039", 40, 41]
    reopened.close()


def test_ndarray_values_on_disk(tmp_path):
    """Values hit the segments in the transport's array-frame encoding and
    come back equal and writable."""
    path = str(tmp_path / "p0")
    frame = np.arange(64, dtype=np.float32).reshape(8, 8)
    with DurablePartitionLog(path) as log:
        log.append(b"f0", (0, frame), 0.0)
    with DurablePartitionLog(path) as log:
        (rec,) = log.read(0, 1)
        idx, got = rec.value
        np.testing.assert_array_equal(got, frame)
        assert got.flags.writeable


def test_oversized_record_refused_at_append(tmp_path, monkeypatch):
    """The recovery scan treats frames past MAX_FRAME_BYTES as corruption,
    so such a record must be refused at append time — committing it and
    destroying it (plus everything after) on the next open would be worse."""
    import repro.data.durable_log as dl

    monkeypatch.setattr(dl, "MAX_FRAME_BYTES", 1024)
    with DurablePartitionLog(str(tmp_path / "p0")) as log:
        log.append(None, "fits", 0.0)
        with pytest.raises(ValueError, match="exceeds"):
            log.append(None, "x" * 4096, 0.0)
        with pytest.raises(ValueError, match="exceeds"):
            log.append_many([(None, "small"), (None, "y" * 4096)], 0.0)
        assert log.end_offset() == 1       # nothing partial committed
    monkeypatch.undo()
    reopened = DurablePartitionLog(str(tmp_path / "p0"))
    assert reopened.end_offset() == 1      # and reopen keeps everything
    assert reopened.truncated_bytes == 0
    reopened.close()


def test_fsync_policies(tmp_path):
    for policy in ("always", "interval", "never"):
        with DurablePartitionLog(str(tmp_path / policy), fsync=policy) as log:
            assert log.append_many([(None, i) for i in range(5)], 0.0) == \
                list(range(5))
    with pytest.raises(ValueError):
        DurablePartitionLog(str(tmp_path / "bad"), fsync="sometimes")


# -- recovery: torn tails and corruption ------------------------------------

def test_torn_tail_truncated_on_open(tmp_path):
    path = str(tmp_path / "p0")
    with DurablePartitionLog(path) as log:
        for i in range(5):
            log.append(None, f"rec-{i}", 0.0)
    (seg,) = _seg_files(path)
    clean_size = os.path.getsize(seg)
    with open(seg, "ab") as f:             # a produce died mid-write
        f.write(b"\x00\x00\x00\x30TORN-FRAME-ONLY-PARTIALLY-WRIT")
    log = DurablePartitionLog(path)
    assert log.truncated_bytes > 0
    assert os.path.getsize(seg) == clean_size
    assert log.end_offset() == 5
    assert [r.value for r in log.read(0, 99)] == [f"rec-{i}" for i in range(5)]
    assert log.append(None, "after-recovery", 0.0) == 5
    log.close()


def test_bit_flip_truncates_to_valid_prefix(tmp_path):
    """A flipped bit mid-file costs the suffix, never correctness: the scan
    keeps every record before the corruption and nothing after."""
    path = str(tmp_path / "p0")
    with DurablePartitionLog(path) as log:
        for i in range(10):
            log.append(str(i).encode(), {"i": i, "pad": "x" * 50}, 0.0)
    (seg,) = _seg_files(path)
    blob = bytearray(open(seg, "rb").read())
    blob[len(blob) // 2] ^= 0x10
    with open(seg, "wb") as f:
        f.write(blob)
    log = DurablePartitionLog(path)
    n = log.end_offset()
    assert 0 < n < 10                      # prefix survived, suffix cut
    assert log.truncated_bytes > 0
    for r in log.read(0, n):               # and the prefix is pristine
        assert r.value == {"i": r.offset, "pad": "x" * 50}
        assert r.key == str(r.offset).encode()
    log.close()


def test_corrupt_early_segment_orphans_later_ones(tmp_path):
    """Offsets must stay dense: segments after a corrupt one cannot rejoin
    the log; they are set aside as .orphan, not silently re-entered."""
    path = str(tmp_path / "p0")
    with DurablePartitionLog(path, segment_bytes=256) as log:
        for i in range(30):
            log.append(None, f"value-{i:04d}", 0.0)
    segs = _seg_files(path)
    assert len(segs) >= 3
    blob = bytearray(open(segs[0], "rb").read())
    blob[len(blob) // 2] ^= 0x01
    with open(segs[0], "wb") as f:
        f.write(blob)
    log = DurablePartitionLog(path, segment_bytes=256)
    n = log.end_offset()
    assert 0 < n < 30
    assert log.orphaned_segments == len(segs) - 1
    assert glob.glob(os.path.join(path, "*.orphan*"))
    assert [r.value for r in log.read(0, n)] == \
        [f"value-{i:04d}" for i in range(n)]
    # appends land after the recovered prefix and survive another reopen
    log.append(None, "post", 0.0)
    log.close()
    reopened = DurablePartitionLog(path, segment_bytes=256)
    assert reopened.end_offset() == n + 1
    assert reopened.read(n, n + 1)[0].value == "post"
    reopened.close()


def test_read_detects_corruption_under_live_log(tmp_path):
    """Corruption that lands *after* recovery accepted a record surfaces as
    LogCorruptionError on read — never a garbage record."""
    path = str(tmp_path / "p0")
    log = DurablePartitionLog(path)
    log.append(None, "x" * 200, 0.0)
    (seg,) = _seg_files(path)
    with open(seg, "r+b") as f:
        f.seek(40)
        f.write(b"\xff")
    with pytest.raises(LogCorruptionError):
        log.read(0, 1)
    log.close()


# -- factory + broker restart ------------------------------------------------

def test_factory_maps_topic_partition_dirs(tmp_path):
    factory = DurableLogFactory(str(tmp_path / "wal"))
    broker = Broker(log_factory=factory)
    broker.create_topic("alpha", 2)
    broker.create_topic("beta")
    broker.produce("alpha", 1, partition=1)
    assert factory.topics_on_disk() == {"alpha": 2, "beta": 1}
    assert os.path.isdir(os.path.join(str(tmp_path / "wal"), "alpha", "p0001"))
    for evil in ("", "..", "a/b", "a\x00b"):
        with pytest.raises(ValueError):
            factory(topic=evil, partition=0)


def test_broker_restart_replays_to_fresh_subscriber(tmp_path):
    """The acceptance path: produce through a durable broker, 'restart' it
    (new Broker over the same root), and a fresh StreamingContext subscriber
    replays every record."""
    root = str(tmp_path / "wal")
    frame = np.arange(16, dtype=np.float32)
    b1 = Broker(log_factory=DurableLogFactory(root))
    b1.create_topic("frames", 2)
    b1.produce_many("frames", [(f"k{i}".encode(), (i, frame * i))
                               for i in range(9)], partition=0)
    for i in range(9, 12):
        b1.produce("frames", (i, frame * i), partition=1)

    factory = DurableLogFactory(root)      # the restarted process
    b2 = Broker(log_factory=factory)
    assert factory.restore(b2) == ["frames"]
    assert b2.end_offsets("frames") == [9, 3]

    sc = StreamingContext(Context(), b2, max_records_per_partition=4)
    sc.subscribe(["frames"])
    seen = []
    sc.foreach_batch(lambda rdd, info: seen.extend(rdd.collect()))
    while sc.lag("frames") > 0:
        sc.run_one_batch()
    assert sorted(i for i, _ in seen) == list(range(12))
    for i, arr in seen:
        np.testing.assert_array_equal(arr, frame * i)


# -- crash: SIGKILL mid-produce ----------------------------------------------

def _crash_producer(root: str) -> None:
    """Child process: append records as fast as possible until killed."""
    from repro.core import Broker as B
    from repro.data.durable_log import DurableLogFactory as F
    broker = B(log_factory=F(root, fsync="never"))
    broker.create_topic("t", 1)
    i = 0
    while True:
        broker.produce("t", {"i": i, "pad": "x" * 100},
                       key=str(i).encode(), timestamp=float(i))
        i += 1


def test_sigkill_mid_produce_keeps_committed_prefix(tmp_path):
    root = str(tmp_path / "wal")
    proc = mp.get_context("spawn").Process(target=_crash_producer,
                                           args=(root,), daemon=True)
    proc.start()
    seg = os.path.join(root, "t", "p0000", "00000000.seg")
    deadline = time.monotonic() + 60
    while time.monotonic() < deadline:
        if os.path.exists(seg) and os.path.getsize(seg) > 20_000:
            break
        time.sleep(0.01)
    else:
        proc.kill()
        pytest.fail("producer never wrote enough data")
    os.kill(proc.pid, signal.SIGKILL)      # no goodbye, mid-produce
    proc.join(timeout=30)

    factory = DurableLogFactory(root)
    broker = Broker(log_factory=factory)
    assert factory.restore(broker) == ["t"]
    n = broker.end_offset("t", 0)
    assert n > 50                          # committed records survived...
    recs = broker.read(OffsetRange("t", 0, 0, n))
    assert [r.value["i"] for r in recs] == list(range(n))   # ...densely...
    for r in recs:                         # ...and uncorrupted
        assert r.key == str(r.value["i"]).encode()
        assert r.value["pad"] == "x" * 100
        assert r.timestamp == float(r.value["i"])


def test_reads_do_not_hold_the_appender_lock_across_disk_io(tmp_path):
    """read() snapshots the index under the lock but does its segment-file
    I/O outside it: a reader parked mid-pread must not stall appends (the
    old implementation held the appender RLock across every disk read)."""
    import threading

    log = DurablePartitionLog(str(tmp_path / "p0"))
    for i in range(10):
        log.append(b"k", i, 0.0)
    gate, entered = threading.Event(), threading.Event()
    orig = log._pread

    def parked_pread(fd, nbytes, pos):
        entered.set()
        assert gate.wait(10)
        return orig(fd, nbytes, pos)

    log._pread = parked_pread
    out = {}
    reader = threading.Thread(
        target=lambda: out.setdefault("recs", log.read(0, 10)))
    reader.start()
    try:
        assert entered.wait(10)
        # the reader is blocked inside its disk read; appends must proceed
        assert log.append(b"k", 99, 0.0) == 10
        assert log.append_many([(b"k", 100)], 0.0) == [11]
        assert log.end_offset() == 12
    finally:
        gate.set()
        reader.join(10)
    assert [r.value for r in out["recs"]] == list(range(10))
    log.close()


def test_directory_fsync_on_segment_create_and_orphan(tmp_path, monkeypatch):
    """The power-loss contract (module docstring): a new segment file and a
    recovery rename are only durable once the *directory* is fsynced, so
    both paths must fsync the partition dir — and fsync="never" skips it."""
    calls = []
    orig = DurablePartitionLog._fsync_dir
    monkeypatch.setattr(
        DurablePartitionLog, "_fsync_dir",
        lambda self: (calls.append(self.fsync), orig(self))[1])

    path = str(tmp_path / "p0")
    with DurablePartitionLog(path, segment_bytes=256) as log:
        for i in range(30):
            log.append(None, f"value-{i:04d}", 0.0)
    created = len(calls)
    assert created >= 3                    # one per segment file created
    # corrupt the first segment: recovery renames later ones to .orphan and
    # must fsync the directory for each rename
    segs = _seg_files(path)
    blob = bytearray(open(segs[0], "rb").read())
    blob[len(blob) // 2] ^= 0x01
    with open(segs[0], "wb") as f:
        f.write(blob)
    log = DurablePartitionLog(path, segment_bytes=256)
    assert log.orphaned_segments == len(segs) - 1
    assert len(calls) >= created + log.orphaned_segments
    log.close()

    # fsync="never" opts out of directory durability along with data fsync
    calls.clear()
    with DurablePartitionLog(str(tmp_path / "p1"), fsync="never") as log:
        log.append(None, "x", 0.0)
    assert calls == ["never"]              # invoked, but a no-op inside
