"""Multi-device semantics (bridge collectives, elastic recovery, hlocost
collectives, dry-run smoke) — run in subprocesses with 8 virtual devices so
the main pytest process keeps its single real device."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(body: str, n: int = 8, timeout: int = 420) -> str:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
        import sys
        sys.path.insert(0, {os.path.join(ROOT, 'src')!r})
    """) + textwrap.dedent(body)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, cwd=ROOT)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_bridge_allreduce_matches_numpy():
    out = run_with_devices("""
        import numpy as np
        from repro.core import Context, MPIBridge
        ctx = Context()
        bridge = MPIBridge()
        assert bridge.world == 8
        rng = np.random.default_rng(0)
        parts = [rng.standard_normal(1000).astype(np.float32)
                 for _ in range(8)]
        got = np.asarray(bridge.allreduce(ctx.from_partitions(parts)))
        want = np.sum(parts, axis=0)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)
        # driver path agrees
        drv = MPIBridge.driver_reduce(ctx.from_partitions(parts))
        np.testing.assert_allclose(drv, want, rtol=1e-5, atol=1e-4)
        print("OK")
    """)
    assert "OK" in out


def test_bridge_compressed_allreduce_error_bounded():
    out = run_with_devices("""
        import numpy as np
        from repro.core import Context, MPIBridge
        ctx = Context()
        bridge = MPIBridge()
        rng = np.random.default_rng(1)
        parts = [rng.standard_normal(4096).astype(np.float32)
                 for _ in range(8)]
        exact = np.sum(parts, axis=0)
        got = np.asarray(bridge.allreduce(ctx.from_partitions(parts),
                                          compression="int8"))
        rel = np.linalg.norm(got - exact) / np.linalg.norm(exact)
        assert rel < 0.05, rel       # int8: ~1/127 per-element quant error
        print("OK", rel)
    """)
    assert "OK" in out


def test_bridge_rank_parallel_program():
    """An arbitrary MPI-style program: ranks exchange with ppermute."""
    out = run_with_devices("""
        import jax, numpy as np
        from repro.core import Context, MPIBridge
        ctx = Context()
        bridge = MPIBridge()
        parts = [np.full((4,), float(r), np.float32) for r in range(8)]

        def ring_shift(x):
            return jax.lax.ppermute(
                x, "workers", [(i, (i + 1) % 8) for i in range(8)])

        out = bridge.run(ctx.from_partitions(parts), ring_shift)
        got = np.asarray(out)[:, 0]
        np.testing.assert_array_equal(got, [(r - 1) % 8 for r in range(8)])
        print("OK")
    """)
    assert "OK" in out


def test_elastic_training_recovery():
    """Train DP on 8 workers, kill 3 at step 6, restore from checkpoint on
    5 workers, finish — final loss must be finite and the trajectory must
    re-execute the lost steps."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np, tempfile, os
        from repro.core import ElasticController, run_with_recovery
        from repro.checkpoint import save, restore, latest_step
        from jax.sharding import NamedSharding, PartitionSpec as P

        tmp = tempfile.mkdtemp()
        rng = np.random.default_rng(0)
        X = rng.standard_normal((64, 16)).astype(np.float32)
        y = X @ rng.standard_normal((16,)).astype(np.float32)

        def init_state(bridge):
            return {"w": jnp.zeros((16,), jnp.float32)}

        steps_run = []
        def step_fn(bridge, state, step):
            steps_run.append((step, bridge.world))
            w = state["w"]
            # data-parallel gradient: shard rows over workers, psum grads
            n = bridge.world
            rows = 64 // n
            def grad_prog(xb, yb):
                pred = xb[0] @ w_dev
                g = xb[0].T @ (pred - yb[0]) / 64.0
                return jax.lax.psum(g, "workers")
            import numpy as _np
            xs = _np.stack(_np.split(X[: rows * n], n))
            ys = _np.stack(_np.split(y[: rows * n], n))
            sharding = NamedSharding(bridge.mesh, P("workers"))
            w_dev = w
            from repro.utils import shard_map_compat
            prog = jax.jit(shard_map_compat(
                grad_prog, mesh=bridge.mesh,
                in_specs=(P("workers"), P("workers")),
                out_specs=P()))
            g = prog(jax.device_put(xs, sharding),
                     jax.device_put(ys, sharding))
            return {"w": w - 0.1 * g}

        def save_fn(state, step):
            save(tmp, step, {"state": state})

        def restore_fn(bridge):
            like = {"state": {"w": jnp.zeros((16,), jnp.float32)}}
            tree, step = restore(tmp, like)
            return tree["state"], step

        ctl = ElasticController(num_workers=8)
        state, events = run_with_recovery(
            ctl, init_state, step_fn, num_steps=12,
            save_fn=save_fn, restore_fn=restore_fn, checkpoint_every=4,
            failure_plan={6: 3})
        assert ctl.world == 5, ctl.world
        assert len(events) == 1
        worlds = {w for _, w in steps_run}
        assert worlds == {8, 5}, worlds
        # steps 4,5 re-executed after restore from step-4 checkpoint
        assert [s for s, w in steps_run if w == 5][0] == 4
        loss = float(np.mean((X @ np.asarray(state["w"]) - y) ** 2))
        assert np.isfinite(loss) and loss < np.mean(y ** 2)
        print("OK", loss)
    """)
    assert "OK" in out


def test_hlocost_collectives_at_mesh_sizes():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.launch.hlocost import hlo_cost
        from repro.utils import make_mesh_compat, shard_map_compat
        for n in (2, 4, 8):
            mesh = make_mesh_compat((n,), ("d",))
            f = jax.jit(shard_map_compat(lambda x: jax.lax.psum(x, "d"),
                                         mesh=mesh, in_specs=P("d"),
                                         out_specs=P()))
            c = f.lower(jax.ShapeDtypeStruct((n, 1024), jnp.float32)).compile()
            cost = hlo_cost(c.as_text())
            want = 2 * 4096 * (n - 1) / n
            assert abs(cost["ici_bytes"] - want) < 1, (n, cost["ici_bytes"])
        print("OK")
    """)
    assert "OK" in out


def test_dryrun_cell_smoke_small_mesh():
    """The dry-run path end-to-end on a (2, 2, 2) multi-pod mini-mesh with a
    reduced config — validates lower+compile+walker wiring without the
    512-device cost (the full meshes run via launch/dryrun.py)."""
    out = run_with_devices("""
        import jax
        from repro.configs import get_config
        from repro.configs.base import ShapeConfig
        from repro.training import lower_cell
        from repro.launch.hlocost import hlo_cost
        from repro.utils import make_mesh_compat
        mesh = make_mesh_compat((2, 2, 2), ("pod", "data", "model"))
        for arch in ("internlm2-1.8b", "granite-moe-3b-a800m"):
            cfg = get_config(arch, reduced=True)
            shape = ShapeConfig("smoke_train", 64, 8, "train")
            lowered, kind = lower_cell(cfg, shape, mesh)
            compiled = lowered.compile()
            cost = hlo_cost(compiled.as_text(), pod_size=4)
            assert cost["flops"] > 0
            ma = compiled.memory_analysis()
            from repro.utils import peak_memory_bytes
            assert peak_memory_bytes(ma) > 0
        print("OK")
    """)
    assert "OK" in out


def test_moe_a2a_matches_baseline_dispatch():
    """Explicit all-to-all EP == GSPMD scatter dispatch (capacity high
    enough that neither path drops tokens)."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models import moe as moe_lib
        from repro.parallel.sharding import ShardingRules, use_mesh
        from repro.utils import make_mesh_compat
        mesh = make_mesh_compat((4, 2), ("data", "model"))
        cfg0 = get_config("granite-moe-3b-a800m", reduced=True)
        cfg0 = cfg0.replace(capacity_factor=4.0)
        cfg_a2a = cfg0.replace(sharding_overrides={
            "_moe_impl": "a2a", "_moe_pad_experts": 8})
        key = jax.random.PRNGKey(0)
        p0, _ = moe_lib.init_moe(key, cfg0, jnp.float32)
        pa, _ = moe_lib.init_moe(key, cfg_a2a, jnp.float32)
        for k in ("w_gate", "w_up", "w_down"):
            pa[k] = pa[k].at[:cfg0.num_experts].set(p0[k])
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg0.d_model),
                              jnp.float32)
        y0, aux0 = jax.jit(lambda x, p: moe_lib.moe_layer(x, p, cfg0))(x, p0)
        with use_mesh(mesh, ShardingRules(overrides=dict(
                cfg_a2a.sharding_overrides))):
            ya, auxa = jax.jit(
                lambda x, p: moe_lib.moe_layer_a2a(x, p, cfg_a2a))(x, pa)
        np.testing.assert_allclose(np.asarray(y0), np.asarray(ya),
                                   rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(float(aux0), float(auxa), rtol=1e-5)
        print("OK")
    """)
    assert "OK" in out


def _has_partial_auto_shard_map() -> bool:
    # partial-manual shard_map (axis_names=...) needs graduated jax.shard_map;
    # on older jaxlib XLA rejects it with "PartitionId ... UNIMPLEMENTED".
    import jax
    return hasattr(jax, "shard_map")


@pytest.mark.skipif(not _has_partial_auto_shard_map(),
                    reason="partial-auto shard_map unsupported on this jax")
def test_gpipe_pipeline_matches_sequential():
    """GPipe over a 2-stage 'pod' axis == sequential layer stack (fwd+bwd)."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.parallel.pp import pipeline_layers
        from repro.utils import make_mesh_compat
        mesh = make_mesh_compat((2, 2, 2), ("pod", "data", "model"))
        L, B, S, D = 4, 8, 16, 32
        key = jax.random.PRNGKey(0)
        W = jax.random.normal(key, (L, D, D)) * 0.1
        x = jax.random.normal(jax.random.PRNGKey(1), (B, S, D))

        def run_block(x, w):
            return jnp.tanh(x @ w) + x

        def seq(x, W):
            for i in range(L):
                x = run_block(x, W[i])
            return x

        def pp(x, W):
            return pipeline_layers(run_block, W, x, mesh, L,
                                   microbatches=4)

        want = jax.jit(seq)(x, W)
        got = jax.jit(pp)(x, W)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
        # gradients flow through the pipeline (reverse ppermute by AD)
        g_seq = jax.grad(lambda W: jnp.sum(jax.jit(seq)(x, W) ** 2))(W)
        g_pp = jax.grad(lambda W: jnp.sum(jax.jit(pp)(x, W) ** 2))(W)
        np.testing.assert_allclose(np.asarray(g_pp), np.asarray(g_seq),
                                   rtol=2e-4, atol=2e-4)
        print("OK")
    """)
    assert "OK" in out
