"""Consumer groups: rebalance, failover, and the partition-handoff chaos
suite.

The contract under test, layer by layer:

- ``sticky_assign`` — every partition exactly once, balance within one,
  sticky under unchanged membership (property suite; hypothesis when
  installed, a seeded deterministic sweep otherwise).
- ``GroupCoordinator`` — two-phase join/sync, heartbeat-lease liveness with
  *lazy* expiry (survivors' calls evict the dead — no background thread),
  generation fencing of commits (stale generation, unowned partition,
  evicted member), all with an injected fake clock.
- ``StreamingContext`` group mode — two contexts split a topic's partitions
  and, once the assignment settles, consume strictly disjoint slices whose
  union is the whole topic; group commits never touch the default group.
- ``GroupConsumer`` — per-partition window-state handoff: a graceful leave
  migrates the *open* window to the next owner, which replays it and fires
  the exact window set a never-rebalanced run fires.
- The acceptance chaos test: three consumer processes over the socket
  transport, one SIGKILLed mid-window; the survivors detect the eviction,
  take over the dead member's partition, replay its open window from the
  handoff checkpoint, and the merged output is byte-identical to an
  uncrashed run — with the group's lag signal drained to zero.
"""
import json
import multiprocessing as mp
import os
import random
import signal
import threading
import time

import pytest

from repro.core import Broker, Context, StreamingContext
from repro.data import (GroupConsumer, GroupCoordinator, GroupError,
                        GroupMember, MetricsRegistry, RemoteBroker,
                        StaleGenerationError, WindowSpec, serve_broker,
                        set_registry, sticky_assign)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                        # container has no hypothesis:
    HAVE_HYPOTHESIS = False                # the seeded sweep below stands in


@pytest.fixture
def fresh_registry():
    reg = MetricsRegistry()
    prev = set_registry(reg)
    yield reg
    set_registry(prev)


# -- assignor: invariants + stickiness ----------------------------------------

def _check_assignment(n, consumers, prior):
    """The three guarantees every assignment must satisfy, plus idempotence
    (re-assigning with the result as prior reproduces the result)."""
    asn = sticky_assign(n, consumers, prior)
    members = sorted(set(consumers))
    assert sorted(asn) == members
    flat = [p for ps in asn.values() for p in ps]
    assert sorted(flat) == list(range(n)), "every partition exactly once"
    if members:
        sizes = [len(ps) for ps in asn.values()]
        assert max(sizes) - min(sizes) <= 1, "balance within one partition"
    assert sticky_assign(n, consumers, asn) == asn, "sticky fixpoint"
    return asn


def test_assignor_basic_shapes():
    assert sticky_assign(4, []) == {}
    assert sticky_assign(0, ["a"]) == {"a": []}
    assert sticky_assign(4, ["a"]) == {"a": [0, 1, 2, 3]}
    # fresh assignment round-robins free partitions to the least loaded
    assert sticky_assign(4, ["a", "b"]) == {"a": [0, 2], "b": [1, 3]}
    # 3 consumers, 4 partitions: exactly one member sits at the cap
    asn = sticky_assign(4, ["a", "b", "c"])
    assert sorted(len(ps) for ps in asn.values()) == [1, 1, 2]
    with pytest.raises(ValueError):
        sticky_assign(-1, ["a"])


def test_assignor_survivors_keep_partitions():
    prior = sticky_assign(6, ["a", "b", "c"])
    after = sticky_assign(6, ["a", "b"], prior)
    for c in ("a", "b"):                   # only the dead member's moved
        assert set(prior[c]) <= set(after[c])
    _check_assignment(6, ["a", "b"], prior)


def test_assignor_scale_out_moves_minimum():
    prior = sticky_assign(8, ["a", "b"])
    after = _check_assignment(8, ["a", "b", "c"], prior)
    kept = sum(len(set(prior[c]) & set(after[c])) for c in ("a", "b"))
    assert kept >= 5                       # 8->[3,3,2]: at most 3 moved
    assert len(after["c"]) >= 2


def test_assignor_ignores_stale_prior_claims():
    # prior claims outside [0, n) or duplicated across members are dropped
    asn = _check_assignment(4, ["a", "b"],
                            {"a": [0, 1, 9, -1], "b": [1, 2, 3]})
    assert asn["a"] == [0, 1]
    assert asn["b"] == [2, 3]


def test_assignor_property_sweep_seeded():
    """Deterministic stand-in for the hypothesis suite: 300 random
    (partitions, membership, prior) shapes, including priors from previous
    memberships (the rebalance case) and garbage priors."""
    rng = random.Random(0xC0FFEE)
    for _ in range(300):
        n = rng.randrange(0, 13)
        k = rng.randrange(1, 7)
        consumers = [f"c{i}" for i in range(k)]
        kind = rng.randrange(3)
        if kind == 0:
            prior = None
        elif kind == 1:                    # prior from an older membership
            old = rng.sample(consumers, rng.randrange(1, k + 1))
            prior = sticky_assign(n, old)
        else:                              # garbage prior
            prior = {c: [rng.randrange(-2, n + 3)
                         for _ in range(rng.randrange(0, n + 1))]
                     for c in rng.sample(consumers, rng.randrange(0, k + 1))}
        _check_assignment(n, consumers, prior)


if HAVE_HYPOTHESIS:
    @settings(max_examples=200, deadline=None)
    @given(n=st.integers(min_value=0, max_value=16),
           k=st.integers(min_value=1, max_value=6),
           drop=st.integers(min_value=0, max_value=5),
           data=st.data())
    def test_assignor_properties_hypothesis(n, k, drop, data):
        consumers = [f"c{i}" for i in range(k)]
        old = consumers[:max(1, k - drop)]
        prior = sticky_assign(n, old) if data.draw(st.booleans()) else None
        _check_assignment(n, consumers, prior)


# -- coordinator: membership, liveness, fencing (fake clock) ------------------

def _fake_clock():
    t = {"now": 0.0}
    return t, (lambda: t["now"])


def _coord_broker(partitions=4, registry=None):
    broker = Broker()
    broker.create_topic("t", partitions)
    t, clock = _fake_clock()
    # install before the first group op: Broker.coordinator is lazy
    broker._coordinator = GroupCoordinator(broker, clock=clock)
    return broker, t, clock


def test_join_sync_two_phase_and_generation_converges():
    broker, t, clock = _coord_broker()
    r1 = broker.join_group("g", "a", ["t"])
    assert r1 == {"generation": 1, "members": ["a"]}
    assert broker.sync_group("g", "a", 1) == {"t": [0, 1, 2, 3]}
    r2 = broker.join_group("g", "b", ["t"])
    assert r2["generation"] == 2 and r2["members"] == ["a", "b"]
    # a's sync at the old generation is fenced; at the new one it halves
    with pytest.raises(StaleGenerationError):
        broker.sync_group("g", "a", 1)
    # convergence: rejoining with unchanged membership does NOT bump again
    assert broker.join_group("g", "a", ["t"])["generation"] == 2
    assert broker.sync_group("g", "a", 2) == {"t": [0, 1]}
    assert broker.sync_group("g", "b", 2) == {"t": [2, 3]}
    assert broker.join_group("g", "b", ["t"])["generation"] == 2


def test_join_validates_inputs():
    broker, _, _ = _coord_broker()
    with pytest.raises(GroupError):
        broker.join_group("g", "", ["t"])
    with pytest.raises(GroupError):
        broker.join_group("g", "a", ["t"], session_timeout=0)
    with pytest.raises(GroupError):
        broker.heartbeat("nope", "a", 1)   # unknown group


def test_heartbeat_expiry_evicts_and_reassigns(fresh_registry):
    broker, t, clock = _coord_broker()
    a = GroupMember(broker, "g", "a", ["t"], session_timeout=5.0, clock=clock)
    b = GroupMember(broker, "g", "b", ["t"], session_timeout=5.0, clock=clock)
    a.join()
    b.join()
    a.maintain(force=True)                 # a catches up to b's generation
    assert a.partitions("t") == [0, 1] and b.partitions("t") == [2, 3]
    t["now"] = 3.0
    a.maintain(force=True)                 # a renews its lease; b goes dark
    t["now"] = 6.0                         # past b's deadline, inside a's
    changed = a.maintain(force=True)       # survivor's heartbeat evicts b
    assert changed and a.partitions("t") == [0, 1, 2, 3]
    d = broker.describe_group("g")
    assert sorted(d["members"]) == ["a"]
    evicted = fresh_registry.counter("group_members_evicted_total",
                                     labels={"group": "g"})
    assert evicted.value() == 1
    # the evicted member's next maintain() rejoins from scratch (sticky: it
    # may get the very partitions back, so "changed" can legitimately be
    # False — membership is what proves the rejoin)
    b.maintain(force=True)
    assert sorted(broker.describe_group("g")["members"]) == ["a", "b"]
    a.maintain(force=True)
    assert sorted(a.partitions("t") + b.partitions("t")) == [0, 1, 2, 3]
    assert set(a.partitions("t")).isdisjoint(b.partitions("t"))


def test_commit_fencing_stale_generation_and_unowned_partition():
    broker, t, clock = _coord_broker(partitions=2)
    for p in range(2):
        for i in range(4):
            broker.produce("t", i, partition=p)
    a = GroupMember(broker, "g", "a", ["t"], clock=clock)
    a.join()
    gen1 = a.generation
    broker.commit("t", 0, 2, group="g", consumer="a", generation=gen1)
    assert broker.committed("t", group="g") == [2, 0]
    b = GroupMember(broker, "g", "b", ["t"], clock=clock)
    b.join()                               # generation moves on under a
    with pytest.raises(StaleGenerationError):
        broker.commit("t", 0, 4, group="g", consumer="a", generation=gen1)
    a.maintain(force=True)                 # rejoin at the new generation
    assert a.partitions("t") == [0]
    with pytest.raises(StaleGenerationError):   # b's partition, not a's
        broker.commit("t", 1, 4, group="g", consumer="a",
                      generation=a.generation)
    with pytest.raises(StaleGenerationError):   # never a member at all
        broker.commit("t", 0, 4, group="g", consumer="ghost",
                      generation=a.generation)
    broker.commit("t", 0, 4, group="g", consumer="a",
                  generation=a.generation)
    assert broker.committed("t", group="g") == [4, 0]
    # the fenced commits advanced nothing and the default group is untouched
    assert broker.committed("t") == [0, 0]


def test_graceful_leave_rebalances_immediately():
    broker, t, clock = _coord_broker()
    a = GroupMember(broker, "g", "a", ["t"], clock=clock)
    b = GroupMember(broker, "g", "b", ["t"], clock=clock)
    a.join()
    b.join()
    b.leave()                              # no expiry wait
    assert b.generation == -1 and b.assignment == {}
    a.maintain(force=True)
    assert a.partitions("t") == [0, 1, 2, 3]
    assert a.generation == broker.describe_group("g")["generation"]
    a.leave()
    assert broker.describe_group("g")["members"] == {}


def test_join_leave_churn_settles_balanced():
    broker, t, clock = _coord_broker(partitions=8)
    members = [GroupMember(broker, "g", f"m{i}", ["t"], clock=clock)
               for i in range(5)]
    for m in members:
        m.join()

    def settle_and_check(live):
        for m in live:
            m.maintain(force=True)
        flat = sorted(p for m in live for p in m.partitions("t"))
        assert flat == list(range(8)), "cover every partition exactly once"
        sizes = [len(m.partitions("t")) for m in live]
        assert max(sizes) - min(sizes) <= 1
        # settled: another maintain round changes nothing
        assert not any(m.maintain(force=True) for m in live)

    settle_and_check(members)
    for i in range(3):                     # waves of leave + rejoin
        members[i].leave()
        settle_and_check(members[:i] + members[i + 1:])
        members[i].join()
        settle_and_check(members)


def test_group_metrics_gauges_and_counters(fresh_registry):
    broker, t, clock = _coord_broker()
    for i in range(10):
        broker.produce("t", i, partition=0)
    a = GroupMember(broker, "g", "a", ["t"], clock=clock)
    b = GroupMember(broker, "g", "b", ["t"], clock=clock)
    a.join()
    b.join()
    reg = fresh_registry
    assert reg.gauge("group_members", labels={"group": "g"}).value() == 2
    assert reg.gauge("group_generation", labels={"group": "g"}).value() == 2
    assert reg.counter("group_rebalances_total",
                       labels={"group": "g"}).value() == 2
    lag = reg.gauge("group_lag", labels={"group": "g", "topic": "t"})
    assert lag.value() == 10
    a.maintain(force=True)
    broker.commit("t", 0, 10, group="g", consumer="a",
                  generation=a.generation)
    assert lag.value() == 0


def test_describe_unknown_group_is_empty():
    broker, _, _ = _coord_broker()
    assert broker.describe_group("nope") == {
        "group": "nope", "generation": 0, "members": {}, "assignments": {}}


# -- over the wire: group ops + error types cross the socket ------------------

def test_group_protocol_over_socket(tmp_path):
    broker = Broker()
    broker.create_topic("t", 4)
    for i in range(8):
        broker.produce("t", i, partition=0)
    server = serve_broker(broker, str(tmp_path / "b.sock"))
    rb = RemoteBroker(server.address)
    try:
        gen = rb.join_group("g", "c1", ["t"])["generation"]
        assert rb.sync_group("g", "c1", gen) == {"t": [0, 1, 2, 3]}
        assert rb.heartbeat("g", "c1", gen) == {"generation": gen,
                                                "rebalance": False}
        rb.commit("t", 0, 8, group="g", consumer="c1", generation=gen)
        assert rb.lag("t", group="g") == 0 and rb.lag("t") == 8
        with pytest.raises(StaleGenerationError):   # the exact type survives
            rb.commit("t", 0, 8, group="g", consumer="c1",
                      generation=gen + 5)
        with pytest.raises(GroupError):
            rb.heartbeat("g", "nobody", 1)
        assert sorted(rb.commit_groups("t")) == ["", "g"]
        assert list(rb.describe_group("g")["members"]) == ["c1"]
        rb.leave_group("g", "c1")
        assert rb.describe_group("g")["members"] == {}
    finally:
        rb.close()
        server.stop()


# -- StreamingContext group mode ----------------------------------------------

def test_streaming_contexts_split_partitions_disjoint():
    broker = Broker()
    broker.create_topic("t", 4)
    s1 = StreamingContext(Context(), broker, max_records_per_partition=5)
    s2 = StreamingContext(Context(), broker, max_records_per_partition=5)
    seen = {"c1": [], "c2": []}
    for sc, cid in ((s1, "c1"), (s2, "c2")):
        sc.subscribe(["t"])
        sc.foreach_batch(lambda rdd, info, c=cid: seen[c].extend(rdd.collect()))
        sc.join_group("g", consumer_id=cid)
    # both members must see the settled assignment BEFORE records flow —
    # otherwise c1 (which joined alone at generation 1) legally consumes
    # partitions it is about to lose, and the handoff replays them (the
    # documented at-least-once overlap, absorbed by idempotent sinks)
    s1.group_member.maintain(force=True)
    for p in range(4):
        for i in range(10):
            broker.produce("t", p * 100 + i, partition=p)
    while s1.run_one_batch() is not None or s2.run_one_batch() is not None:
        pass
    assert set(seen["c1"]).isdisjoint(seen["c2"])
    assert sorted(seen["c1"] + seen["c2"]) == sorted(
        p * 100 + i for p in range(4) for i in range(10))
    assert broker.lag("t", group="g") == 0
    assert broker.lag("t") == 40           # default group never advanced
    s1.close()
    s2.close()
    assert broker.describe_group("g")["members"] == {}


def test_streaming_context_survives_fenced_commit():
    """A context whose group commit comes back fenced must not crash the
    batch loop: it logs, requests a resync, rejoins, and keeps consuming."""
    broker = Broker()
    broker.create_topic("t", 2)
    sc = StreamingContext(Context(), broker, max_records_per_partition=5)
    sc.subscribe(["t"])
    got = []
    sc.foreach_batch(lambda rdd, info: got.extend(rdd.collect()))
    member = sc.join_group("g", consumer_id="c1")
    for i in range(10):
        broker.produce("t", i, partition=0)
    sc.run_one_batch()
    # the group moves on behind the context's back -> its commit is fenced
    broker.join_group("g", "intruder", ["t"])
    sc.run_one_batch()                     # fenced commit -> resync requested
    while sc.run_one_batch() is not None:
        pass
    assert member.generation == broker.describe_group("g")["generation"]
    assert sorted(got) == list(range(10))
    sc.close()


# -- GroupConsumer: open-window handoff ---------------------------------------

def _win_files(outdir):
    out = {}
    for name in sorted(os.listdir(outdir)):
        if name.endswith(".json"):
            with open(os.path.join(outdir, name)) as f:
                out[name[:-5]] = json.load(f)
    return out


def _expected_windows(partitions, total, size):
    return {f"p{p}-w{k:04d}": [p * 1000 + k * size + i for i in range(size)]
            for p in range(partitions) for k in range(total // size)}


def _fire_to(outdir):
    def fn(part, records, winfo):
        tmp = os.path.join(outdir, f".p{part}-w{winfo.index:04d}.tmp")
        with open(tmp, "w") as f:
            json.dump(records, f)
        # analyze: ok replace-without-fsync - atomicity vs the reader below, not crash durability
        os.replace(tmp, os.path.join(outdir, f"p{part}-w{winfo.index:04d}.json"))
    return fn


def test_group_consumer_graceful_handoff_replays_open_window(tmp_path):
    """c1 leaves mid-window; c2 restores c1's open window from the handoff
    checkpoint and the merged output equals an uninterrupted run."""
    broker = Broker()
    broker.create_topic("t", 2)
    for p in range(2):
        for i in range(50):
            broker.produce("t", p * 1000 + i, partition=p)
    outdir = str(tmp_path / "windows")
    os.makedirs(outdir)

    def mk(cid):
        return GroupConsumer(broker, "g", "t", str(tmp_path / "state"),
                             window=WindowSpec(size=20),
                             window_fn=_fire_to(outdir), consumer_id=cid,
                             max_records_per_partition=7)

    c1, c2 = mk("c1"), mk("c2")
    c1.member.maintain(force=True)
    assert sorted(c1.partitions + c2.partitions) == [0, 1]
    for _ in range(2):                     # both sit mid-window (14 of 20)
        c1.step()
        c2.step()
    c1.close()                             # graceful: immediate rebalance
    c2.member.maintain(force=True)
    assert c2.partitions == [0, 1]
    while c2.step() is not None:
        pass
    assert _win_files(outdir) == _expected_windows(2, 40, 20)
    assert broker.lag("t", group="g") == 0
    c2.close()


def test_group_consumer_scale_out_keeps_window_continuity(tmp_path):
    """The opposite migration: c1 owns everything, consumes mid-window, then
    c2 joins and takes half — including an open window c1 had started."""
    broker = Broker()
    broker.create_topic("t", 2)
    for p in range(2):
        for i in range(50):
            broker.produce("t", p * 1000 + i, partition=p)
    outdir = str(tmp_path / "windows")
    os.makedirs(outdir)

    def mk(cid):
        return GroupConsumer(broker, "g", "t", str(tmp_path / "state"),
                             window=WindowSpec(size=20),
                             window_fn=_fire_to(outdir), consumer_id=cid,
                             max_records_per_partition=7)

    c1 = mk("c1")
    assert c1.partitions == [0, 1]
    for _ in range(2):
        c1.step()
    c2 = mk("c2")                          # scale-out: c1 must shed one
    c1.member.maintain(force=True)
    assert sorted(c1.partitions + c2.partitions) == [0, 1]
    assert len(c1.partitions) == 1 and len(c2.partitions) == 1
    while c1.step() is not None or c2.step() is not None:
        pass
    assert _win_files(outdir) == _expected_windows(2, 40, 20)
    assert broker.lag("t", group="g") == 0
    c1.close()
    c2.close()


def test_fenced_batch_never_advances_past_unpushed_records(tmp_path):
    """The startup-storm loss the chaos suite flushed out, made
    deterministic: an intruder bumps the generation behind c1's back, so
    c1's next batch is fenced on every range. The batch must abort without
    advancing the context's local cursor — c1 *keeps* partition 0 after the
    resync, and a quietly skipped range would drop records [0, 7) from the
    window stream forever (all offsets committed, final window never
    fires)."""
    broker = Broker()
    broker.create_topic("t", 2)
    for p in range(2):
        for i in range(40):
            broker.produce("t", p * 1000 + i, partition=p)
    outdir = str(tmp_path / "windows")
    os.makedirs(outdir)
    gc = GroupConsumer(broker, "g", "t", str(tmp_path / "state"),
                       window=WindowSpec(size=20),
                       window_fn=_fire_to(outdir), consumer_id="c1",
                       max_records_per_partition=7,
                       heartbeat_interval=100.0)  # never notices gen 2 early
    try:
        assert gc.partitions == [0, 1]
        broker.join_group("g", "x", ["t"])     # gen 2: c1 silently loses p1
        # c1 still believes generation 1: every range in this batch is
        # fenced, the batch aborts, nothing is pushed or committed
        assert gc.step() is None
        assert broker.committed("t", group="g") == [0, 0]
        broker.leave_group("g", "x")           # gen 3: c1 owns both again
        expect = _expected_windows(2, 40, 20)
        assert gc.run_until(
            lambda: set(_win_files(outdir)) == set(expect), timeout=30)
        assert _win_files(outdir) == expect    # records [0, 7) not dropped
        assert broker.lag("t", group="g") == 0
    finally:
        gc.close()


# -- the chaos suite: SIGKILL a consumer process mid-window -------------------

_GWIN = 20
_GTOTAL = 240                              # per partition -> 12 windows each


def _chaos_fire(outdir, part, records, winfo):
    tmp = os.path.join(outdir, f".p{part}-w{winfo.index:04d}.tmp")
    with open(tmp, "w") as f:
        json.dump(records, f)
    # analyze: ok replace-without-fsync - atomicity vs the reader below, not crash durability
    os.replace(tmp, os.path.join(outdir, f"p{part}-w{winfo.index:04d}.json"))


def _chaos_child(address, root, cid, stopfile):
    """Child process: one group consumer over the socket transport, slow
    enough to be caught mid-window, heartbeating fast enough that survivors
    evict a SIGKILLed sibling in ~1s."""
    import functools

    remote = RemoteBroker(address)
    gc = GroupConsumer(
        remote, "g", "t", os.path.join(root, "state"),
        window=WindowSpec(size=_GWIN),
        window_fn=functools.partial(_chaos_fire,
                                    os.path.join(root, "windows")),
        consumer_id=cid, max_records_per_partition=7,
        heartbeat_interval=0.2, session_timeout=1.0, per_batch_sleep=0.05)
    while not os.path.exists(stopfile):
        if gc.step() is None:
            time.sleep(0.01)
    gc.close()
    remote.close()


def _read_ckpt(root, p):
    try:
        with open(os.path.join(root, "state", f"t-p{p}", "ckpt.json")) as f:
            return json.load(f)
    except (OSError, ValueError):
        return {}


def test_sigkill_consumer_mid_window_partition_handoff(tmp_path):
    """The acceptance test: three consumer processes share a 3-partition
    topic through the group protocol; one is SIGKILLed mid-window. The
    survivors must evict it by heartbeat expiry, adopt its partition,
    replay the open window from the dead owner's last atomic (offset, state
    ref) checkpoint, and finish with the exact window set an uncrashed run
    produces — duplicates absorbed by the idempotent window files, group
    lag drained to zero."""
    root = str(tmp_path)
    os.makedirs(os.path.join(root, "windows"))
    broker = Broker()
    broker.create_topic("t", 3)
    for p in range(3):
        broker.produce_many(
            "t", [(None, p * 1000 + i) for i in range(_GTOTAL)], partition=p)
    server = serve_broker(broker, os.path.join(root, "b.sock"))
    stopfile = os.path.join(root, "stop")
    ctx = mp.get_context("spawn")
    procs = {cid: ctx.Process(target=_chaos_child,
                              args=(server.address, root, cid, stopfile),
                              daemon=True)
             for cid in ("c0", "c1", "c2")}
    try:
        for proc in procs.values():
            proc.start()
        coord = broker.coordinator

        def owned_parts(d):
            return sorted(p for a in d["assignments"].values()
                          for p in a.get("t", []))

        deadline = time.monotonic() + 60
        while time.monotonic() < deadline:     # all three joined + settled
            d = coord.describe("g")
            if len(d["members"]) == 3 and owned_parts(d) == [0, 1, 2]:
                break
            time.sleep(0.01)
        else:
            pytest.fail("group never settled with 3 members")
        gen_settled = d["generation"]
        victim = "c0"
        (vpart,) = d["assignments"][victim]["t"]

        # kill only once the victim's open window is non-empty: offsets
        # checkpointed past a window boundary, records buffered past it
        deadline = time.monotonic() + 60
        while time.monotonic() < deadline:
            if not procs[victim].is_alive():
                pytest.fail("victim exited before it could be killed")
            off = int(_read_ckpt(root, vpart).get("offset", 0))
            if off >= 3 * _GWIN and off % _GWIN != 0:
                os.kill(procs[victim].pid, signal.SIGKILL)
                break
            time.sleep(0.002)
        else:
            pytest.fail("never caught the victim mid-window")
        procs[victim].join(timeout=30)

        expect = _expected_windows(3, _GTOTAL, _GWIN)
        deadline = time.monotonic() + 120
        while time.monotonic() < deadline:     # survivors finish the topic
            done = set(_win_files(os.path.join(root, "windows")))
            if done == set(expect) and broker.lag("t", group="g") == 0:
                break
            time.sleep(0.05)
        else:
            missing = sorted(set(expect) -
                             set(_win_files(os.path.join(root, "windows"))))
            pytest.fail(f"survivors never finished; missing {missing[:6]}, "
                        f"group lag {broker.lag('t', group='g')}")

        d = coord.describe("g")                # before the graceful shutdown
        assert victim not in d["members"], "victim must be evicted"
        assert sorted(d["members"]) == ["c1", "c2"]
        assert owned_parts(d) == [0, 1, 2], "orphaned partition re-assigned"
        assert d["generation"] > gen_settled, "eviction bumped the generation"
        new_owner = _read_ckpt(root, vpart).get("owner")
        assert new_owner in ("c1", "c2")
    finally:
        with open(stopfile, "w"):
            pass
        for proc in procs.values():
            proc.join(timeout=30)
        server.stop()

    got = _win_files(os.path.join(root, "windows"))
    assert got == expect, (
        f"killed {victim} on partition {vpart}: merged survivor output must "
        f"equal the uncrashed window set")


# -- crash/restart of a whole group member with in-process threads ------------

def test_abandoned_member_is_evicted_and_partition_resumes(tmp_path):
    """In-process version of the chaos test's liveness path, deterministic:
    abandon() drops a consumer without leaving (a crash, minus the process),
    and the survivor — whose heartbeats drive lazy expiry on a fake clock —
    adopts the orphaned partition and replays its open window."""
    clockbox, clock = _fake_clock()
    broker = Broker()
    broker.create_topic("t", 2)
    broker._coordinator = GroupCoordinator(broker, clock=clock)
    for p in range(2):
        for i in range(50):
            broker.produce("t", p * 1000 + i, partition=p)
    outdir = str(tmp_path / "windows")
    os.makedirs(outdir)

    def mk(cid):
        gc = GroupConsumer(broker, "g", "t", str(tmp_path / "state"),
                           window=WindowSpec(size=20),
                           window_fn=_fire_to(outdir), consumer_id=cid,
                           max_records_per_partition=7, session_timeout=1.0)
        gc.member._clock = clock           # fake time drives the lease too
        return gc

    c1, c2 = mk("c1"), mk("c2")
    c1.member.maintain(force=True)
    for _ in range(2):                     # both mid-window at offset 14
        c1.step()
        c2.step()
    c1.abandon()                           # crash: no leave_group
    assert sorted(broker.describe_group("g")["members"]) == ["c1", "c2"]
    clockbox["now"] += 2.0                 # c1's lease expires
    c2.member.maintain(force=True)         # survivor's heartbeat evicts it
    assert sorted(broker.describe_group("g")["members"]) == ["c2"]
    assert c2.partitions == [0, 1]
    while c2.step() is not None:
        pass
    assert _win_files(outdir) == _expected_windows(2, 40, 20)
    assert broker.lag("t", group="g") == 0
    c2.close()


def test_in_process_group_threads_spawn_nothing_extra():
    before = threading.active_count()
    test_streaming_contexts_split_partitions_disjoint()
    # collect()'s executor pool shuts down with wait=False, so under load
    # its workers can outlive the call — give them a beat to exit before
    # holding the count to "nothing extra" (i.e. nothing *persistent*)
    deadline = time.monotonic() + 5.0
    while (threading.active_count() > before
           and time.monotonic() < deadline):
        time.sleep(0.01)
    assert threading.active_count() == before


def test_run_until_zero_timeout_is_immediate_not_forever(tmp_path):
    """timeout=0 means "the deadline already passed": run_until must return
    False at once, before stepping a batch — the old truthiness-tested
    deadline treated 0 as "no deadline" and would spin forever on a
    predicate that never comes true."""
    broker = Broker()
    broker.create_topic("t", 1)
    for i in range(5):
        broker.produce("t", i)
    outdir = str(tmp_path / "w")
    os.makedirs(outdir)
    gc = GroupConsumer(broker, "g", "t", str(tmp_path / "state"),
                       window=WindowSpec(size=100),
                       window_fn=_fire_to(outdir), consumer_id="c1")
    try:
        t0 = time.perf_counter()
        assert gc.run_until(lambda: False, timeout=0) is False
        assert time.perf_counter() - t0 < 1.0
        assert broker.committed("t", group="g") == [0]  # nothing consumed
        # an already-satisfied predicate still wins at timeout=0...
        assert gc.run_until(lambda: True, timeout=0) is True
        # ...and a real timeout still lets work proceed
        assert gc.run_until(lambda: broker.lag("t", group="g") == 0,
                            timeout=30) is True
    finally:
        gc.close()
