"""Training/serving integration: loss decreases, checkpoint-resume
continuity, streaming trainer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore, save
from repro.configs import get_config
from repro.configs.base import OptimizerConfig
from repro.training import build_serve_fns, build_train_step, init_state


def _batch(cfg, key, B=4, S=48):
    return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "granite-moe-3b-a800m",
                                  "rwkv6-7b"])
def test_train_loss_decreases(arch):
    cfg = get_config(arch, reduced=True)
    opt = OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=40,
                          zero1=False)
    state = init_state(jax.random.PRNGKey(0), cfg, opt)
    step = jax.jit(build_train_step(cfg, opt), donate_argnums=(0,))
    key = jax.random.PRNGKey(1)
    batch = _batch(cfg, key)               # overfit one batch
    losses = []
    for _ in range(15):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses
    assert np.isfinite(losses).all()


def test_checkpoint_resume_bitexact(tmp_path):
    """Stop at step 5, restore, continue — must match an uninterrupted run."""
    cfg = get_config("internlm2-1.8b", reduced=True)
    opt = OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=20,
                          zero1=False)
    step = jax.jit(build_train_step(cfg, opt))
    batches = [_batch(cfg, jax.random.PRNGKey(i)) for i in range(10)]

    state_a = init_state(jax.random.PRNGKey(0), cfg, opt)
    for b in batches:
        state_a, _ = step(state_a, b)

    state_b = init_state(jax.random.PRNGKey(0), cfg, opt)
    for b in batches[:5]:
        state_b, _ = step(state_b, b)
    save(str(tmp_path), 5, state_b)
    restored, _ = restore(str(tmp_path), jax.eval_shape(lambda: state_b))
    for b in batches[5:]:
        restored, _ = step(restored, b)

    for pa, pb in zip(jax.tree_util.tree_leaves(state_a["params"]),
                      jax.tree_util.tree_leaves(restored["params"])):
        np.testing.assert_array_equal(np.asarray(pa, np.float32),
                                      np.asarray(pb, np.float32))


def test_serve_fns_shapes():
    cfg = get_config("gemma-7b", reduced=True)
    from repro.models.registry import get_model
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    prefill, decode = build_serve_fns(cfg)
    batch = {"tokens": jnp.ones((2, 10), jnp.int32)}
    logits, cache = model.prefill(params, batch, cfg, max_len=16)
    assert logits.shape == (2, 1, cfg.vocab_size)
    logits2, cache = decode(params, jnp.ones((2, 1), jnp.int32), cache)
    assert logits2.shape == (2, 1, cfg.vocab_size)
    assert int(cache["pos"]) == 11


def test_streaming_trainer_cli_smoke(tmp_path):
    """launch/train.py end-to-end including resume."""
    import sys
    from repro.launch import train as train_mod
    argv = ["train", "--arch", "internlm2-1.8b", "--steps", "4",
            "--batch", "2", "--seq", "32", "--ckpt-dir", str(tmp_path),
            "--ckpt-every", "2", "--log-every", "10"]
    old = sys.argv
    try:
        sys.argv = argv
        train_mod.main()
        sys.argv = argv + ["--resume"]
        train_mod.main()
    finally:
        sys.argv = old
    from repro.checkpoint import latest_step
    assert latest_step(str(tmp_path)) >= 4
