"""Per-topic payload codecs (repro.data.codec): round trips, lossy bounds,
JAX parity, unknown-codec refusal, and end-to-end encode-at-flush /
decode-at-subscribe — including byte-identity replication of codec'd logs.
"""
import os

import numpy as np
import pytest

from repro.core import Broker, Context, OffsetRange, StreamingContext
from repro.data.codec import (SENTINEL, CodecBroker, UnknownCodecError,
                              codec_names, compose_decoder, get_codec,
                              maybe_decode)


# -- round trips -------------------------------------------------------------

_VALUES = [
    7,
    "text",
    b"raw-bytes",
    None,
    {"i": 1, "nested": (2.5, [b"x", None])},
    (3, np.arange(12, dtype=np.float32).reshape(3, 4)),
    {"frame": np.ones((4, 4), dtype=np.float64),
     "meta": {"idx": [np.arange(5, dtype=np.int64)]}},
]


def _eq(a, b) -> bool:
    if isinstance(a, np.ndarray) or isinstance(b, np.ndarray):
        return (isinstance(a, np.ndarray) and isinstance(b, np.ndarray)
                and a.dtype == b.dtype and a.shape == b.shape
                and np.array_equal(a, b))
    if isinstance(a, (list, tuple)) and isinstance(b, (list, tuple)):
        return (type(a) is type(b) and len(a) == len(b)
                and all(_eq(x, y) for x, y in zip(a, b)))
    if isinstance(a, dict) and isinstance(b, dict):
        return a.keys() == b.keys() and all(_eq(a[k], b[k]) for k in a)
    return type(a) is type(b) and a == b


def test_registry_names():
    assert codec_names() == ["int8", "raw", "zlib"]


@pytest.mark.parametrize("name", ["raw", "zlib"])
def test_lossless_roundtrip(name):
    codec = get_codec(name)
    for value in _VALUES:
        assert _eq(maybe_decode(codec.encode(value)), value)


def test_int8_roundtrip_structure_and_integers_exact():
    """int8 is lossy only on float arrays: structure, scalars, and integer
    arrays come back exact; float arrays come back same dtype/shape."""
    codec = get_codec("int8")
    value = {"frame": np.linspace(-1, 1, 16, dtype=np.float32).reshape(4, 4),
             "idx": np.arange(6, dtype=np.int32), "n": 3, "tag": "t"}
    got = maybe_decode(codec.encode(value))
    assert got.keys() == value.keys()
    assert np.array_equal(got["idx"], value["idx"])   # integers untouched
    assert got["n"] == 3 and got["tag"] == "t"
    assert got["frame"].dtype == np.float32
    assert got["frame"].shape == (4, 4)


def test_int8_lossy_error_bound():
    """Per-element error is bounded by the tensor's amax/127 (the documented
    contract), across dtypes and value ranges."""
    rng = np.random.default_rng(0)
    for dtype in (np.float32, np.float64):
        for scale_up in (1e-3, 1.0, 1e4):
            arr = (rng.standard_normal((64, 64)) * scale_up).astype(dtype)
            got = maybe_decode(get_codec("int8").encode(arr))
            bound = float(np.max(np.abs(arr))) / 127.0 + 1e-9
            assert float(np.max(np.abs(got.astype(np.float64)
                                       - arr.astype(np.float64)))) <= bound


def test_int8_empty_and_zero_arrays():
    for arr in (np.zeros((3, 3), dtype=np.float32),
                np.zeros((0,), dtype=np.float32)):
        got = maybe_decode(get_codec("int8").encode(arr))
        assert got.shape == arr.shape and np.array_equal(got, arr)


def test_int8_matches_jax_reference():
    """The NumPy quantizer is the codec-side mirror of the optimizer's JAX
    ``quantize_int8`` — identical q arrays and scales, so a value compressed
    for the wire degrades exactly like a gradient compressed for all-reduce."""
    jax = pytest.importorskip("jax")
    from repro.data.codec import _quantize
    from repro.optim.compression import dequantize_int8, quantize_int8

    rng = np.random.default_rng(1)
    for x in (rng.standard_normal((32, 32)).astype(np.float32),
              np.zeros((8,), dtype=np.float32),
              (rng.standard_normal(100) * 1e-6).astype(np.float32)):
        node = _quantize(x)
        q_jax, scale_jax = quantize_int8(jax.numpy.asarray(x))
        np.testing.assert_array_equal(node["q"], np.asarray(q_jax))
        assert node["s"] == pytest.approx(float(scale_jax), rel=1e-6)
        np.testing.assert_allclose(
            node["q"].astype(np.float32) * node["s"],
            np.asarray(dequantize_int8(q_jax, scale_jax)), rtol=1e-6)


# -- refusal and escaping ----------------------------------------------------

def test_unknown_codec_refused_everywhere():
    with pytest.raises(UnknownCodecError, match="martian"):
        get_codec("martian")
    with pytest.raises(UnknownCodecError):
        maybe_decode({SENTINEL: "martian", "v": 1})   # never passed through
    with pytest.raises(UnknownCodecError):
        Broker().create_topic("t", codec="martian")   # fails at create...


def test_unknown_codec_refused_over_the_wire(tmp_path):
    """...and the same error type crosses the transport from a remote
    create_topic (registered in the transport's typed-error table)."""
    from repro.data.transport import RemoteBroker, serve_broker

    server = serve_broker(Broker(), str(tmp_path / "b.sock"))
    client = RemoteBroker(server.address)
    try:
        with pytest.raises(UnknownCodecError):
            client.create_topic("t", codec="martian")
        client.create_topic("t", codec="int8")        # good names still work
        assert client.topic_codec("t") == "int8"
        with pytest.raises(KeyError):
            client.topic_codec("missing")
    finally:
        client.close()
        server.stop()


def test_sentinel_collision_escaped():
    """A *user* value that happens to be a dict carrying the sentinel key
    round-trips exactly through the raw codec's escape hatch instead of
    being misread as an encoded payload."""
    tricky = {SENTINEL: "zlib", "z": b"not really compressed"}
    wrapped = get_codec("raw").encode(tricky)
    assert wrapped is not tricky                      # escaped, not aliased
    assert _eq(maybe_decode(wrapped), tricky)
    # non-colliding values pass through the raw codec untouched (no wrap)
    plain = {"k": 1}
    assert get_codec("raw").encode(plain) is plain


def test_compose_decoder_order():
    """Codec decode runs first, then the user's value decoder."""
    codec = get_codec("zlib")
    dec = compose_decoder(lambda v: v * 10)
    assert dec(codec.encode(7)) == 70
    assert dec(3) == 30                               # unwrapped passthrough
    assert compose_decoder(None)(codec.encode("x")) == "x"


# -- topic configuration and the encode/decode boundary ----------------------

def test_topic_codec_configuration():
    b = Broker()
    b.create_topic("detector", codec="int8")
    b.create_topic("control")
    assert b.topic_codec("detector") == "int8"
    assert b.topic_codec("control") is None
    with pytest.raises(KeyError):
        b.topic_codec("missing")


def test_ingest_encodes_subscribe_decodes():
    """The full boundary: IngestRunner encodes at flush (values travel
    wrapped through the broker), StreamingContext decodes at subscribe —
    detector frames arrive as float arrays within the lossy bound."""
    from repro.data import IngestConfig, IngestRunner, SequenceSource

    class Frames(SequenceSource):
        def __init__(self, n):
            super().__init__()
            rng = np.random.default_rng(2)
            self.frames = [rng.standard_normal((8, 8)).astype(np.float32)
                           for _ in range(n)]

        def __len__(self):
            return len(self.frames)

        def record_at(self, i):
            return f"f{i}".encode(), (i, self.frames[i])

    broker = Broker()
    runner = IngestRunner(broker)
    source = Frames(6)
    m = runner.add(source, IngestConfig(topic="det", codec="int8",
                                        flush_records=3))
    runner.run_inline(timeout=30)
    assert m.produced == 6
    # on the log the values are wrapped (what durable segments would hold)
    raw = broker.read(OffsetRange("det", 0, 0, 10))
    assert all(isinstance(r.value, dict) and r.value[SENTINEL] == "int8"
               for r in raw)
    # a subscriber sees decoded values
    sc = StreamingContext(Context(), broker, max_records_per_partition=10)
    sc.subscribe(["det"])
    seen = []
    sc.foreach_batch(lambda rdd, info: seen.extend(rdd.collect()))
    sc.run_batches(1)
    assert len(seen) == 6
    for i, frame in sorted(seen):
        ref = source.frames[i]
        assert frame.dtype == np.float32
        assert np.max(np.abs(frame - ref)) <= np.max(np.abs(ref)) / 127 + 1e-9


def test_topic_source_decodes_for_chained_stages():
    from repro.data import TopicSource

    broker = Broker()
    broker.create_topic("stage1", codec="zlib")
    codec = get_codec("zlib")
    broker.produce("stage1", codec.encode({"x": 1}), key=b"a")
    broker.produce("stage1", codec.encode((2, np.arange(3))), key=b"b")
    got = TopicSource(broker, "stage1", stop_at_end=True).poll(10)
    assert _eq(got[0], (b"a", {"x": 1}))
    assert _eq(got[1], (b"b", (2, np.arange(3))))


def test_codec_metrics_account_reduction():
    """ingest_codec_bytes_in/out: int8 on float32 frames shows ~4x fewer
    bytes leaving the encode boundary than entering it."""
    from repro.data import IngestConfig, IngestRunner, SequenceSource
    from repro.data.metrics import MetricsRegistry, set_registry

    class Frames(SequenceSource):
        def __len__(self):
            return 4

        def record_at(self, i):
            return None, np.ones((64, 64), dtype=np.float32)

    reg = MetricsRegistry()
    prev = set_registry(reg)
    try:
        runner = IngestRunner(Broker())
        runner.add(Frames(), IngestConfig(topic="t", codec="int8",
                                          flush_records=2))
        runner.run_inline(timeout=30)
        values = {m.name: m.value() for m in reg.metrics()}
        bytes_in = values["ingest_codec_bytes_in"]
        bytes_out = values["ingest_codec_bytes_out"]
        assert bytes_in >= 4 * 64 * 64 * 4
        assert bytes_out < bytes_in / 3           # ~4x minus wrapper overhead
    finally:
        set_registry(prev)


# -- composition with durability and replication -----------------------------

def test_replication_byte_identity_with_codec(tmp_path):
    """Codec'd payloads are opaque to the broker: a follower replicating a
    compressed topic lands byte-identical segment files, and decoding the
    replica yields the original values."""
    from repro.core.broker import COMMIT_TOPIC
    from repro.data.durable_log import DurableLogFactory
    from repro.data.replication import ReplicaFollower
    from repro.data.transport import serve_broker

    primary = Broker(log_factory=DurableLogFactory(str(tmp_path / "primary")),
                     commit_topic=COMMIT_TOPIC)
    server = serve_broker(primary, str(tmp_path / "p.sock"))
    primary.create_topic("t", 2, codec="zlib")
    codec = get_codec("zlib")
    values = [{"i": i, "blob": b"x" * 200} for i in range(30)]
    primary.produce_many("t", [(f"k{i}".encode(), codec.encode(v))
                               for i, v in enumerate(values)])
    fol = ReplicaFollower(server.address, str(tmp_path / "replica"))
    try:
        while fol.sync_once():
            pass
        assert fol.broker.end_offsets("t") == primary.end_offsets("t")
        for p in range(2):
            pdir = tmp_path / "primary" / "t" / f"p{p:04d}"
            fdir = tmp_path / "replica" / "t" / f"p{p:04d}"
            segs = sorted(f for f in os.listdir(pdir) if f.endswith(".seg"))
            assert segs == sorted(f for f in os.listdir(fdir)
                                  if f.endswith(".seg"))
            for seg in segs:
                assert (pdir / seg).read_bytes() == (fdir / seg).read_bytes()
        # the replica's records decode to the original values
        got = []
        for p in range(2):
            end = fol.broker.end_offset("t", p)
            got += [maybe_decode(r.value)
                    for r in fol.broker.read(OffsetRange("t", p, 0, end))]
        assert sorted(v["i"] for v in got) == list(range(30))
    finally:
        fol.stop()
        server.stop()


def test_codec_broker_adapter_passthrough():
    """CodecBroker: encode on produce, decode on read, everything else
    passes through — observationally identical with a lossless codec."""
    cb = CodecBroker(Broker(), codec="zlib")
    cb.create_topic("t", 2)
    assert cb.num_partitions("t") == 2
    cb.produce("t", {"a": 1}, partition=0)
    cb.produce_many("t", [(b"k", np.arange(4))], partition=0)
    recs = cb.read(OffsetRange("t", 0, 0, 10))
    assert _eq(recs[0].value, {"a": 1})
    assert _eq(recs[1].value, np.arange(4))
    # the wrapped broker holds encoded values
    inner = cb._broker.read(OffsetRange("t", 0, 0, 10))
    assert all(isinstance(r.value, dict) and r.value[SENTINEL] == "zlib"
               for r in inner)
