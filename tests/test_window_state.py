"""Restart-safe windowed state: store round-trips, snapshot/delta recovery,
uncommitted-tail truncation, atomic (offsets, window state) checkpointing —
and a real SIGKILL mid-window crash (spawn-context child, like
``tests/test_durable_log.py``).

The contract under test: with a ``DurableStateStore`` behind the windower,
a restarted pipeline fires exactly the windows a never-crashed run fires —
no record lost out of the open window, none duplicated into it — because
window state and consumed offsets commit in one ``os.replace``.
"""
import json
import multiprocessing as mp
import os
import signal
import threading
import time

import pytest

from repro.core import Broker, Context, StreamingContext
from repro.data import (DurableStateStore, InMemoryStateStore, WindowSpec,
                        WindowState, WindowStateStore, windowed)
from repro.data.durable_log import DurableLogFactory


def _state(buf, evicted=0, t0=None, fired=0):
    return WindowState(buf=list(buf), evicted=evicted, t0=t0,
                       windows_fired=fired)


def _mk(vals, start=0):
    """Buffer entries for records ``vals`` arriving one per batch."""
    return [(v, 0.0, start + i) for i, v in enumerate(vals)]


# -- stores: protocol + round trip -------------------------------------------

def test_inmemory_store_round_trip():
    store = InMemoryStateStore()
    assert isinstance(store, WindowStateStore)
    assert store.restore(None) is None
    s = _state(_mk([1, 2, 3]), evicted=5, t0=10.0, fired=2)
    ref = store.commit(7, s)
    assert ref == 7
    s.buf.append(("mutated", 0.0, 9))      # caller mutation must not leak in
    got = store.restore(7)
    assert got.buf == _mk([1, 2, 3]) and got.evicted == 5
    assert got.t0 == 10.0 and got.windows_fired == 2
    got.buf.clear()                        # nor leak back out
    assert store.restore(7).buf == _mk([1, 2, 3])
    assert store.restore(6) is None        # unknown ref: fresh start


def test_durable_store_commit_restore_across_reopen(tmp_path):
    path = str(tmp_path / "w")
    with DurableStateStore(path) as store:
        store.commit(1, _state(_mk([0, 1])))
        store.commit(2, _state(_mk([0, 1, 2, 3])))
        store.commit(3, _state(_mk([2, 3, 4], start=2), evicted=2, fired=1))
    reopened = DurableStateStore(path)
    assert reopened.recovered_frames == 3      # snap + 2 deltas
    got = reopened.restore(3)
    assert got.buf == _mk([2, 3, 4], start=2)
    assert got.evicted == 2 and got.windows_fired == 1
    # restoring an older epoch rewinds AND truncates the newer frames
    reopened.close()
    store2 = DurableStateStore(path)
    got2 = store2.restore(2)
    assert got2.buf == _mk([0, 1, 2, 3]) and got2.evicted == 0
    store2.close()
    assert DurableStateStore(path).restore(3).buf == _mk([0, 1, 2, 3])


def test_durable_store_restore_none_resets(tmp_path):
    path = str(tmp_path / "w")
    with DurableStateStore(path) as store:
        store.commit(1, _state(_mk([1, 2, 3])))
    store = DurableStateStore(path)
    # no checkpoint ref survived (e.g. corrupt checkpoint): state resets too,
    # keeping offsets and window state consistent (both empty)
    assert store.restore(None) is None
    assert os.path.getsize(os.path.join(path, "state.log")) == 0
    store.commit(1, _state(_mk([9])))
    assert store.restore(1).buf == _mk([9])
    store.close()


def test_durable_store_unchanged_state_writes_nothing(tmp_path):
    store = DurableStateStore(str(tmp_path / "w"))
    s = _state(_mk([1, 2]), evicted=1, fired=1)
    assert store.commit(4, s) == 4
    size = os.path.getsize(store._file)
    assert store.commit(5, s) == 4         # previous ref: nothing new on disk
    assert os.path.getsize(store._file) == size
    assert store.commit(6, _state(_mk([1, 2, 3]), evicted=1, fired=1)) == 6
    store.close()


def test_durable_store_torn_tail_truncated(tmp_path):
    path = str(tmp_path / "w")
    with DurableStateStore(path) as store:
        store.commit(1, _state(_mk([0, 1])))
        store.commit(2, _state(_mk([0, 1, 2])))
    with open(os.path.join(path, "state.log"), "ab") as f:
        f.write(b"\x00\x00\x00\x40TORN-DELTA-ONLY-PARTIALLY-WRITTEN")
    store = DurableStateStore(path)
    assert store.truncated_bytes > 0
    assert store.restore(2).buf == _mk([0, 1, 2])
    store.close()


def test_durable_store_bit_flip_keeps_committed_prefix(tmp_path):
    path = str(tmp_path / "w")
    with DurableStateStore(path) as store:
        store.commit(1, _state(_mk([0, 1, 2])))
        store.commit(2, _state(_mk([0, 1, 2, 3, 4])))
    blob = bytearray(open(os.path.join(path, "state.log"), "rb").read())
    blob[-3] ^= 0x20                       # corrupt the delta frame
    with open(os.path.join(path, "state.log"), "wb") as f:
        f.write(blob)
    store = DurableStateStore(path)
    assert store.truncated_bytes > 0
    # epoch 2's delta is gone; epoch 1's snapshot still restores
    assert store.restore(2).buf == _mk([0, 1, 2])
    store.close()


def test_durable_store_compaction_bounds_file(tmp_path):
    path = str(tmp_path / "w")
    store = DurableStateStore(path, snapshot_every=4)
    buf = []
    for e in range(1, 41):
        buf = buf[-3:] + [(e, 0.0, e)]     # sliding-ish: bounded buffer
        store.commit(e, _state(buf, evicted=max(0, e - 4)))
    # 40 commits, snapshot_every=4: the log holds <= 2 snapshots + 4 deltas,
    # never the whole history
    assert store.snapshots >= 8
    size = os.path.getsize(store._file)
    assert size < 8 * 1024
    assert store.restore(40).buf == buf
    store.close()
    # the last two compaction anchors both restore (crash on either side of
    # the caller's checkpoint write)
    reopened = DurableStateStore(path, snapshot_every=4)
    assert reopened.restore(40).buf == buf
    reopened.close()


def test_durable_store_compaction_keeps_previous_committed_epoch(tmp_path):
    """The crash window the two-snapshot compaction exists for: the store
    compacts at epoch N, the process dies before the offset checkpoint
    publishes N — restore(N-1) must still work."""
    path = str(tmp_path / "w")
    store = DurableStateStore(path, snapshot_every=2)
    store.commit(1, _state(_mk([0])))
    store.commit(2, _state(_mk([0, 1])))
    store.commit(3, _state(_mk([0, 1, 2])))   # delta budget spent
    store.commit(4, _state(_mk([0, 1, 2, 3])))  # -> compaction [snap3, snap4]
    store.close()
    store = DurableStateStore(path)
    assert store.restore(4).buf == _mk([0, 1, 2, 3])   # checkpoint saw 4
    store.close()
    store = DurableStateStore(path)
    # checkpoint never saw 4: restoring 3 works AND truncates the epoch-4
    # snapshot for good (it is uncommitted state)
    assert store.restore(3).buf == _mk([0, 1, 2])
    store.close()
    store = DurableStateStore(path)
    assert store.restore(4).buf == _mk([0, 1, 2])      # 4 is gone now
    store.close()


def test_durable_store_snapshot_on_rollback_shaped_change(tmp_path):
    """Counters moving backwards (caller rolled the windower back) cannot be
    expressed as a delta — the store must fall back to a snapshot, not
    extrapolate garbage."""
    store = DurableStateStore(str(tmp_path / "w"))
    store.commit(1, _state(_mk([0, 1, 2]), evicted=6, fired=2))
    store.commit(2, _state(_mk([9]), evicted=3, fired=1))   # went backwards
    store.close()
    store = DurableStateStore(str(tmp_path / "w"))
    got = store.restore(2)
    assert got.buf == _mk([9]) and got.evicted == 3 and got.windows_fired == 1
    store.close()


def test_durable_store_validation(tmp_path):
    with pytest.raises(ValueError):
        DurableStateStore(str(tmp_path / "a"), fsync="sometimes")
    with pytest.raises(ValueError):
        DurableStateStore(str(tmp_path / "b"), snapshot_every=0)


# -- context integration: atomic (offsets, window state) ---------------------

def _windowed_context(broker, ckpt, store, fired, size=10, per_batch=7):
    sc = StreamingContext(Context(), broker, max_records_per_partition=per_batch,
                          checkpoint_path=ckpt)
    sc.subscribe(["t"])
    wout = []
    sc.foreach_batch(windowed(
        WindowSpec(size=size),
        lambda recs, wi: fired.append((wi.index, list(recs))),
        store=store, windower_out=wout))
    return sc, wout[0]


def test_mid_window_restart_resumes_exactly(tmp_path):
    """The tentpole behavior, in-process: offsets checkpoint mid-window, the
    'process' dies, the restart restores the open window from the store and
    fires exactly the windows an uninterrupted run fires."""
    broker = Broker()
    broker.create_topic("t", 1)
    for i in range(40):
        broker.produce("t", i)
    ckpt = str(tmp_path / "ckpt.json")
    fired = []
    store = DurableStateStore(str(tmp_path / "w"))
    sc, _ = _windowed_context(broker, ckpt, store, fired)
    for _ in range(3):                     # 21 consumed: buf holds [20]
        sc.run_one_batch()
    assert [i for i, _ in fired] == [0, 1]
    store.close()                          # crash

    fired2 = []
    store2 = DurableStateStore(str(tmp_path / "w"))
    sc2, w2 = _windowed_context(broker, ckpt, store2, fired2)
    while sc2.run_one_batch() is not None:
        pass
    assert fired2 == [(2, list(range(20, 30))), (3, list(range(30, 40)))]
    assert w2.flush() == []                # nothing pending: 40 = 4 windows
    store2.close()


def test_in_memory_store_loses_open_window_but_api_matches(tmp_path):
    """The degenerate path pins the pre-existing behavior: same wiring, but a
    'restart' (new store) drops the open window — the records consumed into
    it are gone. This is the hole DurableStateStore closes."""
    broker = Broker()
    broker.create_topic("t", 1)
    for i in range(40):
        broker.produce("t", i)
    ckpt = str(tmp_path / "ckpt.json")
    fired = []
    sc, _ = _windowed_context(broker, ckpt, InMemoryStateStore(), fired)
    for _ in range(3):
        sc.run_one_batch()
    fired2 = []
    sc2, w2 = _windowed_context(broker, ckpt, InMemoryStateStore(), fired2)
    while sc2.run_one_batch() is not None:
        pass
    w2.flush()
    flat = [v for _, recs in fired + fired2 for v in recs]
    assert 20 not in flat                  # record 20 was lost mid-window
    assert sorted(flat) == [v for v in range(40) if v != 20]


def test_in_memory_path_spawns_no_threads(tmp_path):
    before = threading.active_count()
    test_in_memory_store_loses_open_window_but_api_matches(tmp_path)
    assert threading.active_count() == before


def test_failed_serial_sink_rolls_back_window_state(tmp_path):
    """A sink raising after the windower pushed must roll the window back:
    the replayed batch pushes the same records again and the window fires
    them once, not twice."""
    broker = Broker()
    broker.create_topic("t", 1)
    for i in range(12):
        broker.produce("t", i)
    ckpt = str(tmp_path / "ckpt.json")
    fired = []
    store = InMemoryStateStore()
    sc, _ = _windowed_context(broker, ckpt, store, fired, size=6, per_batch=6)
    boom = {"armed": True}

    def flaky_sink(info):
        if boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("transient sink failure")

    sc.add_sink(flaky_sink)
    with pytest.raises(RuntimeError):
        sc.run_one_batch()                 # window 0 fired, then sink blew up
    # nothing committed: offsets AND window state rolled back together
    assert sc.committed("t") == 0
    while sc.run_one_batch() is not None:
        pass
    # the replay re-fired window 0 with identical contents (idempotent by
    # index), and no record appears in two different windows
    assert fired[0] == fired[1] == (0, [0, 1, 2, 3, 4, 5])
    assert fired[2] == (1, [6, 7, 8, 9, 10, 11])
    assert len(fired) == 3


def test_store_without_checkpoint_path_is_left_alone(tmp_path):
    broker = Broker()
    broker.create_topic("t", 1)
    for i in range(10):
        broker.produce("t", i)
    sc = StreamingContext(Context(), broker, max_records_per_partition=5)
    sc.subscribe(["t"])
    store = DurableStateStore(str(tmp_path / "w"))
    sc.foreach_batch(windowed(WindowSpec(size=5), lambda r, w: None,
                              store=store))
    while sc.run_one_batch() is not None:
        pass
    assert os.path.getsize(store._file) == 0   # nothing to commit against
    store.close()


def test_restore_warns_when_ref_beyond_log(tmp_path, caplog):
    """A checkpoint ref with no frame on disk means a power loss outran the
    fsync policy (the checkpoint always fsyncs): restore must warn and fall
    back to the newest earlier state, never degrade silently."""
    path = str(tmp_path / "w")
    with DurableStateStore(path) as store:
        store.commit(1, _state(_mk([0, 1])))
    store = DurableStateStore(path)
    with caplog.at_level("WARNING"):
        got = store.restore(3)             # the epoch-3 frame never synced
    assert got.buf == _mk([0, 1])
    assert any("no frame for checkpoint ref 3" in r.message
               for r in caplog.records)
    store.close()


def test_attach_warns_on_time_kind_restore_with_monotonic_clock(
        tmp_path, caplog):
    """time-kind t0 is a clock reading from the *previous* process; under
    the default monotonic clock that is meaningless after a restart — the
    attach path must say so at runtime, not only in docs."""
    broker = Broker()
    broker.create_topic("t", 1)
    for i in range(4):
        broker.produce("t", i)
    ckpt = str(tmp_path / "ckpt.json")
    store = DurableStateStore(str(tmp_path / "w"))
    clock = {"t": 50.0}
    sc = StreamingContext(Context(), broker, max_records_per_partition=2,
                          checkpoint_path=ckpt, clock=lambda: clock["t"])
    sc.subscribe(["t"])
    sc.foreach_batch(windowed(WindowSpec(size=100.0, kind="time"),
                              lambda r, w: None, store=store))
    sc.run_one_batch()                     # t0 = 50.0 committed
    store.close()

    store2 = DurableStateStore(str(tmp_path / "w"))
    with caplog.at_level("WARNING"):
        sc2 = StreamingContext(Context(), broker, max_records_per_partition=2,
                               checkpoint_path=ckpt)   # default clock
        sc2.subscribe(["t"])
        sc2.foreach_batch(windowed(WindowSpec(size=100.0, kind="time"),
                                   lambda r, w: None, store=store2))
    assert any("not comparable across restarts" in r.message
               for r in caplog.records)
    store2.close()
    # an injected clock is trusted: no warning
    caplog.clear()
    store3 = DurableStateStore(str(tmp_path / "w"))
    with caplog.at_level("WARNING"):
        sc3 = StreamingContext(Context(), broker, max_records_per_partition=2,
                               checkpoint_path=ckpt, clock=lambda: clock["t"])
        sc3.subscribe(["t"])
        sc3.foreach_batch(windowed(WindowSpec(size=100.0, kind="time"),
                                   lambda r, w: None, store=store3))
    assert not any("not comparable" in r.message for r in caplog.records)
    store3.close()


def test_pipeline_flush_delivers_to_keyed_sinks_before_checkpoint(tmp_path):
    """The final partial window must reach the keyed sinks BEFORE the
    drained state is checkpointed (sinks-before-commit, same as batches):
    a sink failure leaves the windower and checkpoint un-drained so the
    flush is retryable, and a successful flush is on disk before the
    checkpoint forgets the window."""
    from repro.core import NearRealTimePipeline, PipelineConfig
    from repro.data import NpzDirectorySink

    broker = Broker()
    broker.create_topic("t", 1)
    for i in range(13):
        broker.produce("t", i)
    sink = NpzDirectorySink(str(tmp_path / "npz"))
    calls = {"fail": 1}
    real_write = sink.write_batch

    def flaky_write(items, **kw):
        if calls["fail"] and any(k == "win-0001" for k, _ in items):
            calls["fail"] -= 1             # fail the flush delivery once
            raise OSError("disk hiccup")
        return real_write(items, **kw)

    sink.write_batch = flaky_write
    pipeline = NearRealTimePipeline(
        broker,
        PipelineConfig(topics=("t",), max_records_per_partition=5,
                       checkpoint_path=str(tmp_path / "ckpt.json")),
        lambda recs, wi, bridge: (f"win-{wi.index:04d}",
                                  {"n": len(recs)}),
        window=WindowSpec(size=10),
        window_state=DurableStateStore(str(tmp_path / "w")),
        sinks=[sink])
    pipeline.run_until_drained(producer_done=lambda: True, idle_timeout=0.05)
    assert sink.keys_on_disk() == ["win-0000"]      # full window delivered
    epoch_before = pipeline.streaming._progress.epoch
    with pytest.raises(OSError):
        pipeline.flush_windows()           # sink failed -> nothing committed
    assert pipeline.streaming._progress.epoch == epoch_before
    assert len(pipeline.windower._buf) == 3         # flush rolled back
    results = pipeline.flush_windows()     # retry succeeds
    assert [k for k, _ in results] == ["win-0001"]
    assert sink.keys_on_disk() == ["win-0000", "win-0001"]
    assert pipeline.streaming._progress.epoch == epoch_before + 1
    assert pipeline.flush_windows() == []  # drained: idempotent
    pipeline.close()


# -- crash: SIGKILL mid-window ------------------------------------------------

_WINDOW = 30
_TOTAL = 600


def _fire_to_dir(out_dir):
    """Window fn: record each fired window idempotently by index — the keyed
    sink discipline that upgrades replays to exactly-once."""
    def fn(records, winfo):
        tmp = os.path.join(out_dir, f".win-{winfo.index:04d}.tmp")
        with open(tmp, "w") as f:
            json.dump(records, f)
        # analyze: ok replace-without-fsync - atomicity vs the reader below, not crash durability
        os.replace(tmp, os.path.join(out_dir, f"win-{winfo.index:04d}.json"))
    return fn


def _run_windowed(root, per_batch_sleep=0.0, max_batches=None):
    broker = Broker(log_factory=DurableLogFactory(os.path.join(root, "wal")))
    DurableLogFactory(os.path.join(root, "wal")).restore(broker)
    store = DurableStateStore(os.path.join(root, "wstate"))
    sc = StreamingContext(Context(), broker, max_records_per_partition=7,
                          checkpoint_path=os.path.join(root, "ckpt.json"))
    sc.subscribe(["t"])
    sc.foreach_batch(windowed(WindowSpec(size=_WINDOW),
                              _fire_to_dir(os.path.join(root, "windows")),
                              store=store))
    n = 0
    while sc.run_one_batch() is not None:
        n += 1
        if per_batch_sleep:
            time.sleep(per_batch_sleep)
        if max_batches is not None and n >= max_batches:
            break
    store.close()


def _crash_consumer(root):
    """Child: consume slowly until SIGKILLed mid-window."""
    _run_windowed(root, per_batch_sleep=0.05)


def _windows_on_disk(root):
    out = {}
    wdir = os.path.join(root, "windows")
    for name in sorted(os.listdir(wdir)):
        if name.startswith("win-") and name.endswith(".json"):
            with open(os.path.join(wdir, name)) as f:
                out[int(name[4:-5])] = json.load(f)
    return out


def test_sigkill_mid_window_restart_fires_identical_windows(tmp_path):
    """The acceptance test: records live in a durable-log broker, window
    state in a DurableStateStore, offsets in the epoch checkpoint. SIGKILL
    the consumer mid-window; the restarted pipeline must fire the exact
    window set a never-crashed run fires — nothing lost off the open window,
    nothing duplicated into another one."""
    root = str(tmp_path)
    os.makedirs(os.path.join(root, "windows"))
    producer = Broker(log_factory=DurableLogFactory(os.path.join(root, "wal")))
    producer.create_topic("t", 1)
    producer.produce_many("t", [(None, i) for i in range(_TOTAL)], partition=0)

    proc = mp.get_context("spawn").Process(target=_crash_consumer,
                                           args=(root,), daemon=True)
    proc.start()
    ckpt = os.path.join(root, "ckpt.json")
    deadline = time.monotonic() + 120
    killed_at = None
    while time.monotonic() < deadline:
        if not proc.is_alive():
            pytest.fail("consumer drained before it could be killed")
        try:
            with open(ckpt) as f:
                consumed = sum(sum(v) for v in json.load(f)["offsets"].values())
        except (OSError, ValueError, KeyError):
            consumed = 0
        # kill only once the open window is non-empty: offsets committed past
        # a window boundary with records accumulated toward the next one
        if consumed >= 3 * _WINDOW and consumed % _WINDOW != 0:
            killed_at = consumed
            os.kill(proc.pid, signal.SIGKILL)
            break
        time.sleep(0.002)
    else:
        proc.kill()
        pytest.fail("never caught the consumer mid-window")
    proc.join(timeout=30)
    pre_crash = _windows_on_disk(root)
    assert pre_crash, "no window fired before the kill"

    # restart in-process over the same wal/checkpoint/state dirs
    _run_windowed(root)

    got = _windows_on_disk(root)
    expect = {k: list(range(k * _WINDOW, (k + 1) * _WINDOW))
              for k in range(_TOTAL // _WINDOW)}
    assert got == expect, (
        f"killed at offset {killed_at}: restarted run must reproduce the "
        f"exact uncrashed window set")
