"""Shared fixtures: the lock-order harness for the chaos suites.

For the delivery, groups, and replication chaos suites every
``Broker`` / ``DeliveryRuntime`` / ``GroupCoordinator`` /
``ReplicaFollower`` / durable-log/store constructed during the test takes
traced locks (``repro.data.locktrace``), and teardown asserts the
recorded acquisition graph has no cycle — the documented coordinator →
broker lock order (and every other ordering the suites exercise) is
machine-checked on each run, not just asserted in a docstring.

Set ``REPRO_LOCKTRACE=0`` to opt out (used to A/B the harness's wall-time
overhead; the acceptance bar is <= 1.1x, measured ~1.0x since these
suites are sleep/IO dominated).
"""
import os

import pytest

_TRACED_SUITES = {"test_delivery", "test_groups", "test_replication"}


@pytest.fixture(autouse=True)
def lock_order_harness(request):
    if (request.module.__name__ not in _TRACED_SUITES
            or os.environ.get("REPRO_LOCKTRACE") == "0"):
        yield
        return
    from repro.data import locktrace
    locktrace.enable()
    try:
        yield
    finally:
        report = locktrace.disable().report()
    assert not report.cycles, (
        "lock-order cycles detected (potential deadlock):\n"
        + report.describe())
