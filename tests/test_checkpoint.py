"""Checkpointing: roundtrip, bf16, async, atomicity, gc."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import AsyncCheckpointer, latest_step, restore, save


def tree():
    return {"params": {"w": jnp.arange(12, dtype=jnp.bfloat16).reshape(3, 4),
                       "b": jnp.ones((5,), jnp.float32)},
            "opt": {"step": jnp.asarray(7, jnp.int32)}}


def test_roundtrip_bf16(tmp_path):
    t = tree()
    save(str(tmp_path), 7, t)
    got, step = restore(str(tmp_path), jax.eval_shape(lambda: t))
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(got)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_pointer_and_explicit_step(tmp_path):
    t = tree()
    save(str(tmp_path), 1, t)
    save(str(tmp_path), 5, t)
    assert latest_step(str(tmp_path)) == 5
    _, step = restore(str(tmp_path), jax.eval_shape(lambda: t), step=1)
    assert step == 1


def test_crash_mid_save_keeps_previous(tmp_path):
    """A stale .tmp dir (crash artifact) must not break restore of the last
    good checkpoint."""
    t = tree()
    save(str(tmp_path), 3, t)
    os.makedirs(tmp_path / "step_00000004.tmp")
    with open(tmp_path / "step_00000004.tmp" / "garbage.npy", "w") as f:
        f.write("partial")
    got, step = restore(str(tmp_path), jax.eval_shape(lambda: t))
    assert step == 3


def test_async_checkpointer_and_gc(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    t = tree()
    for s in (1, 2, 3, 4):
        ck.save(s, t)
    ck.wait()
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_00000003", "step_00000004"]
    got, step = restore(str(tmp_path), jax.eval_shape(lambda: t))
    assert step == 4


def test_missing_leaf_raises(tmp_path):
    t = tree()
    save(str(tmp_path), 1, t)
    bigger = {**t, "extra": jnp.zeros((2,))}
    with pytest.raises(KeyError):
        restore(str(tmp_path), jax.eval_shape(lambda: bigger))
