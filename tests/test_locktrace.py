"""Unit suite for the runtime lock-order harness (repro.data.locktrace):
cycle detection on a scripted AB/BA interleaving, no false positive for
consistent ordering, RLock reentrancy, blocking-call hazards, and the
enable/disable switchboard the conftest fixture relies on.
"""
import queue
import socket
import threading

import pytest

from repro.data import locktrace
from repro.data.locktrace import LockRegistry, TracingLock


@pytest.fixture()
def registry():
    return LockRegistry()


def _run_threads(*targets):
    threads = [threading.Thread(target=t) for t in targets]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=10)
        assert not t.is_alive()


# -- cycle detection ---------------------------------------------------------

def test_ab_ba_interleaving_reports_cycle(registry):
    """Two threads nest A/B in opposite orders. The run itself never
    deadlocks (events serialize it) — the *graph* still has the cycle."""
    a = TracingLock("A", registry)
    b = TracingLock("B", registry)
    first_done = threading.Event()

    def ab():
        with a:
            with b:
                pass
        first_done.set()

    def ba():
        first_done.wait(10)
        with b:
            with a:
                pass

    _run_threads(ab, ba)
    rep = registry.report()
    assert rep.cycles == [["A", "B"]]
    assert ("A", "B") in rep.edges and ("B", "A") in rep.edges
    assert "cycle: A -> B -> A" in rep.describe()


def test_consistent_order_is_not_a_cycle(registry):
    a = TracingLock("A", registry)
    b = TracingLock("B", registry)

    def ab():
        for _ in range(50):
            with a:
                with b:
                    pass

    _run_threads(ab, ab, ab)
    rep = registry.report()
    assert rep.cycles == []
    assert set(rep.edges) == {("A", "B")}
    assert rep.locks == {"A", "B"}


def test_three_lock_cycle(registry):
    a = TracingLock("A", registry)
    b = TracingLock("B", registry)
    c = TracingLock("C", registry)
    for first, second in ((a, b), (b, c), (c, a)):
        with first:
            with second:
                pass
    assert registry.cycles() == [["A", "B", "C"]]


def test_edge_records_first_call_site(registry):
    a = TracingLock("A", registry)
    b = TracingLock("B", registry)
    with a:
        with b:
            pass
    site = registry.report().edges[("A", "B")]
    assert "test_locktrace.py" in site


# -- reentrancy and release pairing ------------------------------------------

def test_rlock_reentrant_acquire_is_not_a_self_edge(registry):
    a = TracingLock("A", registry, reentrant=True)
    b = TracingLock("B", registry)
    with a:
        with a:          # reentrant: pushes, but must not edge A -> A
            with b:      # innermost holder is still A: edge A -> B
                pass
        assert a.locked()
    assert not a.locked()
    rep = registry.report()
    assert set(rep.edges) == {("A", "B")}
    assert rep.cycles == []


def test_release_pairs_by_identity_not_order(registry):
    # hand-over-hand: acquire A, acquire B, release A, release B
    a = TracingLock("A", registry)
    b = TracingLock("B", registry)
    a.acquire()
    b.acquire()
    a.release()
    with TracingLock("C", registry):  # holder should now be B, not A
        pass
    b.release()
    assert set(registry.report().edges) == {("A", "B"), ("B", "C")}


def test_failed_nonblocking_acquire_records_nothing(registry):
    a = TracingLock("A", registry)
    b = TracingLock("B", registry)

    def hold_then_signal(acquired, release):
        b.acquire()
        acquired.set()
        release.wait(10)
        b.release()

    acquired, release = threading.Event(), threading.Event()
    t = threading.Thread(target=hold_then_signal, args=(acquired, release))
    t.start()
    acquired.wait(10)
    with a:
        assert b.acquire(blocking=False) is False
    release.set()
    t.join(10)
    assert registry.report().edges == {}


def test_locked_probe_both_flavors(registry):
    for reentrant in (False, True):
        lk = TracingLock(f"L{reentrant}", registry, reentrant=reentrant)
        assert not lk.locked()
        with lk:
            assert lk.locked()
        assert not lk.locked()


# -- switchboard and hazard probes -------------------------------------------

def test_new_lock_plain_when_disabled():
    assert locktrace.active() is None
    lk, rlk = locktrace.new_lock("x"), locktrace.new_rlock("y")
    assert not isinstance(lk, TracingLock)
    assert not isinstance(rlk, TracingLock)
    with lk, rlk:
        pass


def test_new_lock_traced_when_enabled():
    with locktrace.tracing() as reg:
        lk = locktrace.new_lock("Demo._lock")
        rlk = locktrace.new_rlock("Demo._rlock")
        assert isinstance(lk, TracingLock) and not lk.reentrant
        assert isinstance(rlk, TracingLock) and rlk.reentrant
        assert locktrace.active() is reg
    assert locktrace.active() is None
    assert reg.report().locks == {"Demo._lock", "Demo._rlock"}


def test_enable_twice_raises():
    with locktrace.tracing():
        with pytest.raises(RuntimeError, match="already enabled"):
            locktrace.enable()
    with pytest.raises(RuntimeError, match="not enabled"):
        locktrace.disable()


def test_queue_get_hazard_only_while_holding():
    q = queue.Queue()
    q.put(1)
    q.put(2)
    with locktrace.tracing() as reg:
        lk = locktrace.new_lock("Holder._lock")
        q.get()                      # not holding anything: no hazard
        with lk:
            q.get()                  # blocking forever while holding
            q.put(3)
            q.get(timeout=1)         # bounded wait: fine
    hazards = reg.report().hazards
    assert len(hazards) == 1
    assert hazards[0].held == ("Holder._lock",)
    assert hazards[0].call == "queue.Queue.get(timeout=None)"
    assert "test_locktrace.py" in hazards[0].site


def test_socket_recv_hazard():
    left, right = socket.socketpair()
    try:
        right.sendall(b"ping")
        with locktrace.tracing() as reg:
            lk = locktrace.new_lock("Conn._lock")
            with lk:
                left.settimeout(None)
                assert left.recv(4) == b"ping"
            right.sendall(b"pong")
            left.settimeout(5.0)
            with lk:
                assert left.recv(4) == b"pong"   # bounded: no hazard
        hazards = reg.report().hazards
        assert [h.call for h in hazards] == ["socket.recv(timeout=None)"]
    finally:
        left.close()
        right.close()


def test_disable_restores_patches():
    orig_get = queue.Queue.get
    orig_recv = socket.socket.recv
    with locktrace.tracing():
        assert queue.Queue.get is not orig_get
        assert socket.socket.recv is not orig_recv
    assert queue.Queue.get is orig_get
    assert socket.socket.recv is orig_recv


# -- integration: the production seams record real component locks -----------

def test_broker_seam_records_named_locks():
    with locktrace.tracing() as reg:
        from repro.core.broker import Broker
        broker = Broker()
        broker.create_topic("t", partitions=1)
        broker.produce("t", b"x")
    assert {"Broker._lock", "InMemoryPartitionLog._lock"} <= reg.report().locks
    assert reg.report().cycles == []
