"""Property-based framing suite for the transport's two message kinds.

The contract under test is connection-drop-only: a frame either decodes to
*exactly* what was sent, or the receiving side raises ``FrameError`` (clean
EOF at a frame boundary is ``None``). Truncation at any byte, any single-bit
flip, or arbitrary garbage must never crash the process and must never
surface a different ("garbage") record. All three kinds are exercised: ``P``
(restricted pickle), ``A`` (array frames: pickled skeleton + raw out-of-band
ndarray buffers) and ``S`` (same-host shared-memory frames: the skeleton and
buffer *descriptors* on the wire, the bulk bytes in a server-owned segment).
"""
import socket
import struct
import threading
import zlib

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # container has no hypothesis; smoke path below
    HAVE_HYPOTHESIS = False

from repro.data.transport import (KIND_ARRAY, KIND_PICKLE, KIND_SHM, MAGIC,
                                  FrameError, _ShmPool, build_shm_payload,
                                  decode_message, decode_shm_payload,
                                  encode_message, recv_frame, recv_message,
                                  send_frame, send_message)

_HEADER = struct.Struct(">2sII")       # mirror of the wire header


# -- plumbing ----------------------------------------------------------------

def _pair():
    a, b = socket.socketpair()
    a.settimeout(10)
    b.settimeout(10)
    return a, b


def _eq(a, b) -> bool:
    """Structural equality that is array-aware (== on ndarrays is elementwise)."""
    if isinstance(a, np.ndarray) or isinstance(b, np.ndarray):
        if not (isinstance(a, np.ndarray) and isinstance(b, np.ndarray)):
            return False
        if a.dtype != b.dtype or a.shape != b.shape:
            return False
        equal_nan = np.issubdtype(a.dtype, np.inexact)
        return np.array_equal(a, b, equal_nan=equal_nan)
    if isinstance(a, (tuple, list)) and isinstance(b, (tuple, list)):
        return (type(a) is type(b) and len(a) == len(b)
                and all(_eq(x, y) for x, y in zip(a, b)))
    if isinstance(a, dict) and isinstance(b, dict):
        return (a.keys() == b.keys()
                and all(_eq(a[k], b[k]) for k in a))
    return type(a) is type(b) and a == b


def _roundtrip(obj):
    a, b = _pair()
    try:
        send_message(a, obj)
        return recv_message(b)
    finally:
        a.close()
        b.close()


def _frame_bytes(obj) -> bytes:
    """The exact byte string one message frame occupies on the wire."""
    parts = encode_message(obj)
    payload = b"".join(bytes(p) for p in parts)
    return _HEADER.pack(MAGIC, len(payload), zlib.crc32(payload)) + payload


def _outcome(wire_bytes: bytes, original) -> str:
    """Feed (possibly corrupt) bytes to a receiver; classify the result.
    Anything other than {the exact original, clean EOF, FrameError} fails."""
    a, b = _pair()
    a.sendall(wire_bytes)
    a.close()
    try:
        try:
            got = recv_message(b)
        except FrameError:
            return "rejected"
    finally:
        b.close()
    if got is None:
        return "eof"
    assert _eq(got, original), f"garbage surfaced: {got!r} != {original!r}"
    return "intact"


# -- round trips (deterministic matrix; hypothesis widens it below) ----------

_DTYPES = [np.bool_, np.uint8, np.int16, np.int32, np.int64,
           np.float16, np.float32, np.float64, np.complex64, np.complex128]
_SHAPES = [(), (0,), (1,), (7,), (3, 4), (2, 3, 4)]


def _make_array(dtype, shape, seed=0):
    rng = np.random.default_rng(seed)
    n = int(np.prod(shape))            # () -> 1, any 0-dim -> 0
    raw = rng.integers(0, 100, size=n)
    return raw.astype(dtype).reshape(shape)


def test_array_roundtrip_dtype_shape_matrix_smoke():
    """Deterministic replicas of the hypothesis property (runs everywhere)."""
    for dtype in _DTYPES:
        for shape in _SHAPES:
            arr = _make_array(dtype, shape)
            got = _roundtrip(arr)
            assert _eq(got, arr), (dtype, shape)
    # orders and views: F-contiguous stays F; non-contiguous falls back
    # in-band but still round-trips exactly
    c = _make_array(np.float32, (6, 5), seed=1)
    f = np.asfortranarray(c)
    strided = c[::2, 1::2]
    for arr in (f, strided):
        got = _roundtrip(arr)
        assert _eq(got, arr)
    assert _roundtrip(f).flags["F_CONTIGUOUS"]


def test_decoded_arrays_are_writable():
    """Zero-copy decode must not hand out read-only views — consumers
    (solvers) mutate frames in place."""
    arr = _make_array(np.float32, (16, 16))
    got = _roundtrip(("k", arr))[1]
    assert got.flags.writeable
    got += 1.0                             # must not raise
    assert _eq(got, arr + 1.0)


def test_mixed_payload_roundtrip_smoke():
    objs = [
        b"", b"x" * 70_000, "text", 0, -1, 2.5, None, True,
        {"i": 1, "nested": (1, [2, 3], {"b": b"bytes"})},
        ("produce_many", ("t", [(b"k0", _make_array(np.float32, (3, 4))),
                                (None, (7, _make_array(np.int64, (5,))))]),
         {"partition": 1, "timestamp": 2.0}),
    ]
    for obj in objs:
        assert _eq(_roundtrip(obj), obj)


def test_kind_selection():
    only_pickle = encode_message({"i": 1, "b": b"raw"})
    assert len(only_pickle) == 1 and only_pickle[0][:1] == KIND_PICKLE
    with_array = encode_message((b"k", _make_array(np.float32, (4, 4))))
    assert len(with_array) > 1 and bytes(with_array[0][:1]) == KIND_ARRAY


def test_raw_frame_layer_roundtrip_bytes():
    import os
    a, b = _pair()
    try:
        for payload in (b"", b"z", os.urandom(10_000)):
            send_frame(a, payload)
            assert recv_frame(b) == payload
    finally:
        a.close()
        b.close()


# -- corruption: truncation --------------------------------------------------

_TRUNC_MSG = (b"key-7", _make_array(np.int32, (4, 4)), "meta")


def _check_truncation(cut: int) -> None:
    frame = _frame_bytes(_TRUNC_MSG)
    outcome = _outcome(frame[:cut], _TRUNC_MSG)
    if cut == 0:
        assert outcome == "eof"
    elif cut < len(frame):
        assert outcome == "rejected", f"cut={cut} not rejected"
    else:
        assert outcome == "intact"


def test_every_truncation_point_rejected_smoke():
    """Exhaustive: cut the frame at *every* byte boundary. Only the empty
    stream (clean EOF) and the full frame are not errors."""
    frame = _frame_bytes(_TRUNC_MSG)
    for cut in range(len(frame) + 1):
        _check_truncation(cut)


# -- corruption: bit flips ---------------------------------------------------

def _check_bit_flip(byte_idx: int, bit: int) -> None:
    frame = bytearray(_frame_bytes(_TRUNC_MSG))
    frame[byte_idx % len(frame)] ^= 1 << bit
    # CRC-32 detects every single-bit error; header-field flips hit the
    # magic/length/CRC checks first. Nothing may come out but a rejection.
    assert _outcome(bytes(frame), _TRUNC_MSG) == "rejected"


def test_bit_flips_rejected_smoke():
    """Deterministic replicas of the hypothesis property (runs everywhere):
    flips across the header, the kind byte, the skeleton and the raw array
    region."""
    frame_len = len(_frame_bytes(_TRUNC_MSG))
    rng = np.random.default_rng(11)
    positions = list(range(12))                    # full header + kind byte
    positions += [int(i) for i in rng.integers(12, frame_len, 60)]
    for byte_idx in positions:
        for bit in (0, 3, 7):
            _check_bit_flip(byte_idx, bit)


# -- corruption: garbage payloads against decode_message ---------------------

def test_garbage_payloads_raise_frame_error_smoke():
    rng = np.random.default_rng(7)
    blobs = [bytes(rng.integers(0, 256, n, dtype=np.uint8).tolist())
             for n in (1, 2, 9, 64, 400)]
    cases = [b""] + blobs
    cases += [KIND_PICKLE + b for b in blobs]      # well-framed, bad pickle
    cases += [KIND_ARRAY + b for b in blobs]       # bad region headers
    # region lengths that do not add up
    cases += [KIND_ARRAY + struct.pack(">II", 10, 2)
              + struct.pack(">2Q", 4, 1 << 50) + b"x" * 30]
    for payload in cases:
        with pytest.raises(FrameError):
            decode_message(payload)


# -- exact wire bytes: the scatter-gather send path --------------------------

def _wire_bytes(send_fn) -> bytes:
    """Everything ``send_fn(sock)`` puts on the wire, read concurrently so
    large frames cannot deadlock on the socketpair buffer."""
    a, b = _pair()
    chunks: list[bytes] = []

    def reader():
        while True:
            data = b.recv(1 << 16)
            if not data:
                return
            chunks.append(data)

    t = threading.Thread(target=reader)
    t.start()
    try:
        send_fn(a)
    finally:
        a.close()
    t.join(timeout=10)
    b.close()
    assert not t.is_alive()
    return b"".join(chunks)


def test_send_frame_wire_bytes_exact():
    """``send_frame`` writes exactly header+payload — the sendmsg rewrite
    (no O(frame) header+payload concat) must be byte-identical on the wire."""
    import os
    for payload in (b"", b"k", os.urandom(300_000)):
        got = _wire_bytes(lambda s: send_frame(s, payload))
        assert got == _HEADER.pack(MAGIC, len(payload),
                                   zlib.crc32(payload)) + payload


def test_send_message_wire_bytes_exact():
    """``send_message`` (single-part pickle and multi-part array frames
    alike) is byte-identical to the concatenated encoding."""
    objs = [
        "plain-string",
        {"k": 1, "nested": [b"bytes", None]},
        (b"k", _make_array(np.float32, (512, 512))),     # 1 MiB bulk buffer
        ("produce_many", ("t", [(b"a", _make_array(np.int64, (7,))),
                                (b"b", _make_array(np.float64, (3, 3)))]),
         {}),
    ]
    for obj in objs:
        assert _wire_bytes(lambda s: send_message(s, obj)) == _frame_bytes(obj)


# -- shared-memory 'S' frames ------------------------------------------------

def _shm_payload(obj, pool: _ShmPool) -> bytes:
    """Encode ``obj`` the way RemoteBroker._send_shm does: out-of-band
    buffers into a leased pool segment, small descriptor payload back."""
    parts = encode_message(obj)
    assert len(parts) >= 3, "need an array-bearing message for an S frame"
    bufs = parts[2:]
    need = sum(b.nbytes if isinstance(b, memoryview) else len(b)
               for b in bufs)
    name = pool.alloc(max(need, 1))
    assert name is not None
    return build_shm_payload(parts[1], bufs, name, pool.resolve(name))


@pytest.fixture
def shm_pool():
    pool = _ShmPool()
    yield pool
    pool.release_all()
    assert pool.segment_count() == 0 or all(
        s.unlinked for s in pool._segments.values())


def test_shm_roundtrip_dtype_shape_matrix(shm_pool):
    """Every dtype × shape that round-trips as an 'A' frame round-trips as
    an 'S' frame, buffers resolved out of the shared segment."""
    for dtype in _DTYPES:
        for shape in _SHAPES:
            arr = _make_array(dtype, shape)
            payload = _shm_payload((b"k", arr), shm_pool)
            assert payload[:1] == KIND_SHM
            got, name = decode_shm_payload(payload, shm_pool.resolve)
            shm_pool.track(name, got)
            assert _eq(got, (b"k", arr)), (dtype, shape)


def test_shm_multi_buffer_message(shm_pool):
    """Several arrays in one message pack back to back into one segment."""
    msg = ("produce_many", ("t", [(b"a", _make_array(np.float32, (8, 8))),
                                  (b"b", _make_array(np.int16, (100,))),
                                  (b"c", _make_array(np.float64, (3, 4)))]),
           {"partition": 0})
    payload = _shm_payload(msg, shm_pool)
    got, name = decode_shm_payload(payload, shm_pool.resolve)
    shm_pool.track(name, got)
    assert _eq(got, msg)
    assert shm_pool.segment_count() == 1


def test_shm_decoded_arrays_are_writable(shm_pool):
    payload = _shm_payload((b"k", _make_array(np.float32, (16, 16))),
                           shm_pool)
    got, name = decode_shm_payload(payload, shm_pool.resolve)
    shm_pool.track(name, got)
    arr = got[1]
    assert arr.flags.writeable
    arr += 1.0                             # must not raise


_SHM_MSG = (b"key-7", _make_array(np.int32, (4, 4)), "meta")


def test_shm_truncation_every_point_rejected(shm_pool):
    """Cut the descriptor payload at every byte: nothing but the full
    payload may decode (region lengths never add up on a truncation)."""
    payload = _shm_payload(_SHM_MSG, shm_pool)
    for cut in range(len(payload)):
        with pytest.raises(FrameError):
            decode_shm_payload(payload[:cut], shm_pool.resolve)
    got, name = decode_shm_payload(payload, shm_pool.resolve)
    shm_pool.track(name, got)
    assert _eq(got, _SHM_MSG)


def test_shm_frame_bit_flips_rejected(shm_pool):
    """On the wire the frame CRC covers the whole 'S' payload — name and
    descriptors included — so any single-bit flip is rejected at the frame
    layer before a descriptor is ever dereferenced."""
    payload = _shm_payload(_SHM_MSG, shm_pool)
    frame = _HEADER.pack(MAGIC, len(payload), zlib.crc32(payload)) + payload
    rng = np.random.default_rng(13)
    positions = list(range(12))                    # full header + kind byte
    positions += [int(i) for i in rng.integers(12, len(frame), 40)]
    for byte_idx in positions:
        corrupt = bytearray(frame)
        corrupt[byte_idx] ^= 1 << int(rng.integers(0, 8))
        a, b = _pair()
        a.sendall(bytes(corrupt))
        a.close()
        try:
            with pytest.raises(FrameError):
                recv_frame(b)
        finally:
            b.close()


def test_shm_descriptor_out_of_segment_rejected(shm_pool):
    """A structurally valid descriptor pointing outside the named segment
    is refused — a client can never make the server read foreign memory."""
    payload = bytearray(_shm_payload(_SHM_MSG, shm_pool))
    (name_len,) = struct.unpack_from(">H", payload, 9)
    desc_at = 1 + 10 + name_len            # kind + _SHM_HEADER + name
    struct.pack_into(">QQ", payload, desc_at, 1 << 40, 16)
    with pytest.raises(FrameError, match="outside"):
        decode_shm_payload(bytes(payload), shm_pool.resolve)
    # offset within bounds but length running past the end: same refusal
    struct.pack_into(">QQ", payload, desc_at, 0, 1 << 40)
    with pytest.raises(FrameError, match="outside"):
        decode_shm_payload(bytes(payload), shm_pool.resolve)


def test_shm_unknown_segment_refused(shm_pool):
    """A frame naming a segment this connection does not own is refused
    (resolve returns None for anything outside the connection's pool)."""
    payload = _shm_payload(_SHM_MSG, shm_pool)
    with pytest.raises(FrameError, match="unknown segment"):
        decode_shm_payload(payload, lambda name: None)


def test_shm_garbage_payloads_rejected(shm_pool):
    rng = np.random.default_rng(3)
    for n in (0, 1, 9, 64, 400):
        blob = bytes(rng.integers(0, 256, n, dtype=np.uint8).tolist())
        with pytest.raises(FrameError):
            decode_shm_payload(KIND_SHM + blob, shm_pool.resolve)


def test_shm_kind_refused_by_plain_decode(shm_pool):
    """decode_message (the un-negotiated path) refuses 'S' payloads: a
    connection that never said hello cannot make the server touch shm."""
    payload = _shm_payload(_SHM_MSG, shm_pool)
    with pytest.raises(FrameError, match="unknown message kind"):
        decode_message(payload)


def test_shm_pool_recycles_when_arrays_die():
    """Segments are pooled: once every array decoded out of a segment dies,
    the same segment serves the next lease instead of a new allocation."""
    pool = _ShmPool()
    try:
        names = set()
        for _ in range(5):
            payload = _shm_payload((b"k", _make_array(np.float32, (64, 64))),
                                   pool)
            got, name = decode_shm_payload(payload, pool.resolve)
            pool.track(name, got)
            names.add(name)
            del got                        # last view dies -> refs drop to 0
        assert len(names) == 1             # one segment, five leases
        assert pool.segment_count() == 1
    finally:
        pool.release_all()


# -- hypothesis widening -----------------------------------------------------

if HAVE_HYPOTHESIS:
    _dtype_strategy = st.sampled_from(_DTYPES)
    _shape_strategy = st.lists(st.integers(0, 5), min_size=0, max_size=3) \
        .map(tuple)

    @given(payload=st.binary(max_size=5000))
    @settings(max_examples=50, deadline=None)
    def test_property_pickle_kind_roundtrip(payload):
        assert _eq(_roundtrip((payload, len(payload))), (payload, len(payload)))

    @given(dtype=_dtype_strategy, shape=_shape_strategy,
           seed=st.integers(0, 2 ** 16), fortran=st.booleans())
    @settings(max_examples=60, deadline=None)
    def test_property_array_kind_roundtrip(dtype, shape, seed, fortran):
        arr = _make_array(dtype, shape, seed=seed)
        if fortran and arr.ndim > 1:
            arr = np.asfortranarray(arr)
        got = _roundtrip((b"k", arr))[1]
        assert _eq(got, arr)

    @given(cut=st.integers(0, len(_frame_bytes(_TRUNC_MSG))))
    @settings(max_examples=60, deadline=None)
    def test_property_truncation_never_garbage(cut):
        _check_truncation(cut)

    @given(byte_idx=st.integers(0, len(_frame_bytes(_TRUNC_MSG)) - 1),
           bit=st.integers(0, 7))
    @settings(max_examples=80, deadline=None)
    def test_property_bit_flip_never_garbage(byte_idx, bit):
        _check_bit_flip(byte_idx, bit)
