"""Windowing over micro-batches: tumbling/sliding, count/time, flush."""
import pytest

from repro.core import Broker, Context, StreamingContext
from repro.core.dstream import BatchInfo
from repro.data import SyntheticRateSource, WindowSpec, Windower, windowed


def _batch(index, t):
    return BatchInfo(index=index, ranges=[], num_records=0, scheduled_at=t)


def collect_windows():
    fired = []

    def fn(records, info):
        fired.append((info.index, info.start, info.end, list(records),
                      info.batches, info.partial))
        return len(records)

    return fired, fn


def test_tumbling_count_window():
    fired, fn = collect_windows()
    w = Windower(WindowSpec(size=3), fn)
    assert w.push([0, 1], _batch(0, 0.0)) == []
    assert w.push([2, 3, 4], _batch(1, 0.1)) == [3]
    assert w.push([5], _batch(2, 0.2)) == [3]
    assert fired == [(0, 0.0, 3.0, [0, 1, 2], [0, 1], False),
                     (1, 3.0, 6.0, [3, 4, 5], [1, 2], False)]


def test_sliding_count_window_overlaps():
    fired, fn = collect_windows()
    w = Windower(WindowSpec(size=4, slide=2), fn)
    w.push(list(range(8)), _batch(0, 0.0))
    assert [rec for _, _, _, rec, _, _ in fired] == \
        [[0, 1, 2, 3], [2, 3, 4, 5], [4, 5, 6, 7]]
    assert [(s, e) for _, s, e, _, _, _ in fired] == \
        [(0.0, 4.0), (2.0, 6.0), (4.0, 8.0)]


def test_count_window_flush_fires_partial():
    fired, fn = collect_windows()
    w = Windower(WindowSpec(size=10), fn)
    w.push([1, 2, 3], _batch(0, 0.0))
    assert w.flush() == [3]
    assert fired[-1][3] == [1, 2, 3] and fired[-1][5] is True
    # partial-window contract: end is an exclusive bound on the contents —
    # one past the last record index for the count kind
    assert (fired[-1][1], fired[-1][2]) == (0.0, 3.0)
    assert w.flush() == []                      # nothing left


def test_count_window_flush_end_after_fired_windows():
    fired, fn = collect_windows()
    w = Windower(WindowSpec(size=4), fn)
    w.push(list(range(10)), _batch(0, 0.0))     # windows [0,4), [4,8) fire
    w.flush()
    assert fired[-1] == (2, 8.0, 10.0, [8, 9], [0], True)


def test_time_window_flush_end_is_exclusive_bound():
    """Time-kind partial windows report the open window's scheduled bounds
    [start, start + size) — an exclusive bound on every buffered timestamp,
    exactly like a complete window (it used to report end = max(ts), a
    timestamp *inside* the window, breaking the [start, end) contract)."""
    fired, fn = collect_windows()
    w = Windower(WindowSpec(size=1.0, kind="time"), fn)
    w.push(["a"], _batch(0, 100.0))             # t=0.0
    w.push(["b"], _batch(1, 101.2))             # t=1.2 closes [0,1)
    w.push(["c"], _batch(2, 101.5))             # t=1.5, window [1,2) open
    w.flush()
    assert fired[0][1:3] == (0.0, 1.0)          # complete window
    index, start, end, recs, _, partial = fired[1]
    assert partial is True and recs == ["b", "c"]
    assert (start, end) == (1.0, 2.0)           # scheduled bounds, not max(ts)
    assert all(start <= t < end for t in (1.2, 1.5))


def test_sliding_time_window_flush_bounds():
    fired, fn = collect_windows()
    w = Windower(WindowSpec(size=2.0, slide=1.0, kind="time"), fn)
    w.push([1], _batch(0, 10.0))                # t=0
    w.push([2], _batch(1, 12.5))                # t=2.5 closes [0,2)
    w.flush()                                   # open window [1,3): [2]
    assert fired[-1][1:3] == (1.0, 3.0) and fired[-1][5] is True
    assert fired[-1][3] == [2]


def test_tumbling_time_window():
    fired, fn = collect_windows()
    w = Windower(WindowSpec(size=1.0, kind="time"), fn)
    w.push(["a"], _batch(0, 100.0))             # t=0.0
    w.push(["b"], _batch(1, 100.4))             # t=0.4
    assert fired == []                          # window [0,1) still open
    w.push(["c"], _batch(2, 101.2))             # t=1.2 closes [0,1)
    assert len(fired) == 1
    assert fired[0][3] == ["a", "b"] and (fired[0][1], fired[0][2]) == (0.0, 1.0)
    w.push(["d"], _batch(3, 102.5))             # t=2.5 closes [1,2)
    assert fired[1][3] == ["c"]


def test_sliding_time_window():
    fired, fn = collect_windows()
    w = Windower(WindowSpec(size=2.0, slide=1.0, kind="time"), fn)
    w.push([1], _batch(0, 10.0))                # t=0
    w.push([2], _batch(1, 11.5))                # t=1.5
    w.push([3], _batch(2, 12.5))                # t=2.5 closes [0,2)
    w.push([4], _batch(3, 13.5))                # t=3.5 closes [1,3)
    assert [rec for _, _, _, rec, _, _ in fired] == [[1, 2], [2, 3]]


def test_windowed_over_streaming_context():
    """'Reconstruct over the last K frame batches': sliding count window
    composed on a StreamingContext, fed by a subscribed source."""
    broker = Broker()
    sc = StreamingContext(Context(), broker, max_records_per_partition=5)
    sc.subscribe_source(SyntheticRateSource(rate=1e9, total=20), topic="t")
    wout = []
    sums = []
    sc.foreach_batch(windowed(WindowSpec(size=10, slide=5),
                              lambda recs, wi: sums.append(sum(recs)),
                              windower_out=wout))
    while not (sc.sources_exhausted and sc.lag("t") == 0):
        sc.run_one_batch()
    wout[0].flush()
    # windows [0,10), [5,15), [10,20), then flush of the residual [15,20)
    assert sums == [sum(range(10)), sum(range(5, 15)), sum(range(10, 20)),
                    sum(range(15, 20))]


def test_time_windowed_over_streaming_context_fake_clock():
    """Time-based windows through the full StreamingContext, pinned by an
    injected fake clock: every batch's scheduled_at is scripted, so window
    boundaries (and which records fall in them) are exact, not timing-y."""
    clock = {"t": 100.0}
    broker = Broker()
    sc = StreamingContext(Context(), broker, max_records_per_partition=3,
                          clock=lambda: clock["t"])
    sc.subscribe_source(SyntheticRateSource(rate=1e9, total=12), topic="t",
                        poll_batch=3)
    wout, fired = [], []
    sc.foreach_batch(windowed(
        WindowSpec(size=1.0, kind="time"),
        lambda recs, wi: fired.append((wi.start, wi.end, list(recs),
                                       wi.partial)),
        windower_out=wout))
    # 4 batches of 3 records at rel t = 0.0, 0.4, 0.8, 1.2
    while not (sc.sources_exhausted and sc.lag("t") == 0):
        assert sc.run_one_batch() is not None
        clock["t"] += 0.4
    assert [b.scheduled_at for b in sc.history] == pytest.approx(
        [100.0, 100.4, 100.8, 101.2])
    # the batch at rel 1.2 closed window [0, 1): records from rel 0.0/0.4/0.8
    assert fired == [(0.0, 1.0, list(range(9)), False)]
    wout[0].flush()
    assert fired[1][2] == [9, 10, 11] and fired[1][3] is True


def test_sliding_time_windowed_over_streaming_context_fake_clock():
    clock = {"t": 50.0}
    broker = Broker()
    sc = StreamingContext(Context(), broker, max_records_per_partition=2,
                          clock=lambda: clock["t"])
    sc.subscribe_source(SyntheticRateSource(rate=1e9, total=10), topic="t",
                        poll_batch=2)
    windows = []
    sc.foreach_batch(windowed(
        WindowSpec(size=2.0, slide=1.0, kind="time"),
        lambda recs, wi: windows.append((wi.start, list(recs)))))
    # 5 batches of 2 records at rel t = 0, 1, 2, 3, 4
    while not (sc.sources_exhausted and sc.lag("t") == 0):
        sc.run_one_batch()
        clock["t"] += 1.0
    # [0,2) closes at rel 2 (records of batches at 0,1); [1,3) at rel 3; ...
    assert windows == [(0.0, [0, 1, 2, 3]),
                       (1.0, [2, 3, 4, 5]),
                       (2.0, [4, 5, 6, 7])]


def test_window_spec_validation():
    with pytest.raises(ValueError):
        WindowSpec(size=0)
    with pytest.raises(ValueError):
        WindowSpec(size=4, slide=-1)
    with pytest.raises(ValueError):
        WindowSpec(size=4, kind="session")
